"""The paper's experimental comparison at laptop scale (Tables 2-3 analogue).

CIFAR/ResNet are not available offline, so the same *comparative protocol*
runs on a synthetic Gaussian-cluster classification task with an MLP
(the paper's claims are about optimizer/communication behaviour, which
this preserves): QADAM (ours) vs TernGrad vs blockwise-EF SGD (Zheng et
al.) vs WQuan (post-training weight quantization), at matched wire bits.

  PYTHONPATH=src python examples/paper_repro.py --steps 400
"""
import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qadam import (QAdamConfig, qadam, ef_sgdm, terngrad_sgd,
                              apply_updates, wquan)
from repro.data.pipeline import (ClsDataConfig, classification_dataset,
                                 classification_batches)


def mlp_init(key, d_in, d_hidden, n_classes):
    ks = jax.random.split(key, 3)
    s = 1 / np.sqrt(d_in)
    return {
        "w1": jax.random.normal(ks[0], (d_in, d_hidden)) * s,
        "b1": jnp.zeros((d_hidden,)),
        "w2": jax.random.normal(ks[1], (d_hidden, d_hidden)) * 0.05,
        "b2": jnp.zeros((d_hidden,)),
        "w3": jax.random.normal(ks[2], (d_hidden, n_classes)) * 0.05,
        "b3": jnp.zeros((n_classes,)),
    }


def mlp_apply(p, x):
    h = jnp.tanh(x @ p["w1"] + p["b1"])
    h = jnp.tanh(h @ p["w2"] + p["b2"])
    return h @ p["w3"] + p["b3"]


def loss_fn(p, x, y):
    logits = mlp_apply(p, x)
    return jnp.mean(-jax.nn.log_softmax(logits)[jnp.arange(y.shape[0]), y])


def accuracy(p, x, y):
    return float(jnp.mean(jnp.argmax(mlp_apply(p, x), -1) == y))


def run(opt, steps, data, key, batch=128, seed=0, n_workers=8,
        server_q=None, server_ef=True):
    """Multi-worker protocol: each worker gets its own minibatch; updates
    are the mean of the workers' (quantized) deltas - Algorithm 2.
    Workers are vmapped; one jitted step.

    ``server_q`` (a ``repro.comm`` codec spec, e.g. "log:2") turns on
    two-way compression: the server also quantizes the averaged update
    it broadcasts back, with its own error feedback when ``server_ef``
    (the ``efadam`` protocol, Chen et al. '22)."""
    from repro import comm

    xtr, ytr, xte, yte = data
    params = mlp_init(key, xtr.shape[1], 256, int(ytr.max()) + 1)
    state0 = opt.init(params)
    # independent PRNG stream per worker (TernGrad is stochastic)
    wkeys = jax.vmap(lambda i: jax.random.fold_in(state0.key, i))(
        jnp.arange(n_workers))
    sstack = jax.vmap(lambda k: state0._replace(key=k))(wkeys)
    codec = comm.get_codec(server_q) if server_q else None
    es = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                      params)

    @jax.jit
    def step(params, sstack, es, xs, ys):
        def worker(st, x, y):
            fp = opt.forward_params(params, st)
            g = jax.grad(loss_fn)(fp, x, y)
            upd, st2 = opt.update(g, st, params)
            return upd, st2

        upds, sstack2 = jax.vmap(worker)(sstack, xs, ys)
        mean_upd = jax.tree.map(lambda u: jnp.mean(u, axis=0), upds)
        if codec is not None:
            def srv(u, e):
                send = u + e
                scale = codec.compute_scale(send)
                q = codec.dequantize(codec.quantize(send, scale), scale)
                return q, (send - q if server_ef else jnp.zeros_like(e))
            out = jax.tree.map(srv, mean_upd, es)
            is_pair = lambda o: isinstance(o, tuple)
            mean_upd = jax.tree.map(lambda o: o[0], out, is_leaf=is_pair)
            es = jax.tree.map(lambda o: o[1], out, is_leaf=is_pair)
        return apply_updates(params, mean_upd), sstack2, es

    its = [classification_batches(xtr, ytr, batch, seed=seed + w)
           for w in range(n_workers)]
    for t in range(steps):
        pairs = [next(it) for it in its]
        xs = jnp.stack([p[0] for p in pairs])
        ys = jnp.stack([p[1] for p in pairs])
        params, sstack, es = step(params, sstack, es, xs, ys)
    return params


def wire_bits(name):
    return {"fp32": 32, "qadam_log3": 3, "qadam_log2": 2, "terngrad": 2,
            "blockwise": 1}.get(name, 32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--mode", default="qadam", choices=["qadam", "efadam"],
                    help="efadam: two-way compression - the server also "
                         "quantizes the broadcast update, with its own EF")
    ap.add_argument("--server-q", default="log:2",
                    help="efadam server->worker codec spec")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    data = classification_dataset(ClsDataConfig(seed=1))
    xte, yte = data[2], data[3]

    if args.mode == "efadam":
        sq = args.server_q
        methods = {
            # one-way (worker channel only) vs two-way, matched bits
            "QADAM log-3bit 1way": (lambda: qadam(QAdamConfig(
                alpha=2e-3, grad_q="log:2")), None, None, True),
            f"EFADAM 2way {sq}": (lambda: qadam(QAdamConfig(
                alpha=2e-3, grad_q="log:2")), None, sq, True),
            f"EFADAM 2way {sq} no-srv-EF": (lambda: qadam(QAdamConfig(
                alpha=2e-3, grad_q="log:2")), None, sq, False),
            "EFADAM fp32 workers 2way": (lambda: qadam(QAdamConfig(
                alpha=2e-3, grad_q=None)), None, sq, True),
        }
        rows = []
        for name, (builder, wq_after, srv_q, srv_ef) in methods.items():
            accs = []
            for s in range(args.seeds):
                p = run(builder(), args.steps, data, jax.random.PRNGKey(s),
                        seed=s * 100, n_workers=args.workers,
                        server_q=srv_q, server_ef=srv_ef)
                if wq_after is not None:
                    p = wquan(p, k_x=wq_after, absolute=False)
                accs.append(accuracy(p, xte, yte))
            rows.append((name, float(np.mean(accs)), float(np.std(accs))))
            print(f"{name:28s} acc {np.mean(accs) * 100:.2f} "
                  f"+/- {np.std(accs) * 100:.2f}%")
        if args.out:
            with open(args.out, "w") as f:
                json.dump([{"method": n, "acc": a, "std": s}
                           for n, a, s in rows], f, indent=1)
        return

    methods = {
        # name: (optimizer builder, weight quant after?)
        "QADAM fp32": (lambda: qadam(QAdamConfig(
            alpha=2e-3, grad_q=None, weight_q=None)), None),
        "QADAM log-3bit": (lambda: qadam(QAdamConfig(
            alpha=2e-3, grad_q="log:2")), None),
        "QADAM log-2bit": (lambda: qadam(QAdamConfig(
            alpha=2e-3, grad_q="log:1")), None),
        "QADAM log-3bit no-EF": (lambda: qadam(QAdamConfig(
            alpha=2e-3, grad_q="log:2", error_feedback=False)), None),
        "QADAM + Qx(k=5)": (lambda: qadam(QAdamConfig(
            alpha=2e-3, grad_q="log:2", weight_q="uniform_amax:5")), None),
        "WQuan(k=5) post": (lambda: qadam(QAdamConfig(
            alpha=2e-3, grad_q=None, weight_q=None)), 5),
        "TernGrad": (lambda: terngrad_sgd(alpha=2e-2), None),
        "Blockwise-EF SGD": (lambda: ef_sgdm(alpha=2e-3, beta=0.9,
                                             grad_q="blockwise:256"), None),
    }

    rows = []
    for name, (builder, wq_after) in methods.items():
        accs = []
        for s in range(args.seeds):
            p = run(builder(), args.steps, data, jax.random.PRNGKey(s),
                    seed=s * 100, n_workers=args.workers)
            if wq_after is not None:
                p = wquan(p, k_x=wq_after, absolute=False)
            accs.append(accuracy(p, xte, yte))
        rows.append((name, float(np.mean(accs)), float(np.std(accs))))
        print(f"{name:26s} acc {np.mean(accs) * 100:.2f} "
              f"+/- {np.std(accs) * 100:.2f}%")

    if args.out:
        with open(args.out, "w") as f:
            json.dump([{"method": n, "acc": a, "std": s}
                       for n, a, s in rows], f, indent=1)


if __name__ == "__main__":
    main()
