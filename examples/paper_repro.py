"""The paper's experimental comparison at laptop scale (Tables 2-3 analogue).

CIFAR/ResNet are not available offline, so the same *comparative protocol*
runs on a synthetic Gaussian-cluster classification task with an MLP
(the paper's claims are about optimizer/communication behaviour, which
this preserves): QADAM (ours) vs TernGrad vs blockwise-EF SGD (Zheng et
al.) vs WQuan (post-training weight quantization), at matched wire bits.

  PYTHONPATH=src python examples/paper_repro.py --steps 400
"""
import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qadam import (QAdamConfig, qadam, ef_sgdm, terngrad_sgd,
                              apply_updates, wquan)
from repro.data.pipeline import (ClsDataConfig, classification_dataset,
                                 classification_batches)


def mlp_init(key, d_in, d_hidden, n_classes):
    ks = jax.random.split(key, 3)
    s = 1 / np.sqrt(d_in)
    return {
        "w1": jax.random.normal(ks[0], (d_in, d_hidden)) * s,
        "b1": jnp.zeros((d_hidden,)),
        "w2": jax.random.normal(ks[1], (d_hidden, d_hidden)) * 0.05,
        "b2": jnp.zeros((d_hidden,)),
        "w3": jax.random.normal(ks[2], (d_hidden, n_classes)) * 0.05,
        "b3": jnp.zeros((n_classes,)),
    }


def mlp_apply(p, x):
    h = jnp.tanh(x @ p["w1"] + p["b1"])
    h = jnp.tanh(h @ p["w2"] + p["b2"])
    return h @ p["w3"] + p["b3"]


def loss_fn(p, x, y):
    logits = mlp_apply(p, x)
    return jnp.mean(-jax.nn.log_softmax(logits)[jnp.arange(y.shape[0]), y])


def accuracy(p, x, y):
    return float(jnp.mean(jnp.argmax(mlp_apply(p, x), -1) == y))


def run(opt, steps, data, key, batch=128, seed=0, n_workers=8,
        server_q=None, server_ef=True):
    """Multi-worker protocol: each worker gets its own minibatch; updates
    are the mean of the workers' (quantized) deltas - Algorithm 2.
    Workers are vmapped; one jitted step.

    ``server_q`` (a ``repro.comm`` codec spec, e.g. "log:2") turns on
    two-way compression: the server also quantizes the averaged update
    it broadcasts back, with its own error feedback when ``server_ef``
    (the ``efadam`` protocol, Chen et al. '22)."""
    from repro import comm

    xtr, ytr, xte, yte = data
    params = mlp_init(key, xtr.shape[1], 256, int(ytr.max()) + 1)
    state0 = opt.init(params)
    # independent PRNG stream per worker (TernGrad is stochastic)
    wkeys = jax.vmap(lambda i: jax.random.fold_in(state0.key, i))(
        jnp.arange(n_workers))
    sstack = jax.vmap(lambda k: state0._replace(key=k))(wkeys)
    codec = comm.get_codec(server_q) if server_q else None
    es = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                      params)

    @jax.jit
    def step(params, sstack, es, xs, ys):
        def worker(st, x, y):
            fp = opt.forward_params(params, st)
            g = jax.grad(loss_fn)(fp, x, y)
            upd, st2 = opt.update(g, st, params)
            return upd, st2

        upds, sstack2 = jax.vmap(worker)(sstack, xs, ys)
        mean_upd = jax.tree.map(lambda u: jnp.mean(u, axis=0), upds)
        if codec is not None:
            def srv(u, e):
                send = u + e
                scale = codec.compute_scale(send)
                q = codec.dequantize(codec.quantize(send, scale), scale)
                return q, (send - q if server_ef else jnp.zeros_like(e))
            out = jax.tree.map(srv, mean_upd, es)
            is_pair = lambda o: isinstance(o, tuple)
            mean_upd = jax.tree.map(lambda o: o[0], out, is_leaf=is_pair)
            es = jax.tree.map(lambda o: o[1], out, is_leaf=is_pair)
        return apply_updates(params, mean_upd), sstack2, es

    its = [classification_batches(xtr, ytr, batch, seed=seed + w)
           for w in range(n_workers)]
    for t in range(steps):
        pairs = [next(it) for it in its]
        xs = jnp.stack([p[0] for p in pairs])
        ys = jnp.stack([p[1] for p in pairs])
        params, sstack, es = step(params, sstack, es, xs, ys)
    return params


def wire_bits(name):
    return {"fp32": 32, "qadam_log3": 3, "qadam_log2": 2, "terngrad": 2,
            "blockwise": 1}.get(name, 32)


# ---------------------------------------------------------------------------
# --adaptive: fixed k_g vs runtime-adaptive per-leaf bit allocation
# (repro.adapt) under the same multi-worker protocol, measured bytes/step.
# ---------------------------------------------------------------------------

def _leaf_payload_bytes(numel, spec):
    """Measured wire bytes for one worker's payload of one leaf: encode
    a real tensor and take ``.nbytes`` (no hand-rolled formulas)."""
    from repro import comm
    codec = comm.get_codec(spec)
    x = jnp.linspace(-1.0, 1.0, numel, dtype=jnp.float32)
    if isinstance(codec, comm.BlockwiseCodec):
        from repro.opt import engine
        codes2d, _ = engine.quantize_blockwise(x, codec.block)
        rows = comm.pad_rows(codes2d.reshape(-1)[:numel], 1)
        return comm.pack_rows(rows, codec.bits).nbytes
    payload, _ = comm.encode_rows(x, codec, 1, key=jax.random.PRNGKey(0))
    return payload.nbytes


def run_quantized(steps, data, key, *, batch=128, seed=0, n_workers=8,
                  adaptive=False, budget_ratio=0.6, replan_every=25,
                  fixed_spec="log:6", ema_decay=0.8):
    """The Algorithm-2 worker protocol with the quantizer hoisted out of
    the optimizer: every worker sends Q(delta + e) per leaf with its own
    EF residual, the server applies the worker mean. ``adaptive`` swaps
    the per-leaf codecs every ``replan_every`` steps from the
    repro.adapt allocator fed by observed (amax, meansq) EMAs; otherwise
    every leaf stays on ``fixed_spec`` (the paper's fixed k_g). Returns
    ``(params, info)`` with measured bytes/step and the plan log."""
    from repro import comm
    from repro.adapt import allocate as A
    from repro.adapt import stats as S
    from repro.opt import engine

    xtr, ytr, xte, yte = data
    params = mlp_init(key, xtr.shape[1], 256, int(ytr.max()) + 1)
    opt = qadam(QAdamConfig(alpha=2e-3, grad_q=None, weight_q=None))
    state0 = opt.init(params)
    wkeys = jax.vmap(lambda i: jax.random.fold_in(state0.key, i))(
        jnp.arange(n_workers))
    sstack = jax.vmap(lambda k: state0._replace(key=k))(wkeys)
    es = jax.tree.map(
        lambda p: jnp.zeros((n_workers,) + p.shape, jnp.float32), params)
    names = sorted(params)

    def make_step(plan):
        codecs = {k: comm.get_codec(s) for k, s in zip(names, plan)}

        @jax.jit
        def step(params, sstack, es, xs, ys):
            def worker(st, e, x, y):
                fp = opt.forward_params(params, st)
                loss, g = jax.value_and_grad(loss_fn)(fp, x, y)
                upd, st2 = opt.update(g, st, params)
                q, e2, rows = {}, {}, []
                for k in names:
                    send = upd[k] + e[k]
                    c = codecs[k]
                    if isinstance(c, comm.BlockwiseCodec):
                        codes, scales = engine.quantize_blockwise(
                            send.reshape(-1), c.block)
                        deq = (codes.astype(jnp.float32) * scales[:, None]
                               ).reshape(-1)[:send.size].reshape(send.shape)
                    else:
                        scale = c.compute_scale(send)
                        deq = c.dequantize(c.quantize(send, scale), scale)
                    q[k] = deq
                    e2[k] = send - deq
                    rows.append(jnp.stack([jnp.max(jnp.abs(send)),
                                           jnp.mean(send * send)]))
                return q, st2, e2, jnp.stack(rows), loss

            q, sstack2, es2, rows, losses = jax.vmap(worker)(
                sstack, es, xs, ys)
            mean_upd = jax.tree.map(lambda u: jnp.mean(u, axis=0), q)
            stats = jnp.concatenate(
                [jnp.max(rows[:, :, :1], axis=0),
                 jnp.mean(rows[:, :, 1:], axis=0)], axis=1)
            return (apply_updates(params, mean_upd), sstack2, es2, stats,
                    jnp.mean(losses))
        return step

    def plan_bytes(plan):
        return n_workers * sum(_leaf_payload_bytes(params[k].size, s)
                               for k, s in zip(names, plan))

    ema = S.StatsEMA(len(names), ema_decay)
    plan = tuple(fixed_spec for _ in names)
    steps_cache = {}
    its = [classification_batches(xtr, ytr, batch, seed=seed + w)
           for w in range(n_workers)]
    plan_log = [{"step": 0, "plan": list(plan),
                 "bytes_per_step": plan_bytes(plan)}]
    total_bytes = 0
    curve = []   # (cumulative bytes, train loss)
    t = 0
    while t < steps:
        k = min(replan_every, steps - t) if adaptive else steps - t
        step = steps_cache.setdefault(plan, make_step(plan))
        window_rows = []
        pb = plan_log[-1]["bytes_per_step"]
        for _ in range(k):
            pairs = [next(it) for it in its]
            xs = jnp.stack([p[0] for p in pairs])
            ys = jnp.stack([p[1] for p in pairs])
            params, sstack, es, stats, loss = step(params, sstack, es,
                                                   xs, ys)
            window_rows.append(stats)
            total_bytes += pb
            curve.append((total_bytes, loss))
        t += k
        if adaptive and t < steps:
            for r in np.asarray(jnp.stack(window_rows)):
                ema.update(np.concatenate(
                    [r, np.zeros((len(names), 1))], axis=1))
            snap = ema.snapshot()
            groups = [A.Group(name=k, numel=params[k].size,
                              c=params[k].size, amax=float(snap[i, 0]),
                              meansq=float(snap[i, 1]))
                      for i, k in enumerate(names)]
            budget = int(budget_ratio *
                         A.baseline_cost(groups, n_workers, width=4))
            new = A.allocate_specs(groups, budget, n_workers)
            if new != plan:
                plan = new
                plan_log.append({"step": t, "plan": list(plan),
                                 "bytes_per_step": plan_bytes(plan)})
    curve = [(int(b), float(l)) for b, l in curve]
    return params, {"bytes_per_step": total_bytes / steps,
                    "total_bytes": total_bytes, "plan_log": plan_log,
                    "final_test_loss": float(loss_fn(params, xte, yte)),
                    "curve": curve}


def run_adaptive_compare(args, data):
    xte, yte = data[2], data[3]
    arms = {"fixed k_g=6 (log:6)": False, "adaptive": True}
    results = {}
    for name, adaptive in arms.items():
        losses, accs, infos = [], [], []
        for s in range(args.seeds):
            p, info = run_quantized(
                args.steps, data, jax.random.PRNGKey(s), seed=s * 100,
                n_workers=args.workers, adaptive=adaptive,
                budget_ratio=args.budget, replan_every=args.replan_every)
            losses.append(info["final_test_loss"])
            accs.append(accuracy(p, xte, yte))
            infos.append(info)
        results[name] = {
            "loss": float(np.mean(losses)), "loss_std": float(np.std(losses)),
            "acc": float(np.mean(accs)),
            "bytes_per_step": float(np.mean(
                [i["bytes_per_step"] for i in infos])),
            "plan_log": infos[0]["plan_log"],
            "curve": infos[0]["curve"]}
        print(f"{name:22s} loss {np.mean(losses):.4f} "
              f"+/- {np.std(losses):.4f}  acc {np.mean(accs)*100:.2f}%  "
              f"{np.mean([i['bytes_per_step'] for i in infos])/1e3:.1f}"
              f"KB/step")
    fx, ad = results["fixed k_g=6 (log:6)"], results["adaptive"]
    summary = {"bytes_ratio": ad["bytes_per_step"] / fx["bytes_per_step"],
               "loss_parity": fx["loss"] / ad["loss"]}
    print(f"adaptive/fixed bytes: {summary['bytes_ratio']:.3f}x  "
          f"loss parity (fixed/adaptive): {summary['loss_parity']:.4f}")
    for e in ad["plan_log"]:
        lanes = {}
        for s in e["plan"]:
            lanes[s] = lanes.get(s, 0) + 1
        print(f"  plan @{e['step']}: "
              + " ".join(f"{s}x{n}" for s, n in sorted(lanes.items()))
              + f"  ({e['bytes_per_step']/1e3:.1f}KB/step)")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"results": results, "summary": summary}, f, indent=1)
    fig = args.out and args.out.rsplit(".", 1)[0] + ".png"
    if fig:
        try:
            import matplotlib
            matplotlib.use("Agg")
            import matplotlib.pyplot as plt
        except ImportError:
            print("matplotlib not installed; skipping figure")
            return
        plt.figure(figsize=(6, 4))
        for name, r in results.items():
            b, l = zip(*r["curve"])
            plt.plot(np.asarray(b) / 1e6, l, label=name)
        plt.xlabel("cumulative wire MB (all workers)")
        plt.ylabel("train loss")
        plt.legend()
        plt.title(f"fixed vs adaptive wire at budget {args.budget}x")
        plt.tight_layout()
        plt.savefig(fig, dpi=120)
        print(f"wrote {fig}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--mode", default="qadam", choices=["qadam", "efadam"],
                    help="efadam: two-way compression - the server also "
                         "quantizes the broadcast update, with its own EF")
    ap.add_argument("--server-q", default="log:2",
                    help="efadam server->worker codec spec")
    ap.add_argument("--adaptive", action="store_true",
                    help="compare fixed k_g=6 vs repro.adapt runtime bit "
                         "allocation at matched loss, measured bytes/step")
    ap.add_argument("--budget", type=float, default=0.6,
                    help="--adaptive: byte budget vs the fixed wire")
    ap.add_argument("--replan-every", type=int, default=25)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    data = classification_dataset(ClsDataConfig(seed=1))
    xte, yte = data[2], data[3]

    if args.adaptive:
        run_adaptive_compare(args, data)
        return

    if args.mode == "efadam":
        sq = args.server_q
        methods = {
            # one-way (worker channel only) vs two-way, matched bits
            "QADAM log-3bit 1way": (lambda: qadam(QAdamConfig(
                alpha=2e-3, grad_q="log:2")), None, None, True),
            f"EFADAM 2way {sq}": (lambda: qadam(QAdamConfig(
                alpha=2e-3, grad_q="log:2")), None, sq, True),
            f"EFADAM 2way {sq} no-srv-EF": (lambda: qadam(QAdamConfig(
                alpha=2e-3, grad_q="log:2")), None, sq, False),
            "EFADAM fp32 workers 2way": (lambda: qadam(QAdamConfig(
                alpha=2e-3, grad_q=None)), None, sq, True),
        }
        rows = []
        for name, (builder, wq_after, srv_q, srv_ef) in methods.items():
            accs = []
            for s in range(args.seeds):
                p = run(builder(), args.steps, data, jax.random.PRNGKey(s),
                        seed=s * 100, n_workers=args.workers,
                        server_q=srv_q, server_ef=srv_ef)
                if wq_after is not None:
                    p = wquan(p, k_x=wq_after, absolute=False)
                accs.append(accuracy(p, xte, yte))
            rows.append((name, float(np.mean(accs)), float(np.std(accs))))
            print(f"{name:28s} acc {np.mean(accs) * 100:.2f} "
                  f"+/- {np.std(accs) * 100:.2f}%")
        if args.out:
            with open(args.out, "w") as f:
                json.dump([{"method": n, "acc": a, "std": s}
                           for n, a, s in rows], f, indent=1)
        return

    methods = {
        # name: (optimizer builder, weight quant after?)
        "QADAM fp32": (lambda: qadam(QAdamConfig(
            alpha=2e-3, grad_q=None, weight_q=None)), None),
        "QADAM log-3bit": (lambda: qadam(QAdamConfig(
            alpha=2e-3, grad_q="log:2")), None),
        "QADAM log-2bit": (lambda: qadam(QAdamConfig(
            alpha=2e-3, grad_q="log:1")), None),
        "QADAM log-3bit no-EF": (lambda: qadam(QAdamConfig(
            alpha=2e-3, grad_q="log:2", error_feedback=False)), None),
        "QADAM + Qx(k=5)": (lambda: qadam(QAdamConfig(
            alpha=2e-3, grad_q="log:2", weight_q="uniform_amax:5")), None),
        "WQuan(k=5) post": (lambda: qadam(QAdamConfig(
            alpha=2e-3, grad_q=None, weight_q=None)), 5),
        "TernGrad": (lambda: terngrad_sgd(alpha=2e-2), None),
        "Blockwise-EF SGD": (lambda: ef_sgdm(alpha=2e-3, beta=0.9,
                                             grad_q="blockwise:256"), None),
    }

    rows = []
    for name, (builder, wq_after) in methods.items():
        accs = []
        for s in range(args.seeds):
            p = run(builder(), args.steps, data, jax.random.PRNGKey(s),
                    seed=s * 100, n_workers=args.workers)
            if wq_after is not None:
                p = wquan(p, k_x=wq_after, absolute=False)
            accs.append(accuracy(p, xte, yte))
        rows.append((name, float(np.mean(accs)), float(np.std(accs))))
        print(f"{name:26s} acc {np.mean(accs) * 100:.2f} "
              f"+/- {np.std(accs) * 100:.2f}%")

    if args.out:
        with open(args.out, "w") as f:
            json.dump([{"method": n, "acc": a, "std": s}
                       for n, a, s in rows], f, indent=1)


if __name__ == "__main__":
    main()
