"""Serving with code-resident quantized weights: the paper's
weight-quantization motivation ("storage on edge devices") as a
continuous-batching serving demo.

Loads a smoke-scale LM, serves the same requests fp32-resident and
Q_x-code-resident through a ServeSession, asserts the *measured* device
bytes drop ~4x (packed codes + per-layer scales - not a printed
theoretical "/4"), and checks greedy outputs stay consistent. Quantized
projections contract straight from the codes (the fused dequant-matmul,
``repro.comm.matmul``); the fused and unfused sessions are asserted
token-identical, and a k_x=2 run shows the packed 4-bit lanes cutting
residency well below the int8 ratio.

  PYTHONPATH=src python examples/serve_quantized.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.model import Model
from repro.serve import Request, ServeSession, params_nbytes, quantize_params


def main():
    cfg = get_config("yi-6b", smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qparams = quantize_params(params, k_x=6, min_numel=2 ** 10, pack=True)

    fp_bytes = params_nbytes(params)
    q_bytes = params_nbytes(qparams)
    print(f"{cfg.name} (smoke): fp32 model {fp_bytes / 1e6:.1f}MB; "
          f"resident int codes {q_bytes / 1e6:.1f}MB "
          f"({q_bytes / fp_bytes:.2f}x of fp32, measured on the arrays)")
    assert q_bytes <= 0.30 * fp_bytes, (
        f"quantized residency regressed: {q_bytes} vs {fp_bytes} fp32")

    # k_x=2 packs to the registry's 4-bit lanes: sub-int8 residency
    q2params = quantize_params(params, k_x=2, min_numel=2 ** 10, pack=True)
    q2_bytes = params_nbytes(q2params)
    print(f"k_x=2 packed 4-bit lanes: {q2_bytes / 1e6:.2f}MB "
          f"({q2_bytes / fp_bytes:.2f}x of fp32, measured)")
    assert q2_bytes <= 0.16 * fp_bytes, (
        f"packed 4-bit residency regressed: {q2_bytes} vs {fp_bytes} fp32")

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size, size=12)),
                    max_new_tokens=12) for _ in range(4)]

    outs = {}
    for tag, p in (("fp32", params), ("Qx-int", qparams)):
        sess = ServeSession(model, p, slots=4, max_seq=64)
        t0 = time.time()
        handles = [sess.submit(r) for r in reqs]
        res = sess.drain()
        outs[tag] = [res[h].tokens for h in handles]
        print(f"{tag:7s}: {sum(len(t) for t in outs[tag])} tokens "
              f"in {time.time() - t0:.2f}s; req0 -> {outs[tag][0][:8]}")

    agree = np.mean([
        np.mean(np.asarray(a[:6]) == np.asarray(b[:6]))
        for a, b in zip(outs["fp32"], outs["Qx-int"])])
    first = np.mean([a[0] == b[0]
                     for a, b in zip(outs["fp32"], outs["Qx-int"])])
    print(f"greedy agreement over first 6 tokens: {agree * 100:.0f}%; "
          f"first tokens: {first * 100:.0f}% "
          f"(quantization perturbs logits mildly - Table 2's 'WQuan' row)")
    # k_x=6 on random smoke weights drifts after a few tokens; the gate is
    # first-token agreement (with margin), not the full-sequence figure
    assert first >= 0.75, "quantized serving diverged from fp32 immediately"

    # the fused dequant-matmul is bitwise-identical to dequantize-then-
    # matmul, so fused vs unfused sessions must emit IDENTICAL tokens -
    # at the aggressive k_x=2 lanes too, where any decode bug would show
    def run(sess):
        handles = [sess.submit(r) for r in reqs]
        res = sess.drain()
        return [res[h].tokens for h in handles]

    for tag, p in (("qx6", qparams), ("qx2", q2params)):
        tf = run(ServeSession(model, p, slots=4, max_seq=64))
        tp = run(ServeSession(model, p, slots=4, max_seq=64,
                              fused_matmul=False))
        assert tf == tp, f"{tag}: fused tokens diverged from unfused"
    print("fused dequant-matmul tokens identical to unfused (qx6 + qx2)")


if __name__ == "__main__":
    main()
