"""Serving with quantized-resident weights: the paper's weight-quantization
motivation ("storage on edge devices") as a serving engine demo.

Loads a smoke-scale LM, serves a batch of requests twice - fp32-resident
and Q_x-resident - and checks the outputs stay consistent while the model
footprint drops ~4x.

  PYTHONPATH=src python examples/serve_quantized.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.model import Model
from repro.serve.engine import Engine, Request


def main():
    cfg = get_config("gemma2-2b", smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    nbytes = sum(int(np.prod(p.shape)) * 4 for p in jax.tree.leaves(params))
    print(f"{cfg.name} (smoke): fp32 model {nbytes / 1e6:.1f}MB; "
          f"int-coded (k_x=6) ~{nbytes / 4 / 1e6:.1f}MB on device")

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size, size=12)),
                    max_new_tokens=12) for _ in range(4)]

    outs = {}
    for tag, quantized in (("fp32", False), ("Qx-int", True)):
        eng = Engine(model, params, max_seq=64, quantized=quantized)
        t0 = time.time()
        res = eng.generate(reqs)
        outs[tag] = [r.tokens for r in res]
        print(f"{tag:7s}: {sum(len(r.tokens) for r in res)} tokens "
              f"in {time.time() - t0:.2f}s; req0 -> {res[0].tokens[:8]}")

    agree = np.mean([
        np.mean(np.asarray(a[:6]) == np.asarray(b[:6]))
        for a, b in zip(outs["fp32"], outs["Qx-int"])])
    print(f"greedy agreement over first 6 tokens: {agree * 100:.0f}% "
          f"(quantization perturbs logits mildly - Table 2's 'WQuan' row)")


if __name__ == "__main__":
    main()
