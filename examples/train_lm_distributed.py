"""End-to-end driver: distributed QAdam-EF training of a ~100M-param LM
(Algorithms 2+3) on 8 simulated devices - 4 workers x 2-way context
parallelism, int8 update exchange + int8 weight broadcast.

  python examples/train_lm_distributed.py --steps 300

(The device simulation flag must precede the jax import, so run this file
directly, not under another jax process.)
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses
import json

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--alpha", type=float, default=1e-3)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    import jax
    from repro.configs import get_config
    from repro.models.config import ModelConfig
    from repro.models.model import Model
    from repro.dist.step import make_train_step, TrainConfig
    from repro.train.loop import comm_bytes_per_step
    from repro.train.session import SessionConfig, TrainSession
    from repro.data.pipeline import batch_for_model

    # ~100M params: 8 layers of d=768 GQA + 32k vocab
    cfg = dataclasses.replace(
        get_config("yi-6b", smoke=True),
        n_layers=8, d_model=768, n_heads=12, n_kv_heads=4,
        d_ff=2048, vocab_size=32000, dtype="float32")
    model = Model(cfg)
    print(f"params: {cfg.n_params() / 1e6:.1f}M")

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    tc = TrainConfig(alpha=args.alpha, schedule="constant",
                     grad_k=6, weight_k=6, weight_absolute=False,
                     worker_axes=("data",))
    art = make_train_step(model, mesh, tc)
    comm = comm_bytes_per_step(art, tc)
    print(f"4 workers x 2-way CP; per-device wire/step: "
          f"exchange {comm['update_exchange_bytes'] / 1e6:.1f}MB + "
          f"broadcast {comm['weight_broadcast_bytes'] / 1e6:.1f}MB "
          f"(fp32 all-reduce would be "
          f"{comm['shard_params'] * 8 / 1e6:.1f}MB)")

    batches = batch_for_model(cfg, args.seq, args.global_batch, seed=0)
    # TrainSession: batches prefetched + staged to device on a background
    # thread, losses device-resident between log boundaries (the stats
    # line shows dispatches vs host syncs)
    with TrainSession.from_artifacts(
            art, batches, SessionConfig(log_every=10)) as sess:
        history = sess.run(args.steps)
        stats = dict(sess.stats)
    print(f"session stats: {stats}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(history, f)
    losses = [h for h in history if "loss" in h]
    first, last = losses[0]["loss"], losses[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f}")
    assert last < first, "training must make progress"


if __name__ == "__main__":
    main()
