"""Quickstart: train a small LM with Quantized Adam + Error Feedback
(Algorithm 1) and watch the communication budget shrink 8x.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import Model
from repro.core.qadam import QAdamConfig, qadam
from repro.core.quantizers import get_quantizer
from repro.core.packing import pack_codes
from repro.data.pipeline import batch_for_model
from repro.opt.multistep import make_chunked_train_step, stack_batches


def main():
    cfg = get_config("yi-6b", smoke=True)  # 2-layer GQA toy
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model: {cfg.name} (smoke) - {n_params / 1e6:.2f}M params")

    # Algorithm 1: log-grid Q_g (4-bit wire) + EF + absolute-grid Q_x
    opt = qadam(QAdamConfig(alpha=3e-3, grad_q="log:6",
                            weight_q="uniform_amax:7",
                            weight_q_min_numel=2 ** 14))
    state = opt.init(params)

    batches = batch_for_model(cfg, seq_len=64, global_batch=8)

    # wire accounting for one parameter tensor, to make the 8x concrete
    q = get_quantizer("log:6")
    leaf = params["blocks"]["attn"]["q"]
    qt = q.encode(leaf)
    packed = pack_codes(qt.codes, 4)
    print(f"example tensor {leaf.shape}: fp32 wire {leaf.size * 4 / 1e3:.1f}KB"
          f" -> 4-bit codes {packed.size / 1e3:.1f}KB"
          f" ({leaf.size * 4 / packed.size:.1f}x smaller)")

    # the scan-chunked hot loop: 10 steps per compiled call, parameter and
    # optimizer-state buffers donated (repro.opt.multistep); the update
    # itself runs through the backend-dispatched engine (repro.opt.engine)
    def loss_fn(p, batch):
        ls, nt = model.loss(p, batch)
        return ls / nt

    chunk_steps = 10
    chunk = make_chunked_train_step(opt, loss_fn)
    for start in range(0, 40, chunk_steps):
        stacked = stack_batches([next(batches) for _ in range(chunk_steps)])
        params, state, losses = chunk(params, state, stacked)
        print(f"steps {start + 1:3d}-{start + chunk_steps:3d}  "
              f"loss {float(losses[-1]):.4f}")
    print("done - loss decreasing under 4-bit update + 8-bit weight wire, "
          f"{chunk_steps} steps per dispatch.")


if __name__ == "__main__":
    main()
