import os
import sys as _sys
if "--bench" not in _sys.argv:  # bench timing wants the real device count
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")

"""Re-derive collective bytes for existing dryrun JSONL records using the
StableHLO parser (original dtypes), without recompiling: collective totals
come from the unrolled L=1/L=2 LOWERINGS only (entry + L*body fit).

  PYTHONPATH=src python -m benchmarks.recollect results/dryrun_single.jsonl

Or collect a benchmark baseline (runs benches from ``benchmarks.run`` and
writes a committed JSON snapshot so the perf trajectory is queryable):

  PYTHONPATH=src python -m benchmarks.recollect --bench kernels,comm_cost \\
      --out BENCH_pr2.json
"""
import dataclasses
import json
import sys

import numpy as np


def collect_bench(names, out_path):
    """Run the named benches and snapshot their rows as JSON."""
    import platform
    import jax
    from benchmarks import run as bench_run

    rows = []

    def emit(name, us, derived, ratio=None):
        row = {"name": name, "us_per_call": round(us, 1),
               "derived": derived}
        if ratio is not None:
            # dimensionless figure (speedup, residency) - the derived
            # string is for eyes, this field is for tooling (compare.py)
            row["ratio"] = round(float(ratio), 4)
        rows.append(row)
        cell = "" if ratio is None else f"{ratio:.4f}"
        print(f"{name},{us:.1f},{derived},{cell}", flush=True)

    for n in names:
        bench_run.BENCHES[n](emit)
    snap = {"benches": names,
            "backend": jax.default_backend(),
            "device": jax.devices()[0].device_kind,
            "python": platform.python_version(),
            "jax": jax.__version__,
            "rows": rows}
    with open(out_path, "w") as f:
        json.dump(snap, f, indent=1)
    print(f"wrote {len(rows)} rows -> {out_path}")


def main():
    if "--bench" in sys.argv:
        import argparse
        ap = argparse.ArgumentParser()
        ap.add_argument("--bench", required=True,
                        help="comma list of bench names")
        ap.add_argument("--out", default="BENCH_snapshot.json")
        args = ap.parse_args()
        collect_bench(args.bench.split(","), args.out)
        return
    path = sys.argv[1]
    rows = [json.loads(l) for l in open(path)]

    import jax
    from repro.configs import get_config, INPUT_SHAPES
    from repro.launch.mesh import (make_production_mesh, PEAK_FLOPS_BF16,
                                   HBM_BW, ICI_BW_PER_LINK)
    from repro.launch.dryrun import (_lower_one, parse_collectives,
                                     apply_model_overrides)

    out = []
    for r in rows:
        if r.get("skipped") or r.get("error"):
            out.append(r)
            continue
        arch, shape = r["arch"], r["shape"]
        mp = r.get("multi_pod", False)
        try:
            cfg = apply_model_overrides(get_config(arch),
                                        r.get("model_overrides"))
            seq, gbatch, kind = INPUT_SHAPES[shape]
            mesh = make_production_mesh(multi_pod=mp)
            ms = dict(zip(mesh.axis_names, mesh.devices.shape))
            W = tuple(a for a in ("pod", "data") if a in ms)
            bsh = bool(W) and gbatch % int(
                np.prod([ms[a] for a in W])) == 0
            enc_seq = 1536 if cfg.arch_type == "encdec" else 0
            pts = []
            for L in (2, 3):
                reps = {"n_layers": L, "scan_unroll": True}
                if cfg.encoder_layers:
                    reps["encoder_layers"] = L
                cfg_l = dataclasses.replace(cfg, **reps)
                lw = _lower_one(cfg_l, kind, mesh, gbatch, seq, enc_seq, W,
                                bsh, r.get("train_overrides"))
                pts.append(parse_collectives(lw.as_text()))
            L_true = cfg.n_layers
            detail = {}
            for k in ("all-gather", "all-reduce", "reduce-scatter",
                      "all-to-all", "collective-permute"):
                detail[k] = pts[0][k] + (L_true - 2) * (pts[1][k] - pts[0][k])
            total = sum(detail.values())
            r["collective_bytes"] = total
            r["collectives"] = detail
            r["roofline"]["collective_s"] = total / ICI_BW_PER_LINK
            terms = r["roofline"]
            r["bottleneck"] = max(
                ("compute_s", "memory_s", "collective_s"),
                key=lambda k: terms[k]).replace("_s", "")
            print(f"[OK] {arch} x {shape} x {'multi' if mp else 'single'}: "
                  f"coll={total:.3g}B x={terms['collective_s']:.4f}s "
                  f"bound={r['bottleneck']}", flush=True)
        except Exception as ex:  # noqa
            print(f"[FAIL] {arch} x {shape}: {type(ex).__name__}: {ex}",
                  flush=True)
        out.append(r)

    with open(path, "w") as f:
        for r in out:
            f.write(json.dumps(r) + "\n")


if __name__ == "__main__":
    main()
