import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Re-derive collective bytes for existing dryrun JSONL records using the
StableHLO parser (original dtypes), without recompiling: collective totals
come from the unrolled L=1/L=2 LOWERINGS only (entry + L*body fit).

  PYTHONPATH=src python -m benchmarks.recollect results/dryrun_single.jsonl
"""
import dataclasses
import json
import sys

import numpy as np


def main():
    path = sys.argv[1]
    rows = [json.loads(l) for l in open(path)]

    import jax
    from repro.configs import get_config, INPUT_SHAPES
    from repro.launch.mesh import (make_production_mesh, PEAK_FLOPS_BF16,
                                   HBM_BW, ICI_BW_PER_LINK)
    from repro.launch.dryrun import (_lower_one, parse_collectives,
                                     apply_model_overrides)

    out = []
    for r in rows:
        if r.get("skipped") or r.get("error"):
            out.append(r)
            continue
        arch, shape = r["arch"], r["shape"]
        mp = r.get("multi_pod", False)
        try:
            cfg = apply_model_overrides(get_config(arch),
                                        r.get("model_overrides"))
            seq, gbatch, kind = INPUT_SHAPES[shape]
            mesh = make_production_mesh(multi_pod=mp)
            ms = dict(zip(mesh.axis_names, mesh.devices.shape))
            W = tuple(a for a in ("pod", "data") if a in ms)
            bsh = bool(W) and gbatch % int(
                np.prod([ms[a] for a in W])) == 0
            enc_seq = 1536 if cfg.arch_type == "encdec" else 0
            pts = []
            for L in (2, 3):
                reps = {"n_layers": L, "scan_unroll": True}
                if cfg.encoder_layers:
                    reps["encoder_layers"] = L
                cfg_l = dataclasses.replace(cfg, **reps)
                lw = _lower_one(cfg_l, kind, mesh, gbatch, seq, enc_seq, W,
                                bsh, r.get("train_overrides"))
                pts.append(parse_collectives(lw.as_text()))
            L_true = cfg.n_layers
            detail = {}
            for k in ("all-gather", "all-reduce", "reduce-scatter",
                      "all-to-all", "collective-permute"):
                detail[k] = pts[0][k] + (L_true - 2) * (pts[1][k] - pts[0][k])
            total = sum(detail.values())
            r["collective_bytes"] = total
            r["collectives"] = detail
            r["roofline"]["collective_s"] = total / ICI_BW_PER_LINK
            terms = r["roofline"]
            r["bottleneck"] = max(
                ("compute_s", "memory_s", "collective_s"),
                key=lambda k: terms[k]).replace("_s", "")
            print(f"[OK] {arch} x {shape} x {'multi' if mp else 'single'}: "
                  f"coll={total:.3g}B x={terms['collective_s']:.4f}s "
                  f"bound={r['bottleneck']}", flush=True)
        except Exception as ex:  # noqa
            print(f"[FAIL] {arch} x {shape}: {type(ex).__name__}: {ex}",
                  flush=True)
        out.append(r)

    with open(path, "w") as f:
        for r in out:
            f.write(json.dumps(r) + "\n")


if __name__ == "__main__":
    main()
