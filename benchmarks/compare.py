"""Diff a fresh benchmark run against a committed baseline snapshot.

  PYTHONPATH=src python -m benchmarks.compare --baseline BENCH_pr5.json
  PYTHONPATH=src python -m benchmarks.compare --baseline BENCH_pr5.json \\
      --bench comm_codec --out compare_report.md --json compare.json

Runs the baseline's benches (or ``--bench``), joins rows by name, and
gates on PER-ROW budgets instead of one blanket threshold - the check
that would have caught PR-5's fused log decode landing at 0.23x of the
legacy path while every other row looked fine.

Two gate classes:

* ratio floors (always on, machine-independent): rows carrying a
  dimensionless ``ratio`` - fused-vs-legacy speedups, warm-vs-cold
  startup - must clear a named floor. Fused log DECODE must reach 1.0x
  (the SMEM-LUT kernel does zero transcendentals; legacy pays exp2 per
  element), encode and the uniform paths get 1/1.5 (CPU fusion jitter),
  startup warm must beat cold.
* time budgets (``--gate-times``, off by default): fresh us_per_call
  may not exceed baseline x ``--time-budget``. Wall-clock comparisons
  across machines are noise, so this only makes sense when the baseline
  was collected on the same runner class.

Exit code 1 when any gate fails; the markdown report marks each row.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

# Ordered prefix -> floor. First match wins; rows with a ratio but no
# matching rule are reported, not gated (e.g. serve_resident_ratio is a
# size figure, smaller is better).
RATIO_FLOORS = [
    ("comm_decode_speedup_log", 1.0),     # the PR-6 fix: no grace
    ("comm_decode_speedup_", 1 / 1.5),
    ("comm_encode_speedup_", 1 / 1.5),
    ("startup_train_speedup", 1.0),       # warm must beat cold
    ("startup_serve_speedup", 1.0),
    # longest prefix first: the nofuse row must not hit the gated rule
    ("serve_session_qx6_nofuse", 1 / 1.5),
    ("serve_session_qx6", 1.0),           # PR-7 headline: code-resident
                                          # serving at least as fast as fp32
    ("serve_fused_speedup", 1 / 1.5),     # fused vs unfused, noise grace
    # PR-8 headline: the adaptive wire must spend <= 0.6x the fixed
    # k_g=6 bytes/step while holding final loss within 1%
    ("adapt_bytes_reduction", 1 / 0.6),
    ("adapt_loss_parity", 0.99),
    # PR-9 headline: the 2x4 hierarchical topology must ship <= 0.27x
    # flat's inter-node wire bytes (accounting says exactly 0.25x), and
    # the tuned exchange bucket must never lose to the config default
    ("dist_hier_inter_bytes", 1 / 0.27),
    ("dist_bucket_tuned", 1.0),
    # PR-10 headline: paged KV cache at equal cache memory - tokens/s at
    # least fixed-lane's, >= 2x peak concurrent requests, and p99
    # time-to-first-token within 1.5x of fixed (it is typically far
    # better: admission doesn't wait for a whole free lane)
    ("serve_paged_toks", 1.0),
    ("serve_paged_concurrency", 2.0),
    ("serve_ttft_p99", 1 / 1.5),
]


def ratio_floor(name):
    for prefix, floor in RATIO_FLOORS:
        if name.startswith(prefix):
            return floor
    return None


def row_ratio(row):
    """Numeric ratio of a snapshot row; pre-PR-6 baselines only carried
    it inside the derived string ("0.23x"), so fall back to parsing."""
    if row.get("ratio") is not None:
        return float(row["ratio"])
    m = re.match(r"^(\d+(?:\.\d+)?)x", str(row.get("derived", "")))
    return float(m.group(1)) if m else None


def fresh_rows(names):
    from benchmarks import run as bench_run
    rows = []

    def emit(name, us, derived, ratio=None):
        row = {"name": name, "us_per_call": round(us, 1), "derived": derived}
        if ratio is not None:
            row["ratio"] = round(float(ratio), 4)
        rows.append(row)
        print(f"# {name},{us:.1f},{derived}", file=sys.stderr, flush=True)

    for n in names:
        bench_run.BENCHES[n](emit)
    return rows


def compare(base_rows, new_rows, *, gate_times=False, time_budget=2.0):
    base = {r["name"]: r for r in base_rows}
    results = []
    for r in new_rows:
        b = base.get(r["name"])
        entry = {"name": r["name"], "us": r["us_per_call"],
                 "base_us": b["us_per_call"] if b else None,
                 "ratio": row_ratio(r),
                 "base_ratio": row_ratio(b) if b else None,
                 "status": "ok", "detail": ""}
        floor = ratio_floor(r["name"])
        if floor is not None and entry["ratio"] is not None:
            if entry["ratio"] < floor:
                entry["status"] = "FAIL"
                entry["detail"] = (f"ratio {entry['ratio']:.2f} < "
                                   f"floor {floor:.2f}")
        if (entry["status"] == "ok" and gate_times and b
                and b["us_per_call"] > 0 and r["us_per_call"] > 0):
            rel = r["us_per_call"] / b["us_per_call"]
            if rel > time_budget:
                entry["status"] = "FAIL"
                entry["detail"] = (f"{rel:.2f}x baseline time "
                                   f"(budget {time_budget:.2f}x)")
        if b is None:
            entry["detail"] = entry["detail"] or "new row (no baseline)"
        results.append(entry)
    return results


def render_md(results, baseline_path):
    lines = [f"## Bench compare vs `{os.path.basename(baseline_path)}`", "",
             "| name | us/call | base us | ratio | base ratio | status |",
             "|---|---|---|---|---|---|"]
    for e in results:
        fmt = lambda v, p="{:.1f}": "-" if v is None else p.format(v)
        status = e["status"] + (f" ({e['detail']})" if e["detail"] else "")
        lines.append(f"| {e['name']} | {fmt(e['us'])} | {fmt(e['base_us'])} |"
                     f" {fmt(e['ratio'], '{:.2f}')} |"
                     f" {fmt(e['base_ratio'], '{:.2f}')} | {status} |")
    failed = [e for e in results if e["status"] == "FAIL"]
    lines += ["", f"**{len(failed)} gate failure(s), "
                  f"{len(results)} rows checked.**"]
    return "\n".join(lines) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="committed snapshot (recollect.py --bench output)")
    ap.add_argument("--bench", default=None,
                    help="comma list of benches (default: baseline's)")
    ap.add_argument("--out", default=None, help="markdown report path "
                    "(default stdout)")
    ap.add_argument("--json", default=None, help="machine-readable results")
    ap.add_argument("--gate-times", action="store_true",
                    help="also gate absolute us_per_call vs baseline "
                         "(same-machine baselines only)")
    ap.add_argument("--time-budget", type=float, default=2.0)
    args = ap.parse_args()

    with open(args.baseline) as f:
        snap = json.load(f)
    names = args.bench.split(",") if args.bench else snap["benches"]
    results = compare(snap["rows"], fresh_rows(names),
                      gate_times=args.gate_times,
                      time_budget=args.time_budget)
    md = render_md(results, args.baseline)
    if args.out:
        with open(args.out, "w") as f:
            f.write(md)
        print(f"wrote {args.out}", file=sys.stderr)
    else:
        print(md)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"baseline": args.baseline, "results": results}, f,
                      indent=1)
    if any(e["status"] == "FAIL" for e in results):
        sys.exit(1)


if __name__ == "__main__":
    main()
