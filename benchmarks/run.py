"""Benchmark harness - one entry per paper table/figure + system benches.

  PYTHONPATH=src python -m benchmarks.run               # all, CSV to stdout
  PYTHONPATH=src python -m benchmarks.run --only kernels
  PYTHONPATH=src python -m benchmarks.run --suite comm --trace

Benches (name -> paper artifact):
  table2_cifar100_analogue  - Table 2 protocol (QADAM vs TernGrad vs
                              blockwise-EF vs WQuan) on the synthetic
                              classification task, reduced steps
  table3_cifar10_analogue   - Table 3 protocol, second seed/task split
  fig34_convergence         - Figures 3/4: loss-vs-step curves per method
  comm_cost                 - the 'Comm'/'Size' columns: wire bytes per
                              step/model at each quantization level
  kernels                   - Pallas kernel micro-bench (interpret mode on
                              CPU: correctness-path timing, not TPU perf)
  startup                   - cold vs warm jit startup through the
                              persistent compile cache + AOT artifacts
  roofline                  - reads results/dryrun_single.jsonl and emits
                              the three roofline terms per (arch x shape)

Output format: ``name,us_per_call,derived,ratio`` CSV rows; ``ratio`` is
a machine-readable dimensionless figure (fused-vs-legacy speedup,
warm-vs-cold) on rows where us_per_call alone is meaningless, else
empty.

``--trace [--trace-dir D]`` wraps the run in ``jax.profiler.trace``
with one ``TraceAnnotation`` per bench, so a regression like PR-5's
fused log decode (0.23x: per-element exp2 on unpacked codes) shows up
as a named hot region in the timeline instead of surviving five PRs.
Profiler overhead distorts absolute timings (10x+ on CPU interpret
runs), so never combine ``--trace`` with the ``BENCH_ASSERT_*`` gates
or a baseline snapshot - traced runs are for reading timelines.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))


def _time_call(fn, *args, reps=5, warmup=2):
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


# --------------------------------------------------------------------------

def bench_kernels(emit):
    import jax.numpy as jnp
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    for numel in (1 << 16, 1 << 20):
        x = jnp.asarray(rng.normal(size=(numel,)).astype(np.float32))
        us = _time_call(lambda v: ops.quantize_log(v, 6)[0], x)
        emit(f"kernel_quantize_log_{numel}", us, f"{numel}el")
        codes, scale = ops.quantize_log(x, 6)
        us = _time_call(lambda c: ops.dequantize_log(c, scale, 6), codes)
        emit(f"kernel_dequantize_log_{numel}", us, f"{numel}el")
        m = jnp.zeros_like(x)
        us = _time_call(
            lambda g: ops.adam_ef_step(g, m, m, m, 1e-3, 0.99, 0.9, 1e-5,
                                       6)[2], x)
        emit(f"kernel_adam_ef_{numel}", us, f"{numel}el")
    bench_opt_step(emit)


def _time_chain(fn, p, s, k_steps, reps=5, warmup=2):
    """Time fn(p, s) -> (p, s) with the state *chained* through calls, so
    buffer donation is exercised for real (each call consumes the
    previous call's output). Returns us per optimizer step."""
    import jax
    for r in range(warmup + reps):
        if r == warmup:
            jax.block_until_ready(p)
            t0 = time.perf_counter()
        p, s = fn(p, s)
    jax.block_until_ready(p)
    return (time.perf_counter() - t0) / (reps * k_steps) * 1e6


def bench_opt_step(emit, k_steps=16):
    """Single-machine qadam() through the engine: the per-step jax.jit
    loop vs the lax.scan-chunked, buffer-donating multi-step. Reports
    us/step for each; the scan path amortizes Python dispatch + jit-cache
    lookup + per-step host sync, so it must come out faster."""
    import jax
    import jax.numpy as jnp
    from repro.core.qadam import QAdamConfig, qadam, apply_updates
    from repro.opt.multistep import make_chunked_update

    rng = np.random.default_rng(1)
    for numel in (1 << 14, 1 << 18):
        params = {"w": jnp.asarray(rng.normal(size=(numel,), scale=0.1)
                                   .astype(np.float32))}
        gstack = jnp.asarray(rng.normal(size=(k_steps, numel))
                             .astype(np.float32))
        opt = qadam(QAdamConfig(alpha=1e-3, grad_q="log:6"))
        state0 = opt.init(params)

        @jax.jit
        def one_step(p, s, g):
            upd, s2 = opt.update({"w": g}, s, p)
            return apply_updates(p, upd), s2

        def loop_k(p, s):
            for i in range(k_steps):
                p, s = one_step(p, s, gstack[i])
            return p, s

        us = _time_chain(loop_k, params, state0, k_steps)
        emit(f"opt_qadam_loop{k_steps}_{numel}", us, f"{numel}el_per_step")

        chunk = make_chunked_update(opt, donate=True)
        us = _time_chain(lambda p, s: chunk(p, s, {"w": gstack}),
                         jax.tree.map(jnp.copy, params), state0, k_steps)
        emit(f"opt_qadam_scan{k_steps}_{numel}", us, f"{numel}el_per_step")


def bench_serve(emit, requests=8, slots=4, prompt_len=16, max_new=32,
                rounds=3):
    """ServeSession decode throughput (tok/s): fp32-resident vs
    code-resident (k_x=6, packed) through the fused dequant-matmul, and
    the same codes through the unfused dequantize-then-matmul path. The
    three sessions are timed in interleaved rounds (medians per tag) so
    machine noise hits every variant equally - the qx6/fp32 ratio is a
    GATED compare.py floor (>= 1.0: residency must also be a speed win),
    not just a report. Smoke-scale on CPU: the numbers track the serving
    hot path (one fused jit step per token, no per-token host sync), not
    TPU perf."""
    import jax
    from repro.configs import get_config
    from repro.models.model import Model
    from repro.serve import (Request, ServeSession, params_nbytes,
                             quantize_params)

    cfg = get_config("yi-6b", smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qparams = quantize_params(params, k_x=6, min_numel=2 ** 10, pack=True)
    rng = np.random.default_rng(0)

    sessions = {
        "fp32": ServeSession(model, params, slots=slots, max_seq=128, seed=0),
        "qx6": ServeSession(model, qparams, slots=slots, max_seq=128, seed=0),
        "qx6_nofuse": ServeSession(model, qparams, slots=slots, max_seq=128,
                                   seed=0, fused_matmul=False),
    }
    # compile warmup: same prompt length as the timed requests, so the
    # per-length prefill executable is cached before the clock starts
    for sess in sessions.values():
        sess.submit(Request(prompt=list(range(1, prompt_len + 1)),
                            max_new_tokens=4))
        sess.drain()

    def one_round(sess):
        reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size,
                                                 size=prompt_len)),
                        max_new_tokens=max_new) for _ in range(requests)]
        t0 = time.perf_counter()
        hs = [sess.submit(r) for r in reqs]
        res = sess.drain()
        dt = time.perf_counter() - t0
        return dt, sum(len(res[h].tokens) for h in hs)

    times = {tag: [] for tag in sessions}
    toks = 0
    for _ in range(rounds):
        for tag, sess in sessions.items():
            dt, toks = one_round(sess)
            times[tag].append(dt)
    us = {tag: float(np.median(ts)) / toks * 1e6
          for tag, ts in times.items()}

    def tok_s(tag):
        return 1e6 / us[tag]

    emit("serve_session_fp32", us["fp32"],
         f"{tok_s('fp32'):.1f}tok_s_{requests}req_{slots}slots")
    # the headline: packed code-resident serving at least as fast as fp32
    emit("serve_session_qx6", us["qx6"],
         f"{tok_s('qx6'):.1f}tok_s_{us['fp32'] / us['qx6']:.2f}x_vs_fp32",
         us["fp32"] / us["qx6"])
    emit("serve_session_qx6_nofuse", us["qx6_nofuse"],
         f"{tok_s('qx6_nofuse'):.1f}tok_s_"
         f"{us['fp32'] / us['qx6_nofuse']:.2f}x_vs_fp32",
         us["fp32"] / us["qx6_nofuse"])
    emit("serve_fused_speedup_qx6", 0.0,
         f"{us['qx6_nofuse'] / us['qx6']:.2f}x_vs_unfused",
         us["qx6_nofuse"] / us["qx6"])
    emit("serve_resident_ratio", 0.0,
         f"{params_nbytes(qparams) / params_nbytes(params):.3f}x_fp32_measured",
         params_nbytes(qparams) / params_nbytes(params))


def bench_fleet(emit, n_requests=36, seed=0):
    """The paged-cache headline: an arrival-process-driven request fleet
    served by a fixed-lane session and a paged session holding EXACTLY
    the same cache bytes (fixed: 4 slots x 96 tokens; paged: the same
    384 tokens as 24 x 16-token pages fanned over 12 slots). Mixed
    prompt lengths and SLO classes arrive on a deterministic Poisson
    process (seeded numpy, identical schedule for both sessions); the
    driver submits on schedule and steps the session, exactly like a
    serving loop. Gated compare.py floors: paged tokens/s >= fixed
    (``serve_paged_toks``), paged peak concurrency >= 2x fixed
    (``serve_paged_concurrency``), and paged p99 TTFT within 1.5x of
    fixed (``serve_ttft_p99``). Smoke-scale on CPU."""
    import jax
    from repro.configs import get_config
    from repro.models.model import Model
    from repro.serve import Request, ServeSession, cache_nbytes

    cfg = get_config("yi-6b", smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(seed)
    slos = ["interactive", "standard", "batch"]
    sched = []
    step_at = 0
    for i in range(n_requests):
        step_at += int(rng.poisson(0.8))
        sched.append((step_at,
                      [int(t) for t in rng.integers(1, cfg.vocab_size,
                                                    size=rng.integers(4, 25))],
                      int(rng.integers(6, 13)), slos[i % 3]))

    max_seq, ps = 96, 16
    def make(paged):
        if paged:
            return ServeSession(model, params, slots=12, max_seq=max_seq,
                                seed=seed, paged=True, page_size=ps,
                                num_pages=24, prefill_chunk=8)
        return ServeSession(model, params, slots=4, max_seq=max_seq,
                            seed=seed, prefill_chunk=8)

    def run(paged):
        sess = make(paged)
        # compile warmup: a long prompt exercises both chunk shapes
        # (mid + final), the decode step, and the release path; the jits
        # live on the session instance, so warm the instance we time
        sess.submit(Request(prompt=list(range(1, 21)), max_new_tokens=3))
        sess.drain()
        sess.stats["max_inflight"] = 0
        sess.ttft_s.clear()
        sess._steps = 0
        t0 = time.perf_counter()
        it = iter(sched)
        nxt = next(it, None)
        submitted = []
        while nxt is not None:
            while nxt is not None and nxt[0] <= sess._steps:
                _, prompt, max_new, slo = nxt
                submitted.append(sess.submit(Request(
                    prompt=prompt, max_new_tokens=max_new, slo=slo)))
                nxt = next(it, None)
            if nxt is None:
                break
            if sess.inflight or sess.queued:
                sess.step()
            else:
                sess._steps += 1       # idle tick waiting for an arrival
        res = sess.drain()             # finish everything in flight
        dt = time.perf_counter() - t0
        toks = sum(len(res[h].tokens) for h in submitted)
        ttfts = sorted(sess.ttft_s.values())
        p99 = ttfts[min(len(ttfts) - 1, int(len(ttfts) * 0.99))]
        return dict(dt=dt, toks=toks, tok_s=toks / dt, p99=p99,
                    peak=sess.stats["max_inflight"],
                    bytes=cache_nbytes(sess._state["cache"]))

    fx = run(paged=False)
    pg = run(paged=True)
    # pool bytes must match the fixed lanes (the page tables are the only
    # extra, a few hundred int32s)
    mem_ratio = pg["bytes"] / fx["bytes"]

    emit("serve_fleet_fixed", 1e6 / fx["tok_s"],
         f"{fx['tok_s']:.1f}tok_s_peak{fx['peak']}_p99ttft"
         f"{fx['p99'] * 1e3:.0f}ms")
    emit("serve_paged_toks", 1e6 / pg["tok_s"],
         f"{pg['tok_s']:.1f}tok_s_{pg['tok_s'] / fx['tok_s']:.2f}x_vs_fixed_"
         f"mem{mem_ratio:.3f}x", pg["tok_s"] / fx["tok_s"])
    emit("serve_paged_concurrency", 0.0,
         f"peak{pg['peak']}_vs_{fx['peak']}_at_equal_cache_mem",
         pg["peak"] / max(fx["peak"], 1))
    emit("serve_ttft_p99", pg["p99"] * 1e6,
         f"{pg['p99'] * 1e3:.0f}ms_vs_{fx['p99'] * 1e3:.0f}ms_fixed",
         fx["p99"] / max(pg["p99"], 1e-9))


def bench_train(emit, steps=24, chunk=8):
    """TrainSession steps/s vs the legacy blocking per-step loop (which
    pulled+converted a batch and forced a `float(loss)` host sync every
    step), plus the session's measured host-sync count. Smoke-scale on
    CPU: tracks the hot-loop host overhead the session removes, not TPU
    step time."""
    import jax
    from repro.configs import get_config
    from repro.models.model import Model
    from repro.launch.mesh import make_local_mesh
    from repro.dist.step import make_train_step, TrainConfig
    from repro.train.session import SessionConfig, TrainSession
    from repro.data.pipeline import batch_for_model

    cfg = get_config("yi-6b", smoke=True)
    model = Model(cfg)
    mesh = make_local_mesh(data=1, model=1)
    tc = TrainConfig(alpha=3e-3, grad_k=6, weight_k=None, worker_axes=())
    art = make_train_step(model, mesh, tc)

    def batches():
        return batch_for_model(cfg, 64, 4, seed=0)

    # legacy loop: per-step dispatch, sync batch conversion, per-step
    # float() host sync
    step = jax.jit(art.step_fn, donate_argnums=(0,))
    state = art.init_state(jax.random.PRNGKey(0))
    it = batches()
    state, m = step(state, next(it))   # compile
    float(m["loss"])
    t0 = time.perf_counter()
    syncs = 0
    for _ in range(steps):
        state, m = step(state, next(it))
        _ = float(m["loss"])           # the old loop's per-step sync
        syncs += 1
    dt = time.perf_counter() - t0
    emit("train_loop_blocking", dt / steps * 1e6,
         f"{steps / dt:.1f}steps_s_{syncs}syncs")

    # session: prefetch thread + device loss ring, per-step dispatch
    sess = TrainSession.from_artifacts(
        art, batches(), SessionConfig(log_every=0, prefetch=2),
        log=lambda *_: None)
    sess.run(2)                        # compile + prime the prefetcher
    t0 = time.perf_counter()
    sess.run(steps)
    dt = time.perf_counter() - t0
    emit("train_session_step1", dt / steps * 1e6,
         f"{steps / dt:.1f}steps_s_{sess.stats['syncs']}syncs")
    sess.close()

    # session: scan-chunked (K steps per dispatch) on top of prefetch
    sess = TrainSession.from_artifacts(
        art, batches(), SessionConfig(log_every=0, prefetch=2,
                                      scan_chunk=chunk),
        log=lambda *_: None)
    sess.run(chunk)                    # compile
    t0 = time.perf_counter()
    sess.run(steps)
    dt = time.perf_counter() - t0
    emit(f"train_session_scan{chunk}", dt / steps * 1e6,
         f"{steps / dt:.1f}steps_s_{sess.stats['syncs']}syncs")
    sess.close()


def bench_startup(emit, steps=2):
    """Cold vs warm startup through repro.perf: a fresh persistent XLA
    cache + AOT step-artifact dir, then a TrainSession and a
    ServeSession built TWICE against them. Cold pays trace + lower +
    compile (+ export); warm deserializes the compiled step. Rows are
    setup-through-first-work wall time; the speedup rows are the
    machine-independent signal.

    Set BENCH_ASSERT_STARTUP=1 (the CI startup-smoke gate) to hard-fail
    unless warm < cold and the warm sessions report zero compilations.
    """
    import shutil
    import tempfile

    import jax
    from repro import perf
    from repro.configs import get_config
    from repro.models.model import Model
    from repro.launch.mesh import make_local_mesh
    from repro.dist.step import make_train_step, TrainConfig
    from repro.train.session import SessionConfig, TrainSession
    from repro.data.pipeline import batch_for_model
    from repro.serve import Request, ServeSession

    cfg = get_config("yi-6b", smoke=True)
    model = Model(cfg)
    mesh = make_local_mesh(data=1, model=1)
    tc = TrainConfig(alpha=3e-3, grad_k=6, weight_k=None, worker_axes=())
    art = make_train_step(model, mesh, tc)
    params = model.init(jax.random.PRNGKey(0))

    tmp = tempfile.mkdtemp(prefix="bench_startup_")
    cache_dir = os.path.join(tmp, "xla")
    prev_cache = jax.config.jax_compilation_cache_dir
    perf.enable_persistent_cache(cache_dir)
    try:
        def train_once():
            t0 = time.perf_counter()
            sess = TrainSession.from_artifacts(
                art, batch_for_model(cfg, 64, 4, seed=0),
                SessionConfig(log_every=0, prefetch=0,
                              aot_dir=os.path.join(tmp, "aot_train")),
                log=lambda *_: None)
            sess.run(steps)
            dt = time.perf_counter() - t0
            stats = dict(sess.stats)
            sess.close()
            return dt, stats

        cold, st_c = train_once()
        warm, st_w = train_once()
        emit("startup_train_cold", cold * 1e6,
             f"{st_c['compilations']}compiles_{steps}steps")
        emit("startup_train_warm", warm * 1e6,
             f"{st_w['aot_loads']}aot_loads_{steps}steps")
        emit("startup_train_speedup", 0.0, f"{cold / warm:.2f}x_warm",
             cold / warm)

        def serve_once():
            t0 = time.perf_counter()
            sess = ServeSession(model, params, slots=2, max_seq=64, seed=0,
                                aot_dir=os.path.join(tmp, "aot_serve"))
            sess.submit(Request(prompt=[1, 2, 3], max_new_tokens=4))
            sess.drain()
            return time.perf_counter() - t0, dict(sess.stats)

        s_cold, sst_c = serve_once()
        s_warm, sst_w = serve_once()
        emit("startup_serve_cold", s_cold * 1e6,
             f"{sst_c['compilations']}compiles")
        emit("startup_serve_warm", s_warm * 1e6,
             f"{sst_w['aot_loads']}aot_loads")
        emit("startup_serve_speedup", 0.0, f"{s_cold / s_warm:.2f}x_warm",
             s_cold / s_warm)
        emit("startup_compile_cache_entries", 0.0,
             f"{perf.cache_entries(cache_dir)}entries")

        if os.environ.get("BENCH_ASSERT_STARTUP"):
            assert warm < cold, (
                f"warm TrainSession no faster: {warm:.2f}s vs {cold:.2f}s")
            assert st_w["compilations"] == 0 and st_w["aot_loads"] >= 1, (
                f"warm TrainSession recompiled: {st_w}")
            assert s_warm < s_cold, (
                f"warm ServeSession no faster: {s_warm:.2f}s vs {s_cold:.2f}s")
            assert sst_w["compilations"] == 0 and sst_w["aot_loads"] >= 1, (
                f"warm ServeSession recompiled: {sst_w}")
    finally:
        # the bench repointed the process-global cache config; restore
        if prev_cache:
            perf.enable_persistent_cache(prev_cache)
        else:
            perf.disable_persistent_cache()
        shutil.rmtree(tmp, ignore_errors=True)


def bench_comm_codec(emit, numel=1 << 20, steps=6):
    """The fused codec stack vs the legacy three-pass path it replaced.

    * encode: one fused program (amax + quantize + bit-pack; single
      kernel launch on TPU, one XLA program on CPU) vs three separately
      dispatched passes with the code tensor materialized in between -
      at a 4MB (1M-element f32) buffer, the paper's bucket size.
    * decode: fused unpack+dequant vs two passes.
    * end-to-end: dist train step (qadam vs efadam two-way) at 4MB
      exchange buckets, smoke scale - tracks dispatch/fusion overhead of
      the wire path, not TPU perf.

    Set BENCH_ASSERT_FUSED=1 to hard-fail if fused is slower than
    legacy (the CI kernels-bench gate).
    """
    import jax
    import jax.numpy as jnp
    from repro import comm
    from repro.comm import bits as cbits
    from repro.opt import grids

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=numel, scale=0.2).astype(np.float32))
    gbytes = numel * 4 / 1e9
    checks = []

    for spec in ("log:6", "uniform:7:wire"):
        cd = comm.get_codec(spec)
        tag = spec.replace(":", "_")

        fused_enc = jax.jit(
            lambda v, cd=cd: cd._encode_impl(v, key=None, backend="jnp"))
        us_f = _time_call(lambda v: fused_enc(v).payload, x)
        emit(f"comm_encode_fused_{tag}", us_f,
             f"{gbytes / (us_f / 1e6):.2f}GB_s_4MB")

        # the pre-codec wire: amax pass, quantize pass, pack pass - each
        # its own dispatch, codes materialized between them
        amax_fn = jax.jit(grids.amax_scale)
        if spec.startswith("log"):
            quant_fn = jax.jit(lambda v, s: grids.log_quantize(v, s, 6))
        else:
            quant_fn = jax.jit(lambda v, s: jnp.clip(
                grids.uniform_quantize(v, s, 7), -127, 127))
        pack_fn = jax.jit(lambda c, b=cd.bits: cbits.pack_flat(c, b))

        # default-arg binding: the gate times these AFTER the loop, and
        # late-bound closures would make every check run the last spec
        def legacy_enc(v, a=amax_fn, q=quant_fn, p=pack_fn,
                       is_log=spec.startswith("log")):
            s = a(v) if is_log else jnp.float32(0.5)
            c = q(v, s)
            return p(c)

        us_l = _time_call(legacy_enc, x)
        emit(f"comm_encode_legacy3_{tag}", us_l,
             f"{gbytes / (us_l / 1e6):.2f}GB_s_4MB")
        emit(f"comm_encode_speedup_{tag}", 0.0, f"{us_l / us_f:.2f}x",
             us_l / us_f)
        checks.append(("encode", spec,
                       lambda v, f=fused_enc: f(v).payload, legacy_enc, x))

        wb = fused_enc(x)
        fused_dec = jax.jit(
            lambda w, cd=cd: cd._decode_impl(w, backend="jnp"))
        us_fd = _time_call(fused_dec, wb)
        emit(f"comm_decode_fused_{tag}", us_fd,
             f"{gbytes / (us_fd / 1e6):.2f}GB_s_4MB")

        unpack_fn = jax.jit(
            lambda p, b=cd.bits: cbits.unpack_flat(p, b, numel))
        if spec.startswith("log"):
            deq_fn = jax.jit(lambda c, s: grids.log_dequantize(c, s, 6))
        else:
            deq_fn = jax.jit(lambda c, s: grids.uniform_dequantize(c, s, 7))
        legacy_dec = lambda w, u=unpack_fn, d=deq_fn: d(u(w.payload),
                                                       w.scale)
        us_ld = _time_call(legacy_dec, wb)
        emit(f"comm_decode_legacy2_{tag}", us_ld,
             f"{gbytes / (us_ld / 1e6):.2f}GB_s_4MB")
        emit(f"comm_decode_speedup_{tag}", 0.0, f"{us_ld / us_fd:.2f}x",
             us_ld / us_fd)
        checks.append(("decode", spec, fused_dec, legacy_dec, wb))

    if os.environ.get("BENCH_ASSERT_FUSED"):
        # The gate guards against STRUCTURAL regressions of the fused
        # path - e.g. the XLA loop-fusion bug where the packer's strided
        # reads re-ran the transcendental quantize per lane group (2x
        # wall time; fixed with an optimization_barrier in the codec).
        # Budgets are per (direction, grid), not a blanket grace: the
        # PR-5 log-DECODE regression (0.23x: per-element exp2 on
        # unpacked codes) sat comfortably under the old uniform 1.5x
        # check because only the encode direction was asserted tightly.
        # Since the SMEM dequant LUT, fused log decode does zero
        # transcendentals while legacy still pays exp2 per element, so
        # its budget is 1.0 - fused must win outright. Encode and the
        # uniform paths keep 1.5x: on CPU those compare dispatch/fusion
        # overhead, and XLA's fused-loop codegen jitters the
        # transcendental-bound paths by up to ~1.3x either way.
        budgets = {("encode", "log"): 1.5, ("decode", "log"): 1.0,
                   ("encode", "uniform"): 1.5, ("decode", "uniform"): 1.5}
        for kind, spec, f_fn, l_fn, arg in checks:
            grid = "log" if spec.startswith("log") else "uniform"
            budget = budgets[(kind, grid)]
            fs, ls = [], []
            for _ in range(7):
                fs.append(_time_call(f_fn, arg, reps=3, warmup=1))
                ls.append(_time_call(l_fn, arg, reps=3, warmup=1))
            med_f = sorted(fs)[len(fs) // 2]
            med_l = sorted(ls)[len(ls) // 2]
            assert med_f <= med_l * budget, (
                f"fused {kind} over budget ({budget}x) vs legacy for "
                f"{spec}: median {med_f:.1f}us vs {med_l:.1f}us")

    # end-to-end dist step at 4MB exchange buckets, qadam vs efadam
    from repro.configs import get_config
    from repro.models.model import Model
    from repro.dist.step import make_train_step, TrainConfig
    from repro.data.pipeline import batch_for_model

    cfg = get_config("yi-6b", smoke=True)
    model = Model(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    batch = next(batch_for_model(cfg, 64, 4, seed=0))
    for mode in ("qadam", "efadam"):
        tc = TrainConfig(grad_k=6, weight_k=7, mode=mode,
                         exchange_bucket_bytes=4 << 20,
                         worker_axes=("data",))
        art = make_train_step(model, mesh, tc)
        state = art.init_state(jax.random.PRNGKey(0))
        step = jax.jit(art.step_fn, donate_argnums=(0,))
        state, _ = step(state, batch)          # compile
        t0 = time.perf_counter()
        for _ in range(steps):
            state, m = step(state, batch)
        jax.block_until_ready(m["loss"])
        us = (time.perf_counter() - t0) / steps * 1e6
        emit(f"comm_dist_step_{mode}_4MB", us, "smoke_1dev")


def bench_comm_cost(emit):
    """Wire bytes for ResNet-101-sized (162.9MB fp32) and VGG16-sized
    (512.3MB) models at the paper's quantization levels - reproduces the
    Comm/Size columns of Tables 2-3 analytically through our codec."""
    from repro.core.packing import packed_nbytes

    for model_name, fp32_mb in (("resnet101", 162.9), ("vgg16", 512.3)):
        n = fp32_mb * 1e6 / 4
        for bits, tag in ((32, "fp32"), (4, "log_k6_4bit"),
                          (3, "3bit"), (2, "2bit"), (1, "sign")):
            mb = packed_nbytes(int(n), bits) / 1e6
            emit(f"comm_{model_name}_{tag}", 0.0, f"{mb:.2f}MB_per_iter")
        for k_x, tag in ((7, "8bit"), (6, "7bit"), (3, "4bit")):
            mb = packed_nbytes(int(n), k_x + 1) / 1e6
            emit(f"size_{model_name}_kx{k_x}", 0.0, f"{mb:.2f}MB_model")


def _table_protocol(emit, table, seeds, steps):
    import jax
    sys.path.insert(0, os.path.join(ROOT, "examples"))
    import paper_repro as pr
    from repro.core.qadam import (QAdamConfig, qadam, terngrad_sgd, ef_sgdm,
                                  wquan)
    from repro.data.pipeline import ClsDataConfig, classification_dataset

    data = classification_dataset(
        ClsDataConfig(seed=1 if table == 2 else 2))
    xte, yte = data[2], data[3]
    methods = {
        "qadam_fp32": (lambda: qadam(QAdamConfig(alpha=2e-3, grad_q=None)),
                       None),
        "qadam_3bit": (lambda: qadam(QAdamConfig(alpha=2e-3,
                                                 grad_q="log:2")), None),
        "qadam_2bit": (lambda: qadam(QAdamConfig(alpha=2e-3,
                                                 grad_q="log:1")), None),
        "qadam_3bit_qx5": (lambda: qadam(QAdamConfig(
            alpha=2e-3, grad_q="log:2", weight_q="uniform_amax:5")), None),
        "terngrad": (lambda: terngrad_sgd(alpha=2e-2), None),
        "blockwise_ef": (lambda: ef_sgdm(alpha=2e-3, beta=0.9,
                                         grad_q="blockwise:256"), None),
        "wquan_post_k5": (lambda: qadam(QAdamConfig(alpha=2e-3,
                                                    grad_q=None)), 5),
    }
    for name, (builder, wq_after) in methods.items():
        accs = []
        t0 = time.perf_counter()
        for s in range(seeds):
            p = pr.run(builder(), steps, data, jax.random.PRNGKey(s + table),
                       seed=s * 100 + table, n_workers=4)
            if wq_after is not None:
                p = wquan(p, k_x=wq_after, absolute=False)
            accs.append(pr.accuracy(p, xte, yte))
        us = (time.perf_counter() - t0) * 1e6 / max(1, seeds)
        emit(f"table{table}_{name}", us,
             f"acc={np.mean(accs) * 100:.2f}pm{np.std(accs) * 100:.2f}")


def bench_table2(emit):
    _table_protocol(emit, 2, seeds=2, steps=150)


def bench_table3(emit):
    _table_protocol(emit, 3, seeds=2, steps=150)


def bench_fig34(emit, steps=120):
    """Figures 3-4: convergence curves (train loss every 20 steps)."""
    import jax
    sys.path.insert(0, os.path.join(ROOT, "examples"))
    import paper_repro as pr
    from repro.core.qadam import QAdamConfig, qadam, apply_updates
    from repro.data.pipeline import (ClsDataConfig, classification_dataset,
                                     classification_batches)

    data = classification_dataset(ClsDataConfig(seed=3))
    xtr, ytr = data[0], data[1]
    for name, gq, ef in (("fp32", None, True), ("log2bit_ef", "log:1", True),
                         ("log2bit_noef", "log:1", False)):
        opt = qadam(QAdamConfig(alpha=2e-3, grad_q=gq, error_feedback=ef))
        params = pr.mlp_init(jax.random.PRNGKey(0), xtr.shape[1], 256,
                             int(ytr.max()) + 1)
        state = opt.init(params)
        gfun = jax.jit(jax.value_and_grad(pr.loss_fn))
        it = classification_batches(xtr, ytr, 128, seed=0)
        curve = []
        for t in range(steps):
            x, y = next(it)
            fp = opt.forward_params(params, state)
            loss, g = gfun(fp, x, y)
            upd, state = opt.update(g, state, params)
            params = apply_updates(params, upd)
            if t % 20 == 0:
                curve.append(round(float(loss), 4))
        emit(f"fig34_{name}", 0.0, "curve=" + "|".join(map(str, curve)))


def bench_adapt(emit, steps=250, seeds=2, workers=4, replan_every=25,
                budget=0.6):
    """Runtime-adaptive bit allocation (repro.adapt) vs the paper's
    fixed k_g=6 wire on the multi-worker protocol: measured payload
    bytes/step and final test loss for each arm. The two ratio rows are
    GATED compare.py floors: the adaptive wire must come in at or under
    ``budget``x the fixed bytes (adapt_bytes_reduction >= 1/budget)
    while holding final loss within 1% (adapt_loss_parity >= 0.99)."""
    import jax
    sys.path.insert(0, os.path.join(ROOT, "examples"))
    import paper_repro as pr
    from repro.data.pipeline import ClsDataConfig, classification_dataset

    data = classification_dataset(ClsDataConfig(seed=1))
    arms = {}
    for name, adaptive in (("fixed_kg6", False), ("adaptive", True)):
        losses, bps = [], []
        t0 = time.perf_counter()
        for s in range(seeds):
            _, info = pr.run_quantized(
                steps, data, jax.random.PRNGKey(s), seed=s * 100,
                n_workers=workers, adaptive=adaptive, budget_ratio=budget,
                replan_every=replan_every)
            losses.append(info["final_test_loss"])
            bps.append(info["bytes_per_step"])
        us = (time.perf_counter() - t0) * 1e6 / max(1, seeds)
        arms[name] = (float(np.mean(losses)), float(np.mean(bps)))
        emit(f"adapt_{name}", us,
             f"loss={arms[name][0]:.4f}_{arms[name][1] / 1e3:.1f}KB_step")
    (fl, fb), (al, ab) = arms["fixed_kg6"], arms["adaptive"]
    emit("adapt_bytes_reduction", 0.0,
         f"{fb / ab:.3f}x_fewer_bytes_budget{budget}", fb / ab)
    emit("adapt_loss_parity", 0.0,
         f"fixed{fl:.4f}_vs_adaptive{al:.4f}", fl / al)


def bench_dist(emit, steps=6, warmup=2):
    """Flat vs hierarchical parameter-server topology on a simulated
    2-node x 4-device mesh (needs
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``). Two rows
    are GATED compare.py floors: ``dist_hier_inter_bytes`` (the 2x4
    hierarchy must ship <= 0.27x flat's inter-node wire bytes; the
    registry accounting says exactly 1/devices_per_node = 0.25x) and
    ``dist_bucket_tuned`` (the bucket the exchange tuner picks must not
    lose to the config default - the incumbent joins the sweep, so
    >= 1.0 by construction)."""
    import jax
    if jax.device_count() < 8:
        emit("dist_skipped", 0.0,
             f"needs_8_devices_have_{jax.device_count()}")
        return
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.dist import topology as T
    from repro.dist.step import make_train_step, TrainConfig
    from repro.models.model import Model
    from repro.perf.autotune import tune_exchange_buckets
    from repro.train.loop import comm_bytes_per_step

    model = Model(get_config("yi-6b", smoke=True))
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:8]).reshape(2, 4, 1),
        ("pod", "data", "model"))
    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(0, model.cfg.vocab_size,
                                   size=(8, 32)).astype(np.int32))
    batch = {"tokens": tok, "targets": tok}

    cfgs, times = {}, {}
    for name, topo in (("flat", T.FlatTopology()),
                       ("hier", T.HierarchicalTopology(2, 4))):
        tc = TrainConfig(worker_axes=("pod", "data"), topology=topo)
        art = make_train_step(model, mesh, tc)
        state = art.init_state(jax.random.PRNGKey(0))
        step = jax.jit(art.step_fn, donate_argnums=(0,))
        for _ in range(warmup):
            state, metrics = step(state, batch)
        jax.block_until_ready(metrics["loss"])
        t0 = time.perf_counter()
        for _ in range(steps):
            state, metrics = step(state, batch)
        jax.block_until_ready(metrics["loss"])
        times[name] = (time.perf_counter() - t0) / steps * 1e6
        cfgs[name] = (art, tc)
        del state

    fb = comm_bytes_per_step(*cfgs["flat"])["tiers"]["inter"]["total"]
    hb = comm_bytes_per_step(*cfgs["hier"])["tiers"]["inter"]["total"]
    emit("dist_step_flat_2x4", times["flat"], "smoke_8dev")
    emit("dist_step_hier_2x4", times["hier"],
         f"{times['flat'] / times['hier']:.2f}x_vs_flat")
    emit("dist_hier_inter_bytes", 0.0,
         f"hier{hb}B_vs_flat{fb}B_per_step", fb / hb)
    rep = tune_exchange_buckets(model, mesh, cfgs["hier"][1], batch,
                                candidates=(0, 1 << 20), steps=3,
                                warmup=1)
    emit("dist_bucket_tuned", rep["timings_s"][rep["best"]] * 1e6,
         f"bucket{rep['best']}B_{rep['speedup']:.2f}x_vs_default",
         rep["speedup"])


def bench_roofline(emit):
    path = os.path.join(ROOT, "results", "dryrun_single.jsonl")
    if not os.path.exists(path):
        emit("roofline_missing", 0.0, "run repro.launch.dryrun first")
        return
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if r.get("skipped") or r.get("error"):
                continue
            t = r["roofline"]
            ur = r.get("useful_flops_ratio")
            emit(f"roofline_{r['arch']}_{r['shape']}", 0.0,
                 f"c={t['compute_s']:.4f}s;m={t['memory_s']:.4f}s;"
                 f"x={t['collective_s']:.4f}s;bound={r['bottleneck']};"
                 f"useful={round(ur, 3) if ur else 'na'}")


BENCHES = {
    "kernels": bench_kernels,
    "comm_codec": bench_comm_codec,
    "comm_cost": bench_comm_cost,
    "serve": bench_serve,
    "fleet": bench_fleet,
    "train": bench_train,
    "startup": bench_startup,
    "table2_cifar100_analogue": bench_table2,
    "table3_cifar10_analogue": bench_table3,
    "fig34_convergence": bench_fig34,
    "adapt": bench_adapt,
    "dist": bench_dist,
    "roofline": bench_roofline,
}

# named suites: coarse groups for CI jobs / snapshot baselines
SUITES = {
    "serve": ["serve"],
    "fleet": ["fleet"],
    "train": ["train"],
    "comm": ["comm_codec", "comm_cost"],
    "kernels": ["kernels", "comm_codec", "comm_cost"],
    "startup": ["startup"],
    "adapt": ["adapt"],
    "dist": ["dist"],
    "paper": ["table2_cifar100_analogue", "table3_cifar10_analogue",
              "fig34_convergence", "comm_cost"],
    "all": list(BENCHES),
}


# suites dominated by host allocation (session scheduling, request
# bookkeeping, numpy batch staging) where glibc malloc contention shows
# up as run-to-run noise; tcmalloc flattens it (SNIPPETS 1/2 preload the
# same library for exactly these loops)
HOST_ALLOC_HEAVY = {"serve", "fleet", "train", "startup"}


def _check_tcmalloc(names) -> None:
    if not HOST_ALLOC_HEAVY & set(names):
        return
    if "tcmalloc" in os.environ.get("LD_PRELOAD", ""):
        return
    import glob
    hits = sorted(glob.glob("/usr/lib/*/libtcmalloc*.so*")
                  + glob.glob("/usr/lib/libtcmalloc*.so*"))
    if not hits:
        return                     # not installed: nothing to suggest
    print(f"# warning: host-alloc-heavy bench without tcmalloc; numbers "
          f"may carry malloc noise. Re-run with\n"
          f"#   LD_PRELOAD={hits[0]} "
          f"TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=60000000000",
          file=sys.stderr, flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma list of benches")
    ap.add_argument("--suite", default=None, choices=sorted(SUITES),
                    help="named bench group (overrides --only)")
    ap.add_argument("--trace", action="store_true",
                    help="wrap the run in jax.profiler.trace with one "
                         "TraceAnnotation per bench")
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="trace output dir (default results/traces)")
    args, _ = ap.parse_known_args()
    if args.suite:
        names = SUITES[args.suite]
    elif args.only:
        names = args.only.split(",")
    else:
        names = list(BENCHES)
    _check_tcmalloc(names)

    print("name,us_per_call,derived,ratio")

    def emit(name, us, derived, ratio=None):
        cell = "" if ratio is None else f"{ratio:.4f}"
        print(f"{name},{us:.1f},{derived},{cell}", flush=True)

    from repro.perf import profiling
    with profiling.trace(args.trace_dir, enabled=args.trace) as tdir:
        for n in names:
            with profiling.annotate(f"bench:{n}"):
                BENCHES[n](emit)
    if tdir:
        print(f"# trace: {tdir}", file=sys.stderr)


if __name__ == "__main__":
    main()
