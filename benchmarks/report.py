"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from
results/dryrun_*.jsonl.

  PYTHONPATH=src python -m benchmarks.report > /tmp/tables.md

Or render a committed benchmark snapshot (``recollect.py --bench``
output) as a markdown table, ratio column included:

  PYTHONPATH=src python -m benchmarks.report --bench BENCH_pr6.json
"""
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def load(path):
    rows = []
    if not os.path.exists(path):
        return rows
    with open(path) as f:
        for line in f:
            rows.append(json.loads(line))
    # keep the LAST result per (arch, shape, multi_pod)
    seen = {}
    for r in rows:
        seen[(r["arch"], r["shape"], r.get("multi_pod", False))] = r
    return list(seen.values())


def dryrun_table(rows):
    print("| arch | shape | mesh | status | HLO FLOPs/dev | HLO bytes/dev |"
          " coll bytes/dev | temp mem/dev | compile |")
    print("|---|---|---|---|---|---|---|---|---|")
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    for r in sorted(rows, key=lambda r: (r["arch"],
                                         order.get(r["shape"], 9))):
        mesh = r.get("mesh", "2x16x16" if r.get("multi_pod") else "16x16")
        if r.get("error"):
            print(f"| {r['arch']} | {r['shape']} | {mesh} | FAIL |"
                  f" - | - | - | - | - |")
        elif r.get("skipped"):
            print(f"| {r['arch']} | {r['shape']} | {mesh} | skip"
                  f" (full-attn) | - | - | - | - | - |")
        else:
            print(f"| {r['arch']} | {r['shape']} | {mesh} | ok |"
                  f" {r['hlo_flops']:.3g} | {_fmt_bytes(r['hlo_bytes'])} |"
                  f" {_fmt_bytes(r['collective_bytes'])} |"
                  f" {_fmt_bytes(r['memory']['temp_bytes'])} |"
                  f" {r['compile_s']}s |")


def roofline_table(rows):
    print("| arch | shape | compute s | memory s | collective s |"
          " bottleneck | MODEL_FLOPS/dev | useful ratio | note |")
    print("|---|---|---|---|---|---|---|---|---|")
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    for r in sorted(rows, key=lambda r: (r["arch"],
                                         order.get(r["shape"], 9))):
        if r.get("error") or r.get("skipped"):
            continue
        t = r["roofline"]
        ur = r.get("useful_flops_ratio")
        note = _note(r)
        print(f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4f} |"
              f" {t['memory_s']:.4f} | {t['collective_s']:.4f} |"
              f" {r['bottleneck']} | {r['model_flops']:.3g} |"
              f" {ur and round(ur, 3)} | {note} |")


def _note(r):
    """One sentence: what would move the dominant term down."""
    b = r["bottleneck"]
    kind = r.get("kind")
    arch = r.get("arch", "")
    if b == "collective":
        if kind == "decode":
            return ("per-step int8 weight gather dominates single-token "
                    "decode: keep dequantized weights resident across steps")
        if "mamba" in arch or "hymba" in arch:
            return "SSD state exchange: ppermute ladder + bf16 wire (§Perf)"
        return "int8 model-axis FSDP gather + 4-bit packed a2a (§Perf)"
    if b == "memory":
        if kind == "decode":
            return ("KV-cache + weight streaming bound (expected for "
                    "batch-limited decode); raise batch to amortize")
        if r.get("useful_flops_ratio") and r["useful_flops_ratio"] < 0.4:
            return ("low useful-FLOPs ratio: dispatch/remat waste - "
                    "sort-based MoE dispatch (§Perf), selective checkpoint")
        return ("op-level byte accounting (upper bound incl. fusion-"
                "eliminable traffic): selective checkpoint, fused EF pass")
    return "compute-bound: raise per-device batch or reduce remat"


def bench_table(path):
    with open(path) as f:
        snap = json.load(f)
    print(f"## Bench snapshot `{os.path.basename(path)}` "
          f"({snap.get('backend')}/{snap.get('device')}, "
          f"jax {snap.get('jax')})\n")
    print("| name | us/call | ratio | derived |")
    print("|---|---|---|---|")
    for r in snap["rows"]:
        ratio = r.get("ratio")
        print(f"| {r['name']} | {r['us_per_call']:.1f} |"
              f" {ratio if ratio is not None else '-'} | {r['derived']} |")


def main():
    if "--bench" in sys.argv:
        bench_table(sys.argv[sys.argv.index("--bench") + 1])
        return
    single = load(os.path.join(ROOT, "results", "dryrun_single.jsonl"))
    multi = load(os.path.join(ROOT, "results", "dryrun_multi.jsonl"))
    print("## Dry-run (single-pod 16x16)\n")
    dryrun_table(single)
    if multi:
        print("\n## Dry-run (multi-pod 2x16x16)\n")
        dryrun_table(multi)
    print("\n## Roofline (single-pod, per device, v5e model:"
          " 197 TF/s bf16, 819 GB/s HBM, 50 GB/s/link)\n")
    roofline_table(single)


if __name__ == "__main__":
    main()
