"""Building-block layers, written to be context-parallel ("cp") native.

Every function takes a `ShardCtx`. With `ctx.cp_axis=None` the code is purely
local (single-device smoke tests). Under `shard_map` with `cp_axis='model'`,
the sequence dimension is sharded and the layers use explicit collectives:

  * attention      - all_gather of K/V over the cp axis (GQA keeps it small)
  * decode attn    - KV cache sharded along sequence; flash-style partial
                     softmax per shard + logsumexp combine via tiny psum
  * SSD (mamba2)   - chunk-local work + linear cross-device state correction
  * causal conv1d  - halo exchange of d_conv-1 tokens via ppermute
  * MoE            - experts sharded over the cp axis; token routing via
                     all_to_all (the cp token partition IS the EP dispatch
                     partition)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig, MoEConfig, SSMConfig


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """How the current trace is sharded (static)."""
    cp_axis: Optional[str] = None    # mesh axis for sequence/expert sharding
    cp_size: int = 1
    dp_axes: tuple = ()              # data-parallel axes (loss reduction)
    # FSDP hook: callable(subtree, kind) with kind in
    # ("static", "blocks", "enc_blocks"); gathers weight shards over cp_axis
    # (plain bf16 or int8 Q_x - see repro.dist.collectives). None = identity.
    param_gather: Optional[object] = None

    @property
    def sharded(self) -> bool:
        return self.cp_axis is not None and self.cp_size > 1

    def cp_index(self):
        if not self.sharded:
            return 0
        return jax.lax.axis_index(self.cp_axis)

    def gather(self, subtree, kind: str):
        if self.param_gather is None:
            return subtree
        return self.param_gather(subtree, kind)


# ---------------------------------------------------------------------------
# Projections
# ---------------------------------------------------------------------------

def code_resident(w) -> bool:
    """True for code-resident quantized weights (duck-typed on
    ``dequantize()`` so the model layers never import the serve stack;
    see ``repro.serve.quantized.QuantizedLeaf``)."""
    return hasattr(w, "dequantize")


def pmatmul(x, w):
    """Weight projection ``x @ w`` in x's dtype - the model's single
    contraction choke point. ``w`` is a float array, or a code-resident
    ``QuantizedLeaf`` whose ``__rmatmul__`` dispatches to the fused
    dequant-matmul (``repro.comm.matmul``) so the fp32 weight tensor is
    never materialized; both paths are bitwise identical."""
    return x @ w.astype(x.dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def layernorm(x, w, b, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def apply_norm(x, p, cfg: ModelConfig):
    if cfg.norm == "layernorm":
        return layernorm(x, p["w"], p["b"], cfg.norm_eps)
    return rmsnorm(x, p["w"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x, positions, theta):
    """x: (..., S, H, hd); positions: (S,) int32 global positions, or (B, S)
    per-row positions (continuous-batching decode, where every slot sits at
    its own depth). theta may be a traced per-layer scalar (gemma3 mixes
    10k/1M bases)."""
    hd = x.shape[-1]
    half = hd // 2
    theta = jnp.asarray(theta, jnp.float32)
    positions = jnp.asarray(positions)
    inv_freq = jnp.exp(-jnp.log(theta) * 2.0
                       * jnp.arange(half, dtype=jnp.float32) / hd)
    ang = positions.astype(jnp.float32)[..., None] * inv_freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    if positions.ndim == 1:
        cos, sin = cos[None], sin[None]                        # (1, S, 1, half)
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., :half], xf[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(S, d, offset=0):
    # offset may be traced (decode position), scalar or (B,) per-slot
    off = jnp.asarray(offset, jnp.float32)
    pos = off[..., None] + jnp.arange(S, dtype=jnp.float32)   # (..., S)
    i = jnp.arange(d // 2, dtype=jnp.float32)
    ang = pos[..., None] / jnp.power(10000.0, 2 * i / d)      # (..., S, d/2)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Attention (training / prefill): local queries vs gathered K/V
# ---------------------------------------------------------------------------

def _softcap(s, cap):
    if cap is None:
        return s
    return cap * jnp.tanh(s / cap)


def attention(q, k, v, *, q_pos, causal=True, window=0, softcap=None,
              meta_tokens=0, ctx: ShardCtx = ShardCtx(), kv_pos0_full=0):
    """q: (B,Sq,H,hd) local; k,v: (B,Skv,K,hd) local (sequence-sharded iff ctx).

    q_pos: (Sq,) global positions of the local queries.
    window=0 -> full attention; window>0 -> sliding window of that size.
    """
    B, Sq, H, hd = q.shape
    if ctx.sharded:
        k = jax.lax.all_gather(k, ctx.cp_axis, axis=1, tiled=True)
        v = jax.lax.all_gather(v, ctx.cp_axis, axis=1, tiled=True)
    Skv = k.shape[1]
    K = k.shape[2]
    rep = H // K
    qr = q.reshape(B, Sq, K, rep, hd)
    scores = jnp.einsum("bqkrd,bskd->bkrqs", qr, k,
                        preferred_element_type=jnp.float32)
    scores = scores / np.sqrt(hd)
    scores = _softcap(scores, softcap)
    kv_pos = kv_pos0_full + jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= q_pos[:, None] >= kv_pos[None, :]
    # `window` may be a traced per-layer flag (0 = full attention)
    win = jnp.where(jnp.asarray(window) > 0, jnp.asarray(window, jnp.int32),
                    jnp.int32(2 ** 30))
    wmask = kv_pos[None, :] > q_pos[:, None] - win
    if meta_tokens:
        wmask |= kv_pos[None, :] < meta_tokens
    mask &= wmask
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkrqs,bskd->bqkrd", probs, v)
    return out.reshape(B, Sq, H, hd)


def decode_attention(q, k_cache, v_cache, *, total_len, window=0,
                     softcap=None, q_pos, ctx: ShardCtx = ShardCtx(),
                     meta_kv=None, kv_positions=None, extra_valid=None):
    """Single-token decode against a sequence-sharded KV cache.

    q: (B,1,H,hd); k_cache/v_cache: (B,S_loc,K,hd) covering global positions
    [cp_index*S_loc, ...). total_len: #valid cache entries, scalar or (B,)
    per-slot (continuous batching: each slot has its own depth);
    q_pos: global position of the query token, scalar or (B,).

    Computes flash-style partial softmax per shard and combines across the
    cp axis with (logsumexp, weighted-sum) psums - bytes moved per step are
    O(B*H*hd), independent of sequence length.

    meta_kv: optional (mk, mv) learned prefix of shape (B,M,K,hd); always
    visible. Under cp it is counted on shard 0 only (so the logsumexp
    combine sees it exactly once).

    kv_positions: optional (S_loc,) global positions of the cache columns,
    overriding the contiguous-shard default (paged views carry global
    positions even when the pool - not the sequence - is what's sharded).
    extra_valid: optional (B,S_loc) mask ANDed into validity; the paged
    path uses it for page ownership, so each cp shard counts each page
    exactly once in the logsumexp combine.
    """
    B, _, H, hd = q.shape
    S_loc, K = k_cache.shape[1], k_cache.shape[2]
    rep = H // K
    if kv_positions is None:
        pos0 = ctx.cp_index() * S_loc
        kv_pos = pos0 + jnp.arange(S_loc)
    else:
        kv_pos = kv_positions
    tl = jnp.broadcast_to(jnp.asarray(total_len), (B,))
    qp = jnp.broadcast_to(jnp.asarray(q_pos), (B,))
    valid = kv_pos[None, :] < tl[:, None]                 # (B, S_loc)
    win = jnp.where(jnp.asarray(window) > 0, jnp.asarray(window, jnp.int32),
                    jnp.int32(2 ** 30))
    valid &= kv_pos[None, :] > qp[:, None] - win
    if extra_valid is not None:
        valid &= extra_valid
    if meta_kv is not None:
        mk, mv = meta_kv
        M = mk.shape[1]
        k_cache = jnp.concatenate([mk.astype(k_cache.dtype), k_cache], axis=1)
        v_cache = jnp.concatenate([mv.astype(v_cache.dtype), v_cache], axis=1)
        meta_valid = jnp.broadcast_to(ctx.cp_index() == 0, (B, M))
        valid = jnp.concatenate([meta_valid, valid], axis=1)
        S_loc += M
    qr = q.reshape(B, K, rep, hd)
    scores = jnp.einsum("bkrd,bskd->bkrs", qr, k_cache,
                        preferred_element_type=jnp.float32) / np.sqrt(hd)
    scores = _softcap(scores, softcap)
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    l_loc = jnp.max(scores, axis=-1)                      # (B,K,rep)
    l_safe = jnp.where(jnp.isfinite(l_loc), l_loc, -1e30)
    p = jnp.exp(scores - l_safe[..., None])
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    denom = jnp.sum(p, axis=-1)                           # (B,K,rep)
    o_un = jnp.einsum("bkrs,bskd->bkrd", p, v_cache.astype(jnp.float32))
    if ctx.sharded:
        l_max = jax.lax.pmax(l_safe, ctx.cp_axis)
        w = jnp.exp(l_safe - l_max)
        o = jax.lax.psum(o_un * w[..., None], ctx.cp_axis)
        z = jax.lax.psum(denom * w, ctx.cp_axis)
    else:
        o, z = o_un, denom
    out = o / jnp.maximum(z[..., None], 1e-30)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def chunk_attention(q, k_cache, v_cache, *, q_pos, window=0, softcap=None,
                    meta_kv=None, kv_positions=None, extra_valid=None,
                    ctx: ShardCtx = ShardCtx()):
    """Chunked-prefill attention: Sq in-flight prompt tokens per slot
    attend to that slot's cache view (which already contains the chunk's
    own K/V - the model writes before attending, exactly like decode).

    q: (B,Sq,H,hd); k_cache/v_cache: (B,S,K,hd) cache view.
    q_pos: (B,Sq) global positions of the chunk tokens; causality within
    the chunk rides on these (kv_pos <= q_pos_i matches decode's
    kv_pos < total_len with total_len = pos+1). Padding queries past the
    chunk's valid prefix produce garbage outputs the caller discards.

    Local-path only: chunked admission is a per-slot (B=1) host-scheduled
    operation; mesh sessions admit by token injection instead.
    """
    if ctx.sharded:
        raise NotImplementedError("chunk_attention is local-only; mesh "
                                  "sessions admit via token injection")
    B, Sq, H, hd = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    rep = H // K
    kv_pos = jnp.arange(S) if kv_positions is None else kv_positions
    qp = jnp.asarray(q_pos)                                   # (B,Sq)
    valid = kv_pos[None, None, :] <= qp[:, :, None]           # (B,Sq,S)
    win = jnp.where(jnp.asarray(window) > 0, jnp.asarray(window, jnp.int32),
                    jnp.int32(2 ** 30))
    valid &= kv_pos[None, None, :] > qp[:, :, None] - win
    if extra_valid is not None:
        valid &= extra_valid[:, None, :]
    if meta_kv is not None:
        mk, mv = meta_kv
        M = mk.shape[1]
        k_cache = jnp.concatenate([mk.astype(k_cache.dtype), k_cache], axis=1)
        v_cache = jnp.concatenate([mv.astype(v_cache.dtype), v_cache], axis=1)
        valid = jnp.concatenate(
            [jnp.ones((B, Sq, M), bool), valid], axis=2)
        S += M
    qr = q.reshape(B, Sq, K, rep, hd)
    scores = jnp.einsum("bqkrd,bskd->bkrqs", qr, k_cache,
                        preferred_element_type=jnp.float32) / np.sqrt(hd)
    scores = _softcap(scores, softcap)
    mask = valid[:, None, None]                               # (B,1,1,Sq,S)
    scores = jnp.where(mask, scores, -jnp.inf)
    l_loc = jnp.max(scores, axis=-1)
    l_safe = jnp.where(jnp.isfinite(l_loc), l_loc, -1e30)
    p = jnp.exp(scores - l_safe[..., None])
    p = jnp.where(mask, p, 0.0)
    denom = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkrqs,bskd->bqkrd", p, v_cache.astype(jnp.float32))
    out = o / jnp.maximum(jnp.moveaxis(denom, -1, 1)[..., None], 1e-30)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp(params, x, act="silu"):
    if act == "gelu":  # whisper: non-gated
        h = jax.nn.gelu(pmatmul(x, params["w_up"]), approximate=True)
        return pmatmul(h, params["w_down"])
    h = (jax.nn.silu(pmatmul(x, params["w_gate"]))
         * pmatmul(x, params["w_up"]))
    return pmatmul(h, params["w_down"])


# ---------------------------------------------------------------------------
# Mixture of Experts (shared + routed, einsum dispatch, optional EP a2a)
# ---------------------------------------------------------------------------

def moe(params, x, mcfg: MoEConfig, ctx: ShardCtx = ShardCtx()):
    """x: (B,S,d). Experts in params are per-device shards (E_loc,...) when
    ctx.sharded else the full set (E,...). Returns (y, aux_loss)."""
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    E = mcfg.n_experts
    n_dev = ctx.cp_size if ctx.sharded else 1
    E_loc = E // n_dev

    logits = pmatmul(xt, params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, mcfg.top_k)    # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style)
    me = jnp.mean(probs, axis=0)                              # (E,)
    ce = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], E), axis=0)
    aux = jnp.sum(me * ce) * E * mcfg.router_aux_weight

    C = max(1, int(np.ceil(T * mcfg.top_k / E * mcfg.capacity_factor)))
    if mcfg.dispatch == "sort":
        xe, sort_aux = _moe_dispatch_sort(xt, gate_idx, gate_vals, E, C)
    else:
        # classic Switch one-hot dispatch: builds (T,k,E,C) intermediates
        onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)     # (T,k,E)
        flatoh = onehot.reshape(T * mcfg.top_k, E)
        pos = jnp.cumsum(flatoh, axis=0) * flatoh - 1             # (T*k, E)
        pos = pos.reshape(T, mcfg.top_k, E)
        in_cap = (pos >= 0) & (pos < C)
        disp = (jax.nn.one_hot(pos, C, dtype=x.dtype)
                * in_cap[..., None].astype(x.dtype)
                * onehot[..., None].astype(x.dtype))              # (T,k,E,C)
        comb = jnp.sum(disp * gate_vals.astype(x.dtype)[:, :, None, None],
                       axis=1)                                    # (T,E,C)
        xe = jnp.einsum("td,tkec->ecd", xt, disp)                 # (E,C,d)
    if ctx.sharded:
        # send expert-chunks to their owners; receive every device's tokens
        # for the local experts: (E, C, d) -> (E_loc, n_dev*C, d)
        xe = jax.lax.all_to_all(xe, ctx.cp_axis, split_axis=0, concat_axis=1,
                                tiled=True)
    h = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"].astype(xe.dtype))
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", xe,
                                    params["w_up"].astype(xe.dtype))
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(xe.dtype))
    if ctx.sharded:
        # (E_loc, n_dev*C, d) -> (E, C, d)
        ye = jax.lax.all_to_all(ye, ctx.cp_axis, split_axis=1, concat_axis=0,
                                tiled=True)
    if mcfg.dispatch == "sort":
        y = _moe_combine_sort(ye, sort_aux, T, xt.dtype)
    else:
        y = jnp.einsum("ecd,tec->td", ye, comb)

    if mcfg.n_shared:
        y = y + mlp(params["shared"], xt)
    return y.reshape(B, S, d), aux


def _moe_dispatch_sort(xt, gate_idx, gate_vals, E, C):
    """argsort/scatter dispatch: no (T,E,C) one-hot tensors.

    Drop order matches the einsum path exactly: stable sort by expert keeps
    token order, so capacity evicts the same late tokens.
    """
    T, k = gate_idx.shape
    d = xt.shape[1]
    flat_e = gate_idx.reshape(-1)                      # (T*k,)
    tok = jnp.arange(T * k, dtype=jnp.int32) // k      # owning token
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    st = tok[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(T * k, dtype=jnp.int32) - starts[se]
    keep = rank < C
    dest = se * C + jnp.minimum(rank, C - 1)
    gv = gate_vals.reshape(-1)[order]
    contrib = jnp.where(keep[:, None], xt[st], jnp.zeros((1, d), xt.dtype))
    xbuf = jnp.zeros((E * C, d), xt.dtype).at[dest].add(contrib)
    return xbuf.reshape(E, C, d), (st, dest, keep, gv)


def _moe_combine_sort(ye, aux, T, dtype):
    st, dest, keep, gv = aux
    d = ye.shape[-1]
    w = (gv * keep.astype(gv.dtype)).astype(dtype)
    vals = ye.reshape(-1, d)[dest] * w[:, None]
    return jnp.zeros((T, d), dtype).at[st].add(vals)


# ---------------------------------------------------------------------------
# Mamba-2 SSD (state-space duality), chunked, cp-aware
# ---------------------------------------------------------------------------

def _segsum(a):
    """a: (..., l). Returns (..., l, l) lower-tri segment sums:
    out[..., i, j] = sum_{k=j+1..i} a[...,k] for i>=j, -inf above diag."""
    l = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    ss = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, ss, -jnp.inf)


def ssd_chunked(xdt, a_bar, Bm, Cm, *, chunk, ctx: ShardCtx = ShardCtx(),
                initial_state=None, cp_exchange: str = "gather",
                cp_wire_dtype=jnp.float32):
    """Chunked SSD scan.

    xdt:  (B, S, H, P)   inputs pre-multiplied by dt
    a_bar:(B, S, H)      log-decay per token (dt * A, negative)
    Bm,Cm:(B, S, G, N)   input/output projections (G groups broadcast to H)
    Returns y (B,S,H,P) and final_state (B,H,P,N).

    Under cp the sequence is device-sharded; the inter-chunk recurrence is
    linear in the initial state, so each device runs its local scan from
    zero and adds `initial_state * decay` correction terms computed from an
    all_gather of per-device (total_decay, final_state) summaries.
    """
    B, S, H, P = xdt.shape
    G, N = Bm.shape[2], Bm.shape[3]
    reph = H // G
    nc = S // chunk
    f32 = jnp.float32

    xc = xdt.reshape(B, nc, chunk, H, P).astype(f32)
    ac = a_bar.reshape(B, nc, chunk, H).astype(f32)
    Bc = Bm.reshape(B, nc, chunk, G, N).astype(f32)
    Cc = Cm.reshape(B, nc, chunk, G, N).astype(f32)
    Bh = jnp.repeat(Bc, reph, axis=3)  # (B,nc,l,H,N)
    Ch = jnp.repeat(Cc, reph, axis=3)

    acum = jnp.cumsum(ac, axis=2)                       # (B,nc,l,H)
    # intra-chunk (diagonal) term
    Lmat = jnp.exp(_segsum(jnp.swapaxes(ac, 2, 3)))     # (B,nc,H,l,l)
    Y_diag = jnp.einsum("bclhn,bcshn,bchls,bcshp->bclhp", Ch, Bh, Lmat, xc)

    # per-chunk output states
    decay_states = jnp.exp(acum[:, :, -1:, :] - acum)   # (B,nc,l,H)
    states = jnp.einsum("bclhn,bclh,bclhp->bchpn", Bh, decay_states, xc)
    chunk_decay = jnp.exp(acum[:, :, -1, :])            # (B,nc,H)

    # inter-chunk recurrence: prefix (exclusive) states
    def comb(e1, e2):
        d1, s1 = e1
        d2, s2 = e2
        return d1 * d2, s1 * d2[..., None, None] + s2

    dfx, sfx = jax.lax.associative_scan(comb, (chunk_decay, states), axis=1)
    # exclusive prefix: shift right by one chunk
    prev = jnp.concatenate(
        [jnp.zeros_like(sfx[:, :1]), sfx[:, :-1]], axis=1)  # (B,nc,H,P,N)
    local_total_decay = dfx[:, -1]                          # (B,H)
    local_final = sfx[:, -1]                                # (B,H,P,N)

    if ctx.sharded:
        ndev = ctx.cp_size
        idx = jax.lax.axis_index(ctx.cp_axis)
        if cp_exchange == "ladder":
            # Hillis-Steele prefix scan over the cp axis via ppermute:
            # (log2(n)+1) hops x state bytes instead of n x (all_gather).
            # The wire optionally carries bf16 (re-rounded per hop).
            wd = jnp.dtype(cp_wire_dtype)
            acc_d, acc_s = local_total_decay, local_final
            hop = 1
            while hop < ndev:
                perm = [(i, i + hop) for i in range(ndev - hop)]
                rd = jax.lax.ppermute(acc_d.astype(wd), ctx.cp_axis,
                                      perm).astype(acc_d.dtype)
                rs = jax.lax.ppermute(acc_s.astype(wd), ctx.cp_axis,
                                      perm).astype(acc_s.dtype)
                take = idx >= hop
                # incoming segment precedes ours: (d_in, s_in) o (d, s)
                acc_s = jnp.where(take, rs * acc_d[..., None, None] + acc_s,
                                  acc_s)
                acc_d = jnp.where(take, rd * acc_d, acc_d)
                hop *= 2
            shift = [(i, i + 1) for i in range(ndev - 1)]
            inc_state = jax.lax.ppermute(acc_s.astype(wd), ctx.cp_axis,
                                         shift).astype(acc_s.dtype)
            inc_decay = jnp.where(
                idx == 0, jnp.ones_like(acc_d),
                jax.lax.ppermute(acc_d.astype(wd), ctx.cp_axis,
                                 shift).astype(acc_d.dtype))
            # nameable for remat policy "ssd_state": saving these skips the
            # whole ladder replay in the backward pass
            from jax.ad_checkpoint import checkpoint_name
            inc_state = checkpoint_name(inc_state, "ssd_prefix_state")
            inc_decay = checkpoint_name(inc_decay, "ssd_prefix_state")
        else:
            # reference: all_gather every device's (decay, state) summary
            gd = jax.lax.all_gather(local_total_decay, ctx.cp_axis)
            gs = jax.lax.all_gather(local_final, ctx.cp_axis)

            def dev_comb(c, i):
                d_acc, s_acc = c
                take = i < idx
                d_i = jnp.where(take, gd[i], jnp.ones_like(gd[i]))
                s_i = jnp.where(take, gs[i], jnp.zeros_like(gs[i]))
                return (d_acc * d_i, s_acc * d_i[..., None, None] + s_i), None

            (inc_decay, inc_state), _ = jax.lax.scan(
                dev_comb, (jnp.ones_like(local_total_decay),
                           jnp.zeros_like(local_final)),
                jnp.arange(ndev))
        init = inc_state if initial_state is None \
            else inc_state + initial_state * inc_decay[..., None, None]
    else:
        init = initial_state

    if init is not None:
        # correction: chunk c sees extra state init * prod(decay of chunks<c)
        excl_decay = jnp.concatenate(
            [jnp.ones_like(dfx[:, :1]), dfx[:, :-1]], axis=1)  # (B,nc,H)
        prev = prev + init[:, None] * excl_decay[..., None, None]
        local_final = local_final + init * local_total_decay[..., None, None]

    decay_out = jnp.exp(acum)                               # (B,nc,l,H)
    Y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp", Ch, prev, decay_out)
    y = (Y_diag + Y_off).reshape(B, S, H, P)
    return y.astype(xdt.dtype), local_final


def causal_conv1d(x, w, *, ctx: ShardCtx = ShardCtx(), prev_tail=None):
    """Depthwise causal conv. x: (B,S,C), w: (d_conv, C).

    Under cp, the left halo (d_conv-1 tokens) comes from the previous device
    via ppermute; device 0 gets zeros (or `prev_tail` from a decode cache).
    """
    B, S, C = x.shape
    dconv = w.shape[0]
    halo = dconv - 1
    if prev_tail is None:
        tail = jnp.zeros((B, halo, C), x.dtype)
    else:
        tail = prev_tail
    if ctx.sharded:
        src_tail = x[:, -halo:, :]
        perm = [(i, i + 1) for i in range(ctx.cp_size - 1)]
        recv = jax.lax.ppermute(src_tail, ctx.cp_axis, perm)
        idx = jax.lax.axis_index(ctx.cp_axis)
        tail = jnp.where(idx > 0, recv, tail)
    xp = jnp.concatenate([tail, x], axis=1)  # (B, S+halo, C)
    # depthwise conv as stacked shifts (d_conv is tiny, typically 4)
    y = jnp.zeros((B, S, C), jnp.float32)
    for i in range(dconv):
        y = y + xp[:, i:i + S, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return y.astype(x.dtype)


def mamba2_mix(params, x, scfg: SSMConfig, d_model: int,
               ctx: ShardCtx = ShardCtx(), decode_cache=None):
    """Full mamba2 mixer. x: (B,S,d_model).

    decode_cache: None for train/prefill (returns (y, final_state, conv_tail))
    or dict(conv=(B,halo,conv_dim), ssm=(B,H,P,N)) for single-token decode.
    """
    B, S, d = x.shape
    di = scfg.expand * d_model
    G, N, Pd = scfg.n_groups, scfg.d_state, scfg.head_dim
    H = di // Pd
    conv_dim = di + 2 * G * N

    zxbcdt = pmatmul(x, params["in_proj"])
    z, xbc, dt = jnp.split(zxbcdt, [di, di + conv_dim], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,S,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))              # (H,)

    if decode_cache is None:
        xbc_c = causal_conv1d(xbc, params["conv_w"], ctx=ctx)
        new_conv_tail = xbc[:, -(scfg.d_conv - 1):, :]
    else:
        xbc_c = causal_conv1d(xbc, params["conv_w"],
                              prev_tail=decode_cache["conv"])
        new_conv_tail = jnp.concatenate(
            [decode_cache["conv"], xbc], axis=1)[:, -(scfg.d_conv - 1):, :]
    xbc_c = jax.nn.silu(xbc_c)
    xs, Bm, Cm = jnp.split(xbc_c, [di, di + G * N], axis=-1)
    xs = xs.reshape(B, S, H, Pd)
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)

    a_bar = dt * A[None, None, :]               # (B,S,H) log decay
    xdt = xs * dt[..., None].astype(xs.dtype)

    if decode_cache is None:
        y, final_state = ssd_chunked(
            xdt, a_bar, Bm, Cm, chunk=scfg.chunk, ctx=ctx,
            cp_exchange=scfg.cp_exchange,
            cp_wire_dtype=jnp.bfloat16
            if scfg.cp_wire_dtype == "bfloat16" else jnp.float32)
        new_ssm = final_state
    elif S == 1:
        # single-token recurrence
        h = decode_cache["ssm"]                  # (B,H,P,N)
        dA = jnp.exp(a_bar[:, 0])                # (B,H)
        Bh = jnp.repeat(Bm[:, 0], H // G, axis=1)   # (B,H,N)
        Ch = jnp.repeat(Cm[:, 0], H // G, axis=1)
        h = h * dA[..., None, None] + jnp.einsum(
            "bhp,bhn->bhpn", xdt[:, 0].astype(jnp.float32),
            Bh.astype(jnp.float32))
        y = jnp.einsum("bhpn,bhn->bhp", h, Ch.astype(jnp.float32))
        y = y[:, None].astype(x.dtype)           # (B,1,H,P)
        new_ssm = h
    else:
        # chunked prefill: advance the cached state by a whole chunk of
        # prompt tokens through the same chunked scan as training, seeded
        # with the decode state (requires S % scfg.chunk == 0 - sessions
        # gate chunked admission on that)
        y, new_ssm = ssd_chunked(
            xdt, a_bar, Bm, Cm, chunk=scfg.chunk,
            initial_state=decode_cache["ssm"])

    y = y + xs * params["D"].astype(xs.dtype)[None, None, :, None]
    y = y.reshape(B, S, di)
    y = rmsnorm(y * jax.nn.silu(z), params["norm_w"])
    out = pmatmul(y, params["out_proj"])
    return out, {"ssm": new_ssm, "conv": new_conv_tail}
