"""Architecture configuration schema covering the 10 assigned families.

One frozen dataclass drives every model: dense GQA (llama/yi/qwen),
gemma2/gemma3 (local:global patterns, softcaps, qk-norm), MoE
(deepseek-moe, llama4), SSM (mamba2/SSD), hybrid (hymba), enc-dec
(whisper), and VLM backbones (llava-next: embeddings-in).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int              # routed experts
    top_k: int
    n_shared: int = 0           # always-on shared experts
    d_ff_expert: int = 0        # per-expert hidden size
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # token->expert dispatch: "einsum" (one-hot (T,E,C) tensors, the
    # classic Switch formulation) or "sort" (argsort + scatter, no
    # T x E x C intermediates - see EXPERIMENTS.md §Perf)
    dispatch: str = "einsum"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    n_groups: int = 1
    chunk: int = 128
    # d_inner = expand * d_model; n_heads_ssm = d_inner // head_dim
    # cross-device chunk-state exchange under context parallelism:
    # "gather" (all_gather of every device's (decay, state) summary) or
    # "ladder" (Hillis-Steele prefix scan via ppermute: (log2(n)+1)/n of
    # the gather bytes - see EXPERIMENTS.md §Perf)
    cp_exchange: str = "gather"
    # wire dtype for the cross-device state exchange ("float32"/"bfloat16")
    cp_wire_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str              # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads

    # attention features
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None     # gemma2: 50.0
    final_softcap: Optional[float] = None    # gemma2: 30.0
    qk_norm: bool = False                    # gemma3
    rope_theta: float = 10_000.0
    rope_theta_local: Optional[float] = None  # gemma3: local layers 10k, global 1M
    window: Optional[int] = None             # sliding window for "local" layers
    # per-layer attention pattern: string of 'g' (global) / 'l' (local),
    # tiled to n_layers. None -> all global.
    pattern: Optional[str] = None
    post_norm: bool = False                  # gemma2/3 post-sublayer norms

    # sub-modules
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    meta_tokens: int = 0                     # hymba learnable prefix

    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0                     # audio frames after conv stub
    # how inputs arrive: tokens | embeddings (vlm) | audio+tokens (whisper)
    input_mode: str = "tokens"

    tie_embeddings: bool = True
    emb_scale: bool = False                  # gemma: embed * sqrt(d)
    act: str = "silu"                        # "gelu": whisper (non-gated)
    norm: str = "rmsnorm"                    # "layernorm": whisper
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # full-unroll the layer scans (dry-run cost-analysis calibration only:
    # XLA cost analysis counts a while body once, unrolled HLO counts all)
    scan_unroll: bool = False
    # layer remat policy: "full" (recompute everything), "dots" (save
    # matmul outputs - trades HBM for recompute FLOPs), "ssd_state" (save
    # the cross-device SSD prefix states - skips the ladder replay in bwd)
    remat_policy: str = "full"

    # ---- derived ----
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: no layer does full-attention over the
        whole sequence, or attention-free."""
        if self.arch_type == "ssm":
            return True
        if self.pattern is not None and self.window is not None:
            # global layers still attend fully; eligibility requires their
            # KV to be shardable (it is, over the model axis) AND few of
            # them. We follow the brief: SWA archs are eligible.
            return True
        return False

    def layer_windows(self) -> Tuple[int, ...]:
        """Per-layer window size; 0 means full/global attention."""
        if self.pattern is None or self.window is None:
            return tuple(0 for _ in range(self.n_layers))
        pat = (self.pattern * self.n_layers)[: self.n_layers]
        return tuple(self.window if c == "l" else 0 for c in pat)

    def layer_rope_thetas(self) -> Tuple[float, ...]:
        if self.rope_theta_local is None:
            return tuple(self.rope_theta for _ in range(self.n_layers))
        pat = ((self.pattern or "g") * self.n_layers)[: self.n_layers]
        return tuple(self.rope_theta_local if c == "l" else self.rope_theta
                     for c in pat)

    @property
    def d_inner(self) -> int:
        assert self.ssm is not None
        return self.ssm.expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        assert self.ssm is not None
        return self.d_inner // self.ssm.head_dim

    def n_params(self) -> int:
        """Analytic parameter count (for 6ND roofline math)."""
        d, f, V, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        hd = self.head_dim_
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        total = V * d  # embeddings (tied head)
        if not self.tie_embeddings:
            total += V * d
        if self.arch_type == "ssm":
            s = self.ssm
            di = self.d_inner
            conv_dim = di + 2 * s.n_groups * s.d_state
            per = (d * (2 * di + 2 * s.n_groups * s.d_state + self.n_ssm_heads)
                   + s.d_conv * conv_dim + di * d + di + 3 * self.n_ssm_heads)
            return total + L * per
        mlp = 3 * d * f if self.act != "gelu" else 2 * d * f
        per = attn + d * 2  # norms
        if self.moe is not None:
            fe = self.moe.d_ff_expert or f
            per += d * self.moe.n_experts
            per += 3 * d * fe * (self.moe.n_experts + self.moe.n_shared)
        else:
            per += mlp
        if self.arch_type == "hybrid":
            s = self.ssm
            di = self.d_inner
            conv_dim = di + 2 * s.n_groups * s.d_state
            per += (d * (2 * di + 2 * s.n_groups * s.d_state + self.n_ssm_heads)
                    + s.d_conv * conv_dim + di * d + di + 3 * self.n_ssm_heads)
        total += L * per
        if self.arch_type == "encdec":
            enc_per = attn + mlp + d * 2
            cross = attn
            total += self.encoder_layers * enc_per + L * cross
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        fe = self.moe.d_ff_expert or self.d_ff
        all_experts = 3 * d * fe * (self.moe.n_experts + self.moe.n_shared)
        active = 3 * d * fe * (self.moe.top_k + self.moe.n_shared)
        return self.n_params() - L * (all_experts - active)
