"""Model zoo: one scan-over-layers implementation per architecture family.

Families (arch_type):
  dense   - llama-style GQA stacks: yi-6b, qwen2.5-14b (QKV bias),
            gemma2-2b (alt local/global + softcaps + post-norms),
            gemma3-4b (5:1 local:global + qk-norm + dual rope bases)
  moe     - deepseek-moe-16b (2 shared + 64 routed top-6),
            llama4-maverick (1 shared + 128 routed top-1)
  ssm     - mamba2 (SSD)
  hybrid  - hymba (parallel attn+SSM heads, SWA+3 global layers, meta tokens
            realized as learned per-layer KV prefix + learned SSM init state)
  encdec  - whisper (conv/mel frontend stubbed: audio arrives as frame
            embeddings per the brief)
  vlm     - llava-next (vision tower stubbed: inputs are patch+text
            embeddings; mistral-7b decoder)

Every block is homogeneous within a stack so `lax.scan` keeps the HLO small
(512-device dry-runs compile in seconds, remat stays per-layer). Per-layer
heterogeneity (local/global windows, rope bases) rides along as scanned
flag arrays, never as Python branching.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models import layers as L
from repro.models.layers import ShardCtx


def _norm_param(cfg, d, key=None):
    if cfg.norm == "layernorm":
        return {"w": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}
    return {"w": jnp.ones((d,), jnp.float32)}


def _dense(key, shape, std=0.02):
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32) * std)


# ---------------------------------------------------------------------------
# Per-family block parameter builders
# ---------------------------------------------------------------------------

def _attn_params(key, cfg: ModelConfig):
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    p = {
        "q": _dense(ks[0], (d, H * hd)),
        "k": _dense(ks[1], (d, K * hd)),
        "v": _dense(ks[2], (d, K * hd)),
        "o": _dense(ks[3], (H * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), jnp.float32)
        p["bk"] = jnp.zeros((K * hd,), jnp.float32)
        p["bv"] = jnp.zeros((K * hd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    if cfg.meta_tokens:
        p["meta_k"] = _dense(jax.random.fold_in(key, 7),
                             (cfg.meta_tokens, K, hd))
        p["meta_v"] = _dense(jax.random.fold_in(key, 8),
                             (cfg.meta_tokens, K, hd))
    return p


def _mlp_params(key, cfg: ModelConfig, d_in=None, d_ff=None):
    d = d_in or cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "gelu":
        return {"w_up": _dense(ks[0], (d, f)), "w_down": _dense(ks[1], (f, d))}
    return {"w_gate": _dense(ks[0], (d, f)), "w_up": _dense(ks[1], (d, f)),
            "w_down": _dense(ks[2], (f, d))}


def _moe_params(key, cfg: ModelConfig):
    m = cfg.moe
    d = cfg.d_model
    fe = m.d_ff_expert or cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense(ks[0], (d, m.n_experts)),
        "w_gate": _dense(ks[1], (m.n_experts, d, fe)),
        "w_up": _dense(ks[2], (m.n_experts, d, fe)),
        "w_down": _dense(ks[3], (m.n_experts, fe, d)),
    }
    if m.n_shared:
        p["shared"] = _mlp_params(ks[4], cfg, d_in=d, d_ff=m.n_shared * fe)
    return p


def _ssm_params(key, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    H = di // s.head_dim
    conv_dim = di + 2 * s.n_groups * s.d_state
    ks = jax.random.split(key, 4)
    dt = jnp.exp(jax.random.uniform(ks[2], (H,), jnp.float32)
                 * (np.log(0.1) - np.log(1e-3)) + np.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    p = {
        "in_proj": _dense(ks[0], (d, 2 * di + 2 * s.n_groups * s.d_state + H)),
        "conv_w": _dense(ks[1], (s.d_conv, conv_dim), std=0.2),
        "dt_bias": dt_bias,
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32) % 15 + 1.0),
        "D": jnp.ones((H,), jnp.float32),
        "norm_w": jnp.ones((di,), jnp.float32),
        "out_proj": _dense(ks[3], (di, d)),
    }
    if cfg.meta_tokens:
        p["init_state"] = jnp.zeros((H, s.head_dim, s.d_state), jnp.float32)
    return p


def _block_params(key, cfg: ModelConfig):
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    if cfg.arch_type == "ssm":
        return {"ln1": _norm_param(cfg, d), "ssm": _ssm_params(ks[0], cfg)}
    p = {"ln1": _norm_param(cfg, d), "attn": _attn_params(ks[0], cfg),
         "ln2": _norm_param(cfg, d)}
    if cfg.post_norm:
        p["ln1_post"] = _norm_param(cfg, d)
        p["ln2_post"] = _norm_param(cfg, d)
    if cfg.arch_type == "hybrid":
        p["ssm"] = _ssm_params(ks[1], cfg)
        p["attn_out_norm"] = _norm_param(cfg, d)
        p["ssm_out_norm"] = _norm_param(cfg, d)
    if cfg.moe is not None:
        p["moe"] = _moe_params(ks[2], cfg)
    else:
        p["mlp"] = _mlp_params(ks[3], cfg)
    return p


def _encdec_block_params(key, cfg: ModelConfig, cross: bool):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p = {"ln1": _norm_param(cfg, d), "attn": _attn_params(ks[0], cfg),
         "ln2": _norm_param(cfg, d), "mlp": _mlp_params(ks[1], cfg)}
    if cross:
        p["ln_x"] = _norm_param(cfg, d)
        p["xattn"] = _attn_params(ks[2], cfg)
    return p


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---------------- init ----------------
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        ks = jax.random.split(key, 6)
        params: Dict[str, Any] = {
            "embed": _dense(ks[0], (cfg.vocab_size, cfg.d_model)),
            "final_norm": _norm_param(cfg, cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = _dense(ks[4], (cfg.d_model, cfg.vocab_size))

        def stack(fn, key, n):
            keys = jax.random.split(key, n)
            return jax.vmap(fn)(keys)

        if cfg.arch_type == "encdec":
            params["enc_blocks"] = stack(
                lambda k: _encdec_block_params(k, cfg, cross=False),
                ks[1], cfg.encoder_layers)
            params["enc_norm"] = _norm_param(cfg, cfg.d_model)
            params["blocks"] = stack(
                lambda k: _encdec_block_params(k, cfg, cross=True),
                ks[2], cfg.n_layers)
        else:
            params["blocks"] = stack(lambda k: _block_params(k, cfg),
                                     ks[1], cfg.n_layers)
        return params

    # ---------------- flags ----------------
    def _flags(self):
        cfg = self.cfg
        return (jnp.asarray(cfg.layer_windows(), jnp.int32),
                jnp.asarray(cfg.layer_rope_thetas(), jnp.float32))

    # ---------------- sublayers ----------------
    def _attn_sublayer(self, p, h, *, q_pos, window, theta, ctx,
                       kv_override=None, causal=True, return_kv=False):
        cfg = self.cfg
        B, S, d = h.shape
        H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
        q = L.pmatmul(h, p["q"])
        if cfg.qkv_bias:
            q = q + p["bq"].astype(h.dtype)
        q = q.reshape(B, S, H, hd)
        if kv_override is None:
            kh = h
            k = L.pmatmul(kh, p["k"])
            v = L.pmatmul(kh, p["v"])
            if cfg.qkv_bias:
                k = k + p["bk"].astype(h.dtype)
                v = v + p["bv"].astype(h.dtype)
            k = k.reshape(B, S, K, hd)
            v = v.reshape(B, S, K, hd)
        else:
            k, v = kv_override
        if cfg.qk_norm:
            q = L.rmsnorm(q, p["q_norm"], cfg.norm_eps)
            k = L.rmsnorm(k, p["k_norm"], cfg.norm_eps)
        if cfg.arch_type != "encdec":  # whisper uses absolute positions
            q = L.rope(q, q_pos, theta)
            if kv_override is None:
                kv_pos = q_pos
                k = L.rope(k, kv_pos, theta)
        if cfg.meta_tokens and kv_override is None:
            mk = jnp.broadcast_to(p["meta_k"].astype(h.dtype),
                                  (B,) + p["meta_k"].shape)
            mv = jnp.broadcast_to(p["meta_v"].astype(h.dtype),
                                  (B,) + p["meta_v"].shape)
            # meta prefix participates only on device 0's gathered segment:
            # we emulate "always visible" by giving it positions < meta_tokens
            # and letting the window mask whitelist those columns.
            if ctx.sharded:
                k_full = jax.lax.all_gather(k, ctx.cp_axis, axis=1, tiled=True)
                v_full = jax.lax.all_gather(v, ctx.cp_axis, axis=1, tiled=True)
            else:
                k_full, v_full = k, v
            k_full = jnp.concatenate([mk, k_full], axis=1)
            v_full = jnp.concatenate([mv, v_full], axis=1)
            out = L.attention(
                q, k_full, v_full, q_pos=q_pos + cfg.meta_tokens,
                causal=causal, window=window,
                softcap=cfg.attn_softcap, meta_tokens=cfg.meta_tokens,
                ctx=ShardCtx())  # already gathered
            out = L.pmatmul(out.reshape(B, S, H * hd), p["o"])
            return (out, (k, v)) if return_kv else out
        out = L.attention(q, k, v, q_pos=q_pos, causal=causal,
                          window=window, softcap=cfg.attn_softcap,
                          meta_tokens=cfg.meta_tokens, ctx=ctx)
        out = L.pmatmul(out.reshape(B, S, H * hd), p["o"])
        return (out, (k, v)) if return_kv else out

    # ---------------- decoder-only forward ----------------
    def _embed_in(self, params, batch, ctx):
        cfg = self.cfg
        if cfg.input_mode == "embeddings":
            x = batch["embeds"].astype(_dt(cfg))
        elif L.code_resident(params["embed"]):
            # code-resident table: gather only the hit rows' codes
            x = params["embed"].astype(_dt(cfg)).take(batch["tokens"])
        else:
            x = params["embed"].astype(_dt(cfg))[batch["tokens"]]
        if cfg.emb_scale:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
        return x

    def forward(self, params, batch, ctx: ShardCtx = ShardCtx(),
                collect_cache: bool = False):
        """Training/prefill forward -> (logits (B,S_local,V), aux) or,
        with collect_cache=True, (logits, aux, per-layer cache pytree)."""
        cfg = self.cfg
        if cfg.arch_type == "encdec":
            assert not collect_cache, "use prefill() for enc-dec serving"
            return self._forward_encdec(params, batch, ctx)
        params = ctx.gather(params, "static")
        x = self._embed_in(params, batch, ctx)
        B, S, d = x.shape
        q_pos = ctx.cp_index() * S + jnp.arange(S)
        windows, thetas = self._flags()
        aux_total = jnp.zeros((), jnp.float32)

        def block(carry, scanned):
            x, aux = carry
            p, window, theta = scanned
            p = ctx.gather(p, "blocks")
            ys = {}
            h = L.apply_norm(x, p["ln1"], cfg)
            if cfg.arch_type == "ssm":
                out, st = L.mamba2_mix(p["ssm"], h, cfg.ssm, cfg.d_model,
                                       ctx=ctx)
                if collect_cache:
                    ys["ssm"], ys["conv"] = st["ssm"], st["conv"]
                x = x + out
                return (x, aux), ys
            attn_out, kv = self._attn_sublayer(p["attn"], h, q_pos=q_pos,
                                               window=window, theta=theta,
                                               ctx=ctx, return_kv=True)
            if collect_cache:
                ys["k"], ys["v"] = kv
            if cfg.arch_type == "hybrid":
                ssm_out, st = L.mamba2_mix(p["ssm"], h, cfg.ssm, cfg.d_model,
                                           ctx=ctx)
                if collect_cache:
                    ys["ssm"], ys["conv"] = st["ssm"], st["conv"]
                attn_out = 0.5 * (
                    L.apply_norm(attn_out, p["attn_out_norm"], cfg)
                    + L.apply_norm(ssm_out, p["ssm_out_norm"], cfg))
            if cfg.post_norm:
                attn_out = L.apply_norm(attn_out, p["ln1_post"], cfg)
            x = x + attn_out
            h2 = L.apply_norm(x, p["ln2"], cfg)
            if cfg.moe is not None:
                mlp_out, a = L.moe(p["moe"], h2, cfg.moe, ctx=ctx)
                aux = aux + a
            else:
                mlp_out = L.mlp(p["mlp"], h2, cfg.act)
            if cfg.post_norm:
                mlp_out = L.apply_norm(mlp_out, p["ln2_post"], cfg)
            x = x + mlp_out
            return (x, aux), ys

        blk = jax.checkpoint(block, policy=_remat_policy(cfg))
        (x, aux_total), caches = jax.lax.scan(
            blk, (x, aux_total), (params["blocks"], windows, thetas),
            unroll=cfg.scan_unroll)
        x = L.apply_norm(x, params["final_norm"], cfg)
        logits = self._head(params, x)
        if collect_cache:
            return logits, aux_total, caches
        return logits, aux_total

    def prefill(self, params, batch, max_seq_local: int,
                ctx: ShardCtx = ShardCtx()):
        """Serving prefill: forward pass that also materializes the KV/SSM
        cache, padded along (local) sequence to max_seq_local."""
        cfg = self.cfg
        logits, _, caches = self.forward(params, batch, ctx,
                                         collect_cache=True)
        cache = {}
        if "k" in caches:
            pad = max_seq_local - caches["k"].shape[2]
            padw = [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)]
            cache["k"] = jnp.pad(caches["k"], padw)
            cache["v"] = jnp.pad(caches["v"], padw)
        if "ssm" in caches:
            ssm, conv = caches["ssm"].astype(jnp.float32), caches["conv"]
            if ctx.sharded:
                # the global final state lives on the last cp shard; decode
                # keeps SSM state replicated, so broadcast it
                ssm = jax.lax.all_gather(ssm, ctx.cp_axis)[-1]
                conv = jax.lax.all_gather(conv, ctx.cp_axis)[-1]
            cache["ssm"], cache["conv"] = ssm, conv
        return logits, cache

    def _head(self, params, x):
        cfg = self.cfg
        if cfg.tie_embeddings:
            w = params["embed"]
            if L.code_resident(w):
                logits = w.astype(x.dtype).matmul_t(x)
            else:
                logits = x @ w.astype(x.dtype).T
        else:
            logits = L.pmatmul(x, params["unembed"])
        logits = logits.astype(jnp.float32)
        if cfg.final_softcap:
            logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
        return logits

    # ---------------- whisper ----------------
    def _encode(self, params, audio, ctx):
        cfg = self.cfg
        x = audio.astype(_dt(cfg))
        B, Sa, d = x.shape
        pos0 = ctx.cp_index() * Sa
        x = x + L.sinusoidal_positions(Sa, d, offset=pos0).astype(x.dtype)[None]

        def enc_block(x, p):
            p = ctx.gather(p, "enc_blocks")
            h = L.apply_norm(x, p["ln1"], cfg)
            # bidirectional self attention, absolute positions
            out = self._attn_sublayer(p["attn"], h,
                                      q_pos=pos0 + jnp.arange(Sa),
                                      window=0, theta=cfg.rope_theta,
                                      ctx=ctx, causal=False)
            x = x + out
            h2 = L.apply_norm(x, p["ln2"], cfg)
            return x + L.mlp(p["mlp"], h2, cfg.act), None

        x, _ = jax.lax.scan(jax.checkpoint(enc_block), x,
                            params["enc_blocks"], unroll=cfg.scan_unroll)
        return L.apply_norm(x, params["enc_norm"], cfg)

    def _forward_encdec(self, params, batch, ctx):
        cfg = self.cfg
        params = ctx.gather(params, "static")
        enc = self._encode(params, batch["audio"], ctx)
        x = params["embed"].astype(_dt(cfg))[batch["tokens"]]
        B, S, d = x.shape
        pos0 = ctx.cp_index() * S
        x = x + L.sinusoidal_positions(S, d, offset=pos0).astype(x.dtype)[None]
        q_pos = pos0 + jnp.arange(S)
        K, hd = cfg.n_kv_heads, cfg.head_dim_

        def dec_block(x, p):
            p = ctx.gather(p, "blocks")
            h = L.apply_norm(x, p["ln1"], cfg)
            out = self._attn_sublayer(p["attn"], h, q_pos=q_pos, window=0,
                                      theta=cfg.rope_theta, ctx=ctx)
            x = x + out
            hx = L.apply_norm(x, p["ln_x"], cfg)
            ek = L.pmatmul(enc, p["xattn"]["k"]).reshape(
                B, enc.shape[1], K, hd)
            ev = L.pmatmul(enc, p["xattn"]["v"]).reshape(
                B, enc.shape[1], K, hd)
            xout = self._attn_sublayer(p["xattn"], hx, q_pos=q_pos, window=0,
                                       theta=cfg.rope_theta, ctx=ctx,
                                       kv_override=(ek, ev), causal=False)
            x = x + xout
            h2 = L.apply_norm(x, p["ln2"], cfg)
            return x + L.mlp(p["mlp"], h2, cfg.act), None

        x, _ = jax.lax.scan(jax.checkpoint(dec_block), x, params["blocks"],
                            unroll=cfg.scan_unroll)
        x = L.apply_norm(x, params["final_norm"], cfg)
        return self._head(params, x), jnp.zeros((), jnp.float32)

    # ---------------- loss ----------------
    def loss(self, params, batch, ctx: ShardCtx = ShardCtx()):
        """Returns (local loss sum, local token count). DP/CP mean happens
        in the caller (psum over mesh axes)."""
        logits, aux = self.forward(params, batch, ctx)
        targets = batch["targets"]
        mask = batch.get("mask")
        if mask is None:
            mask = jnp.ones_like(targets, jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, targets[..., None],
                                   axis=-1)[..., 0]
        nll = (logz - gold) * mask
        return jnp.sum(nll) + aux, jnp.sum(mask)

    # ---------------- KV cache (decode) ----------------
    def init_cache(self, batch_size: int, max_seq_local: int,
                   encoder_seq_local: int = 0,
                   dtype=None,
                   page_pool: Optional[Tuple[int, int]] = None
                   ) -> Dict[str, Any]:
        """Decode cache. With ``page_pool=(num_pages, page_size)`` the KV
        lanes become a shared physical page pool ``pk``/``pv`` plus a
        per-slot page table ``ptab`` (entries init to the RELEASED
        sentinel ``num_pages``: writes drop, reads are masked). SSM/conv
        state stays per-slot (it is O(1) in sequence length - paging buys
        nothing), as do whisper's fixed-length cross caches."""
        cfg = self.cfg
        dtype = dtype or _dt(cfg)
        B = batch_size
        K, hd, lyr = cfg.n_kv_heads, cfg.head_dim_, cfg.n_layers
        cache: Dict[str, Any] = {}
        if cfg.arch_type != "ssm" and page_pool is not None:
            num_pages, page_size = page_pool
            if max_seq_local % page_size:
                raise ValueError(
                    f"max_seq_local={max_seq_local} must be a multiple of "
                    f"page_size={page_size} (the per-slot view keeps the "
                    "fixed-lane shape so decode stays bitwise identical)")
            npag = max_seq_local // page_size
            cache["pk"] = jnp.zeros((lyr, num_pages, page_size, K, hd), dtype)
            cache["pv"] = jnp.zeros((lyr, num_pages, page_size, K, hd), dtype)
            cache["ptab"] = jnp.full((B, npag), num_pages, jnp.int32)
        elif cfg.arch_type != "ssm":
            cache["k"] = jnp.zeros((lyr, B, max_seq_local, K, hd), dtype)
            cache["v"] = jnp.zeros((lyr, B, max_seq_local, K, hd), dtype)
        if cfg.arch_type in ("ssm", "hybrid"):
            s = cfg.ssm
            H = cfg.n_ssm_heads
            conv_dim = cfg.d_inner + 2 * s.n_groups * s.d_state
            cache["ssm"] = jnp.zeros((lyr, B, H, s.head_dim, s.d_state),
                                     jnp.float32)
            cache["conv"] = jnp.zeros((lyr, B, s.d_conv - 1, conv_dim), dtype)
        if cfg.arch_type == "encdec":
            cache["ck"] = jnp.zeros((lyr, B, encoder_seq_local, K, hd), dtype)
            cache["cv"] = jnp.zeros((lyr, B, encoder_seq_local, K, hd), dtype)
        return cache

    def prefill_encoder(self, params, audio, cache, ctx: ShardCtx = ShardCtx()):
        """Whisper: run encoder, fill cross-attention cache."""
        cfg = self.cfg
        params = ctx.gather(params, "static")
        enc = self._encode(params, audio, ctx)
        B, Sa, _ = enc.shape
        K, hd = cfg.n_kv_heads, cfg.head_dim_

        def fill(p):
            p = ctx.gather(p, "blocks")
            ck = L.pmatmul(enc, p["xattn"]["k"]).reshape(B, Sa, K, hd)
            cv = L.pmatmul(enc, p["xattn"]["v"]).reshape(B, Sa, K, hd)
            return ck, cv

        ck, cv = jax.vmap(fill)(params["blocks"])
        cache = dict(cache)
        cache["ck"], cache["cv"] = ck, cv
        return cache

    # ---------------- decode ----------------
    def decode_step(self, params, inputs, cache, pos,
                    ctx: ShardCtx = ShardCtx()):
        """One-token decode. inputs: {"token": (B,1)} or {"embeds": (B,1,d)}.
        pos: int32 global position of this token - scalar (batch-synchronous
        decode) or (B,) per-slot positions (continuous batching: every slot
        sits at its own depth, attention masked to its own valid prefix).
        The KV cache is sequence-sharded over the cp axis; SSM state is
        replicated."""
        cfg = self.cfg
        pos = jnp.asarray(pos, jnp.int32)
        per_slot = pos.ndim == 1
        params = ctx.gather(params, "static")
        if cfg.input_mode == "embeddings":
            x = inputs["embeds"].astype(_dt(cfg))
        elif L.code_resident(params["embed"]):
            x = params["embed"].astype(_dt(cfg)).take(inputs["token"])
        else:
            x = params["embed"].astype(_dt(cfg))[inputs["token"]]
        if cfg.emb_scale:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
        if cfg.arch_type == "encdec":
            B, _, d = x.shape
            se = L.sinusoidal_positions(1, d, offset=pos).astype(x.dtype)
            x = x + (se if per_slot else se[None])
        B = x.shape[0]
        windows, thetas = self._flags()
        K, hd = cfg.n_kv_heads, cfg.head_dim_
        H = cfg.n_heads

        paged = "pk" in cache
        if paged:
            # lazy: models never import the serve stack at module scope
            from repro.serve.paged import gather_pages
            cache = dict(cache)
            ptab = cache.pop("ptab")                     # (B, npag) global ids
            P_loc, ps = cache["pk"].shape[1], cache["pk"].shape[2]
            npag = ptab.shape[1]
            S_view = npag * ps
            page0 = ctx.cp_index() * P_loc               # pages cp-sharded
            posv = pos if per_slot else jnp.broadcast_to(pos, (B,))
            rows_p = jnp.arange(B)
            wslot = jnp.clip(posv // ps, 0, npag - 1)
            wloc = ptab[rows_p, wslot] - page0
            # unwritable (out-of-view position, RELEASED-sentinel table row,
            # or a page another cp shard owns) redirects to index P_loc:
            # out-of-bounds scatters drop, so the write just vanishes
            widx = jnp.where((posv < S_view) & (wloc >= 0) & (wloc < P_loc),
                             wloc, P_loc)
            woff = posv % ps
            own = (ptab >= page0) & (ptab < page0 + P_loc)   # (B, npag)
            extra_valid = jnp.repeat(own, ps, axis=1)        # (B, S_view)
            view_pos = jnp.arange(S_view)
            ptab_loc = ptab - page0   # gather_pages clips; `own` masks strays

        S_loc = cache["k"].shape[2] if "k" in cache else 0
        if ctx.sharded and S_loc:
            local_pos = pos - ctx.cp_index() * S_loc
            in_range = (local_pos >= 0) & (local_pos < S_loc)
            local_pos_c = jnp.clip(local_pos, 0, S_loc - 1)
        else:
            local_pos_c = pos
            in_range = jnp.broadcast_to(jnp.asarray(True), pos.shape)

        def block(carry, scanned):
            x = carry
            p, window, theta, cache_l = scanned
            p = ctx.gather(p, "blocks")
            h = L.apply_norm(x, p["ln1"], cfg)
            new_cache_l = dict(cache_l)
            if cfg.arch_type == "ssm":
                out, st = L.mamba2_mix(
                    p["ssm"], h, cfg.ssm, cfg.d_model,
                    decode_cache={"ssm": cache_l["ssm"],
                                  "conv": cache_l["conv"]})
                new_cache_l["ssm"], new_cache_l["conv"] = st["ssm"], st["conv"]
                return x + out, new_cache_l

            # self-attention against the cache
            pa = p["attn"]
            q = L.pmatmul(h, pa["q"])
            if cfg.qkv_bias:
                q = q + pa["bq"].astype(h.dtype)
            q = q.reshape(B, 1, H, hd)
            k = L.pmatmul(h, pa["k"])
            v = L.pmatmul(h, pa["v"])
            if cfg.qkv_bias:
                k = k + pa["bk"].astype(h.dtype)
                v = v + pa["bv"].astype(h.dtype)
            k = k.reshape(B, 1, K, hd)
            v = v.reshape(B, 1, K, hd)
            if cfg.qk_norm:
                q = L.rmsnorm(q, pa["q_norm"], cfg.norm_eps)
                k = L.rmsnorm(k, pa["k_norm"], cfg.norm_eps)
            if cfg.arch_type != "encdec":
                ppos = pos[:, None] if per_slot else pos[None]
                q = L.rope(q, ppos, theta)
                k = L.rope(k, ppos, theta)
            if paged:
                # scatter this token's K/V into each slot's current page;
                # the gathered view then matches the fixed lane bitwise at
                # every valid position
                kp = cache_l["pk"].at[widx, woff].set(
                    k[:, 0].astype(cache_l["pk"].dtype), mode="drop")
                vp = cache_l["pv"].at[widx, woff].set(
                    v[:, 0].astype(cache_l["pv"].dtype), mode="drop")
                new_cache_l["pk"], new_cache_l["pv"] = kp, vp
                kc = gather_pages(kp, ptab_loc)
                vc = gather_pages(vp, ptab_loc)
            elif per_slot:
                # per-row scatter: slot i appends at its own position
                rows = jnp.arange(B)
                kc = cache_l["k"].at[rows, local_pos_c].set(
                    k[:, 0].astype(cache_l["k"].dtype))
                vc = cache_l["v"].at[rows, local_pos_c].set(
                    v[:, 0].astype(cache_l["v"].dtype))
                keep = in_range[:, None, None, None]
                kc = jnp.where(keep, kc, cache_l["k"])
                vc = jnp.where(keep, vc, cache_l["v"])
            else:
                kc = jax.lax.dynamic_update_slice(
                    cache_l["k"], k.astype(cache_l["k"].dtype),
                    (0, local_pos_c, 0, 0))
                vc = jax.lax.dynamic_update_slice(
                    cache_l["v"], v.astype(cache_l["v"].dtype),
                    (0, local_pos_c, 0, 0))
                kc = jnp.where(in_range, kc, cache_l["k"])
                vc = jnp.where(in_range, vc, cache_l["v"])
            if not paged:
                new_cache_l["k"], new_cache_l["v"] = kc, vc

            meta_kv = None
            if cfg.meta_tokens:
                meta_kv = (
                    jnp.broadcast_to(pa["meta_k"].astype(h.dtype),
                                     (B,) + pa["meta_k"].shape),
                    jnp.broadcast_to(pa["meta_v"].astype(h.dtype),
                                     (B,) + pa["meta_v"].shape))
            attn_out = L.decode_attention(
                q, kc, vc, total_len=pos + 1, window=window,
                softcap=cfg.attn_softcap, q_pos=pos, ctx=ctx,
                meta_kv=meta_kv,
                kv_positions=view_pos if paged else None,
                extra_valid=extra_valid if paged else None)
            attn_out = L.pmatmul(attn_out.reshape(B, 1, H * hd), pa["o"])

            if cfg.arch_type == "hybrid":
                ssm_out, st = L.mamba2_mix(
                    p["ssm"], h, cfg.ssm, cfg.d_model,
                    decode_cache={"ssm": cache_l["ssm"],
                                  "conv": cache_l["conv"]})
                new_cache_l["ssm"], new_cache_l["conv"] = st["ssm"], st["conv"]
                attn_out = 0.5 * (
                    L.apply_norm(attn_out, p["attn_out_norm"], cfg)
                    + L.apply_norm(ssm_out, p["ssm_out_norm"], cfg))
            if cfg.post_norm:
                attn_out = L.apply_norm(attn_out, p["ln1_post"], cfg)
            x = x + attn_out

            if cfg.arch_type == "encdec":
                hx = L.apply_norm(x, p["ln_x"], cfg)
                # cross-attention is non-causal over the full encoder cache,
                # so the (per-slot) query position never enters the mask
                xq_pos = jnp.max(pos)[None] if per_slot else pos[None]
                xout = self._attn_sublayer(
                    p["xattn"], hx, q_pos=xq_pos, window=0,
                    theta=cfg.rope_theta, ctx=ctx,
                    kv_override=(cache_l["ck"], cache_l["cv"]), causal=False)
                x = x + xout

            h2 = L.apply_norm(x, p["ln2"], cfg)
            if cfg.moe is not None:
                mlp_out, _ = L.moe(p["moe"], h2, cfg.moe, ctx=ctx)
            else:
                mlp_out = L.mlp(p["mlp"], h2, cfg.act)
            if cfg.post_norm:
                mlp_out = L.apply_norm(mlp_out, p["ln2_post"], cfg)
            return x + mlp_out, new_cache_l

        x, new_cache = jax.lax.scan(
            block, x, (params["blocks"], windows, thetas, cache),
            unroll=cfg.scan_unroll)
        x = L.apply_norm(x, params["final_norm"], cfg)
        logits = self._head(params, x)[:, 0]
        if paged:
            new_cache["ptab"] = ptab
        return logits, new_cache

    def decode_chunk(self, params, inputs, cache, start, nvalid,
                     ctx: ShardCtx = ShardCtx()):
        """Chunked prefill: advance B slots by one fixed-size chunk of
        prompt tokens against their own (fixed-lane or paged) cache.

        inputs: {"token": (B,Sq)} or {"embeds": (B,Sq,d)}; start: (B,)
        global position of each slot's first chunk token; nvalid: (B,)
        valid tokens in the chunk - the padded tail's cache writes are
        dropped and its activations never reach a valid position (its
        tokens sit at *future* positions nothing valid attends to).

        Returns (logits (B,V) of position start+nvalid-1, new_cache): one
        jit shape per chunk size regardless of prompt length. For SSM and
        hybrid stacks the caller must dispatch only full chunks with
        Sq % cfg.ssm.chunk == 0 (the SSD scan has no per-token validity
        masking - sessions gate admission on it).

        Local-path only (mesh sessions admit by token injection).
        """
        cfg = self.cfg
        if ctx.sharded:
            raise NotImplementedError("decode_chunk is local-only")
        if cfg.arch_type == "encdec":
            raise NotImplementedError("enc-dec serving prefills via prefill()")
        start = jnp.asarray(start, jnp.int32)
        nvalid = jnp.asarray(nvalid, jnp.int32)
        params = ctx.gather(params, "static")
        if cfg.input_mode == "embeddings":
            x = inputs["embeds"].astype(_dt(cfg))
        elif L.code_resident(params["embed"]):
            x = params["embed"].astype(_dt(cfg)).take(inputs["token"])
        else:
            x = params["embed"].astype(_dt(cfg))[inputs["token"]]
        if cfg.emb_scale:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
        B, Sq, _ = x.shape
        windows, thetas = self._flags()
        K, hd = cfg.n_kv_heads, cfg.head_dim_
        H = cfg.n_heads
        rows = jnp.arange(B)
        q_pos = start[:, None] + jnp.arange(Sq)[None, :]       # (B, Sq)
        valid_q = jnp.arange(Sq)[None, :] < nvalid[:, None]    # (B, Sq)

        paged = "pk" in cache
        if paged:
            from repro.serve.paged import gather_pages
            cache = dict(cache)
            ptab = cache.pop("ptab")
            P_loc, ps = cache["pk"].shape[1], cache["pk"].shape[2]
            npag = ptab.shape[1]
            S_view = npag * ps
            wslot = jnp.clip(q_pos // ps, 0, npag - 1)
            wloc = ptab[rows[:, None], wslot]                  # (B, Sq)
            widx = jnp.where(valid_q & (q_pos < S_view)
                             & (wloc < P_loc), wloc, P_loc)
            woff = q_pos % ps
            own = ptab < P_loc                                 # (B, npag)
            extra_valid = jnp.repeat(own, ps, axis=1)
            view_pos = jnp.arange(S_view)
            ptab_loc = ptab
        else:
            S_loc = cache["k"].shape[2] if "k" in cache else 0
            if S_loc:
                lane_idx = jnp.where(valid_q & (q_pos < S_loc), q_pos, S_loc)

        def block(carry, scanned):
            x = carry
            p, window, theta, cache_l = scanned
            p = ctx.gather(p, "blocks")
            h = L.apply_norm(x, p["ln1"], cfg)
            new_cache_l = dict(cache_l)
            if cfg.arch_type == "ssm":
                out, st = L.mamba2_mix(
                    p["ssm"], h, cfg.ssm, cfg.d_model,
                    decode_cache={"ssm": cache_l["ssm"],
                                  "conv": cache_l["conv"]})
                new_cache_l["ssm"], new_cache_l["conv"] = st["ssm"], st["conv"]
                return x + out, new_cache_l

            pa = p["attn"]
            q = L.pmatmul(h, pa["q"])
            k = L.pmatmul(h, pa["k"])
            v = L.pmatmul(h, pa["v"])
            if cfg.qkv_bias:
                q = q + pa["bq"].astype(h.dtype)
                k = k + pa["bk"].astype(h.dtype)
                v = v + pa["bv"].astype(h.dtype)
            q = q.reshape(B, Sq, H, hd)
            k = k.reshape(B, Sq, K, hd)
            v = v.reshape(B, Sq, K, hd)
            if cfg.qk_norm:
                q = L.rmsnorm(q, pa["q_norm"], cfg.norm_eps)
                k = L.rmsnorm(k, pa["k_norm"], cfg.norm_eps)
            q = L.rope(q, q_pos, theta)
            k = L.rope(k, q_pos, theta)
            if paged:
                kp = cache_l["pk"].at[widx, woff].set(
                    k.astype(cache_l["pk"].dtype), mode="drop")
                vp = cache_l["pv"].at[widx, woff].set(
                    v.astype(cache_l["pv"].dtype), mode="drop")
                new_cache_l["pk"], new_cache_l["pv"] = kp, vp
                kc = gather_pages(kp, ptab_loc)
                vc = gather_pages(vp, ptab_loc)
            else:
                kc = cache_l["k"].at[rows[:, None], lane_idx].set(
                    k.astype(cache_l["k"].dtype), mode="drop")
                vc = cache_l["v"].at[rows[:, None], lane_idx].set(
                    v.astype(cache_l["v"].dtype), mode="drop")
                new_cache_l["k"], new_cache_l["v"] = kc, vc

            meta_kv = None
            if cfg.meta_tokens:
                meta_kv = (
                    jnp.broadcast_to(pa["meta_k"].astype(h.dtype),
                                     (B,) + pa["meta_k"].shape),
                    jnp.broadcast_to(pa["meta_v"].astype(h.dtype),
                                     (B,) + pa["meta_v"].shape))
            attn_out = L.chunk_attention(
                q, kc, vc, q_pos=q_pos, window=window,
                softcap=cfg.attn_softcap, meta_kv=meta_kv,
                kv_positions=view_pos if paged else None,
                extra_valid=extra_valid if paged else None)
            attn_out = L.pmatmul(attn_out.reshape(B, Sq, H * hd), pa["o"])

            if cfg.arch_type == "hybrid":
                ssm_out, st = L.mamba2_mix(
                    p["ssm"], h, cfg.ssm, cfg.d_model,
                    decode_cache={"ssm": cache_l["ssm"],
                                  "conv": cache_l["conv"]})
                new_cache_l["ssm"], new_cache_l["conv"] = st["ssm"], st["conv"]
                attn_out = 0.5 * (
                    L.apply_norm(attn_out, p["attn_out_norm"], cfg)
                    + L.apply_norm(ssm_out, p["ssm_out_norm"], cfg))
            if cfg.post_norm:
                attn_out = L.apply_norm(attn_out, p["ln1_post"], cfg)
            x = x + attn_out

            h2 = L.apply_norm(x, p["ln2"], cfg)
            if cfg.moe is not None:
                mlp_out, _ = L.moe(p["moe"], h2, cfg.moe, ctx=ctx)
            else:
                mlp_out = L.mlp(p["mlp"], h2, cfg.act)
            if cfg.post_norm:
                mlp_out = L.apply_norm(mlp_out, p["ln2_post"], cfg)
            return x + mlp_out, new_cache_l

        x, new_cache = jax.lax.scan(
            block, x, (params["blocks"], windows, thetas, cache),
            unroll=cfg.scan_unroll)
        x = L.apply_norm(x, params["final_norm"], cfg)
        last = jnp.clip(nvalid - 1, 0, Sq - 1)
        xl = x[rows, last][:, None]                            # (B, 1, d)
        logits = self._head(params, xl)[:, 0]
        if paged:
            new_cache["ptab"] = ptab
        return logits, new_cache


def _dt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _remat_policy(cfg: ModelConfig):
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    if cfg.remat_policy == "ssd_state":
        return jax.checkpoint_policies.save_only_these_names(
            "ssd_prefix_state")
    return None
