"""Bit-packing of quantization codes for the wire - thin shim.

The packing math lives in ``repro.comm.bits`` (the codec stack's lane
packer); this module keeps the historical flat-array API. The byte
layout for 2/4/8-bit codes is unchanged; 3-, 6- and 16-bit lanes are new
(odd widths pack in 24-bit groups - see ``repro.comm.bits``).

Packing is what turns "fewer levels" into "fewer bytes" on the TPU ICI:
the collectives in repro.dist move the *packed* arrays.
"""
from __future__ import annotations

import jax

from repro.comm.bits import (  # noqa: F401
    SUPPORTED_BITS,
    packed_nbytes,
)
from repro.comm import bits as _B


def pack_codes(codes: jax.Array, bits: int) -> jax.Array:
    """Pack signed int codes into a dense uint8 array of shape
    ``(packed_nbytes(numel, bits),)``."""
    return _B.pack_flat(codes, bits)


def unpack_codes(packed: jax.Array, bits: int, numel: int) -> jax.Array:
    """Inverse of pack_codes -> codes of shape (numel,) (int8; int16 for
    16-bit lanes)."""
    return _B.unpack_flat(packed, bits, numel)
