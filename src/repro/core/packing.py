"""Bit-packing of quantization codes for the wire.

The paper's log grid at k_g<=6 has <=15 levels -> 4 bits/code; the channel
ships two codes per int8. TernGrad/sign codes fit 2 bits -> four per int8.
Packing is what turns "fewer levels" into "fewer bytes" on the TPU ICI: the
collectives in repro.dist move the *packed* arrays.

Signed codes c in [-(2^(b-1)-1), 2^(b-1)-1] are biased to unsigned
u = c + 2^(b-1) before packing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def pack_codes(codes: jax.Array, bits: int) -> jax.Array:
    """Pack signed int codes (int8) into a dense uint8 array.

    codes: any shape, values in [-(2^(bits-1)), 2^(bits-1)-1].
    Returns uint8 of shape (ceil(numel*bits/8),).
    """
    if bits == 8:
        return codes.astype(jnp.int8).reshape(-1).view(jnp.uint8)
    assert 8 % bits == 0, f"bits={bits} must divide 8"
    per = 8 // bits
    bias = 1 << (bits - 1)
    flat = codes.reshape(-1).astype(jnp.int32) + bias  # unsigned
    pad = (-flat.shape[0]) % per
    flat = jnp.pad(flat, (0, pad), constant_values=bias)
    grp = flat.reshape(-1, per)
    shifts = jnp.arange(per, dtype=jnp.int32) * bits
    packed = jnp.sum(grp << shifts[None, :], axis=1)
    return packed.astype(jnp.uint8)


def unpack_codes(packed: jax.Array, bits: int, numel: int) -> jax.Array:
    """Inverse of pack_codes -> int8 codes of shape (numel,)."""
    if bits == 8:
        return packed.view(jnp.int8)[:numel]
    per = 8 // bits
    bias = 1 << (bits - 1)
    mask = (1 << bits) - 1
    u = packed.astype(jnp.int32)
    shifts = jnp.arange(per, dtype=jnp.int32) * bits
    grp = (u[:, None] >> shifts[None, :]) & mask
    flat = grp.reshape(-1)[:numel] - bias
    return flat.astype(jnp.int8)


def packed_nbytes(numel: int, bits: int) -> int:
    return int(np.ceil(numel * bits / 8))
