"""Quantized Generic Adam with Error Feedback (Algorithm 1) + baselines.

Optax-style API (no optax dependency):

    opt = qadam(QAdamConfig(alpha=1e-3, grad_q="log:6", weight_q="uniform:7"))
    state = opt.init(params)
    qparams = opt.forward_params(params, state)       # Q_x(x_t) - run fwd/bwd on these
    updates, state = opt.update(grads, state)         # quantized delta, EF applied
    params = apply_updates(params, updates)

The hyperparameter schedule follows Assumption 4 / Section 5:
  theta_t = 1 - theta/t, alpha_t per `schedule`, beta constant.
`schedule` options: "sqrt" (alpha/sqrt(t), Assumption 4), "constant",
"halving:K" (halve every K steps - the paper's experimental setting).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.quantizers import (Quantizer, IdentityQuantizer,
                                   LogGradQuantizer, get_quantizer)
from repro.opt import engine


@dataclasses.dataclass(frozen=True)
class QAdamConfig:
    alpha: float = 1e-3
    beta: float = 0.99
    theta: float = 0.999
    eps: float = 1e-5
    schedule: str = "constant"     # "sqrt" | "constant" | "halving:K"
    grad_q: Optional[str] = "log:6"
    weight_q: Optional[str] = None
    error_feedback: bool = True    # ablation knob (paper: EF on)
    # leaves smaller than this skip Q_x (norm scales / biases would be
    # clipped by the absolute grid; the paper quantizes weight matrices).
    # 0 = quantize everything (fully faithful Algorithm 1).
    weight_q_min_numel: int = 0
    # engine backend for the leaf update: "jnp" | "pallas" | None = auto
    # (Pallas on TPU for tile-sized leaves). Both emit identical codes.
    backend: Optional[str] = None

    def grad_quantizer(self) -> Quantizer:
        return get_quantizer(self.grad_q)

    def weight_quantizer(self) -> Quantizer:
        return get_quantizer(self.weight_q)


class QAdamState(NamedTuple):
    count: jax.Array          # t (starts at 0; step uses t+1)
    m: Any                    # first moment, per param
    v: Any                    # second moment, per param
    e: Any                    # error-feedback residual, per param
    key: jax.Array            # PRNG for stochastic quantizers (TernGrad)


class Optimizer(NamedTuple):
    init: Callable
    update: Callable
    forward_params: Callable


def _alpha_t(cfg: QAdamConfig, t: jax.Array) -> jax.Array:
    tf = t.astype(jnp.float32)
    if cfg.schedule == "sqrt":
        return cfg.alpha / jnp.sqrt(tf)
    if cfg.schedule == "constant":
        return jnp.float32(cfg.alpha)
    if cfg.schedule.startswith("halving"):
        k = int(cfg.schedule.split(":")[1])
        return cfg.alpha * 0.5 ** jnp.floor((tf - 1.0) / k)
    raise ValueError(cfg.schedule)


def _theta_t(cfg: QAdamConfig, t: jax.Array) -> jax.Array:
    # theta_t = 1 - theta/t  (Assumption 4). With theta<1 this stays in (0,1).
    return 1.0 - cfg.theta / t.astype(jnp.float32)


def _zeros_like_tree(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def qadam(cfg: QAdamConfig, seed: int = 0) -> Optimizer:
    """Algorithm 1: Quantized Generic Adam (single worker)."""
    gq = cfg.grad_quantizer()
    wq = cfg.weight_quantizer()

    def init(params) -> QAdamState:
        return QAdamState(
            count=jnp.zeros((), jnp.int32),
            m=_zeros_like_tree(params),
            v=_zeros_like_tree(params),
            e=_zeros_like_tree(params),
            key=jax.random.PRNGKey(seed),
        )

    def forward_params(params, state=None):
        """Q_x(x_t): weights the gradient must be sampled at (Assumption 3)."""
        if isinstance(wq, IdentityQuantizer):
            return params

        def leaf(p):
            if p.size < cfg.weight_q_min_numel:
                return p
            return wq(p).astype(p.dtype)
        return jax.tree.map(leaf, params)

    def update(grads, state: QAdamState, params=None):
        t = state.count + 1
        a_t = _alpha_t(cfg, t)
        th_t = _theta_t(cfg, t)
        key, sub = jax.random.split(state.key)
        leaves = jax.tree.structure(grads).num_leaves
        subkeys = list(jax.random.split(sub, leaves))
        keys_tree = jax.tree.unflatten(jax.tree.structure(grads), subkeys)

        def leaf(g, m, v, e, k):
            g = g.astype(jnp.float32)
            if isinstance(gq, LogGradQuantizer):
                # the paper's Q_g: the engine's fused update core
                # (two-pass Pallas on TPU, jnp elsewhere - identical codes)
                delta_q, m_new, v_new, e_new = engine.adam_ef_update(
                    g, m, v, e, a_t, cfg.beta, th_t, cfg.eps,
                    k_g=gq.k_g, error_feedback=cfg.error_feedback,
                    backend=cfg.backend)
                return -delta_q, m_new, v_new, e_new
            m_new, v_new, delta_full = engine.adam_ef_moments(
                g, m, v, e, a_t, cfg.beta, th_t, cfg.eps,
                backend=cfg.backend)
            if isinstance(gq, IdentityQuantizer):
                delta_q = delta_full
            else:
                delta_q = gq(delta_full, key=k)
            e_new = (delta_full - delta_q) if cfg.error_feedback \
                else jnp.zeros_like(e)
            return -delta_q, m_new, v_new, e_new

        out = jax.tree.map(leaf, grads, state.m, state.v, state.e, keys_tree)
        updates = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        e = jax.tree.map(lambda o: o[3], out, is_leaf=lambda x: isinstance(x, tuple))
        return updates, QAdamState(count=t, m=m, v=v, e=e, key=key)

    return Optimizer(init=init, update=update, forward_params=forward_params)


def ef_sgdm(alpha: float = 0.1, beta: float = 0.9,
            grad_q: str = "blockwise:256", schedule: str = "constant",
            seed: int = 0) -> Optimizer:
    """Zheng et al. '19 baseline: blockwise-compressed momentum SGD with EF."""
    gq = get_quantizer(grad_q)
    cfg = QAdamConfig(alpha=alpha, beta=beta, schedule=schedule)

    def init(params):
        return QAdamState(count=jnp.zeros((), jnp.int32),
                          m=_zeros_like_tree(params),
                          v=_zeros_like_tree(params),
                          e=_zeros_like_tree(params),
                          key=jax.random.PRNGKey(seed))

    def forward_params(params, state=None):
        return params

    def update(grads, state, params=None):
        t = state.count + 1
        a_t = _alpha_t(cfg, t)
        key, sub = jax.random.split(state.key)
        leaves = jax.tree.structure(grads).num_leaves
        keys_tree = jax.tree.unflatten(jax.tree.structure(grads),
                                       list(jax.random.split(sub, leaves)))

        def leaf(g, m, e, k):
            g = g.astype(jnp.float32)
            m_new = beta * m + g
            delta_full = a_t * m_new + e
            delta_q = gq(delta_full, key=k)
            return -delta_q, m_new, delta_full - delta_q

        out = jax.tree.map(leaf, grads, state.m, state.e, keys_tree)
        upd = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        e = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return upd, QAdamState(count=t, m=m, v=state.v, e=e, key=key)

    return Optimizer(init=init, update=update, forward_params=forward_params)


def terngrad_sgd(alpha: float = 0.1, schedule: str = "constant",
                 seed: int = 0) -> Optimizer:
    """TernGrad baseline (Wen et al. '17): unbiased ternary SGD, no EF."""
    gq = get_quantizer("terngrad")
    cfg = QAdamConfig(alpha=alpha, schedule=schedule)

    def init(params):
        return QAdamState(count=jnp.zeros((), jnp.int32),
                          m=_zeros_like_tree(params), v=_zeros_like_tree(params),
                          e=_zeros_like_tree(params), key=jax.random.PRNGKey(seed))

    def forward_params(params, state=None):
        return params

    def update(grads, state, params=None):
        t = state.count + 1
        a_t = _alpha_t(cfg, t)
        key, sub = jax.random.split(state.key)
        leaves = jax.tree.structure(grads).num_leaves
        keys_tree = jax.tree.unflatten(jax.tree.structure(grads),
                                       list(jax.random.split(sub, leaves)))
        upd = jax.tree.map(lambda g, k: -a_t * gq(g.astype(jnp.float32), key=k),
                           grads, keys_tree)
        return upd, QAdamState(count=t, m=state.m, v=state.v, e=state.e, key=key)

    return Optimizer(init=init, update=update, forward_params=forward_params)


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
        params, updates)


def wquan(params, k_x: int = 7, absolute: bool = True):
    """WQuan baseline: quantize weights once, after training."""
    wq = get_quantizer(f"uniform:{k_x}" if absolute else f"uniform_amax:{k_x}")
    return jax.tree.map(lambda p: wq(p).astype(p.dtype), params)
