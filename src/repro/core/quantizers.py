"""Quantization operators from the paper (and the baselines it compares
to) - thin shims over the ``repro.comm`` codec registry.

The paper's two quantizers (Section 5):

  Q_g(g) = ||g||_inf * argmin_{ghat in G^d} || g/||g||_inf - ghat ||,
      G = {-1, ..., -2^{-k_g}, 0, 2^{-k_g}, ..., 1}            (log grid)

  Q_x(x) = 0.5 * argmin_{xhat in X} || 2x - xhat ||,
      X = {-1, ..., -1/2^{k_x}, 0, 1/2^{k_x}, ..., 1}          (uniform grid)

Baselines: TernGrad (Wen et al. '17) and blockwise sign (Zheng et al.
'19). Every quantizer wraps a :class:`repro.comm.Codec` - the grid math,
scale policy, lane width, and byte accounting all live once there; this
module only keeps the historical ``QTensor`` (unpacked integer codes +
scale) wire objects and the spec-string surface.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import comm
from repro.comm.bits import lane_bits_for, payload_nbytes
from repro.opt import grids


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    """Integer codes + scale: a wire tensor *before* bit-packing.

    (The packed form is :class:`repro.comm.WireBuffer`; QTensor keeps the
    codes addressable for code-level tests and the single-machine
    optimizer.)

    codes: integer array (int8 storage; int16 for wide uniform grids)
    scale: scalar (per-tensor) or per-block array of float32
    meta:  static metadata (grid kind, packed lane bits, shape).
    """

    codes: jax.Array
    scale: jax.Array
    kind: str = dataclasses.field(metadata=dict(static=True))
    bits: int = dataclasses.field(metadata=dict(static=True))
    shape: tuple = dataclasses.field(metadata=dict(static=True))

    def tree_flatten(self):
        return (self.codes, self.scale), (self.kind, self.bits, self.shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        codes, scale = children
        kind, bits, shape = aux
        return cls(codes=codes, scale=scale, kind=kind, bits=bits, shape=shape)

    @property
    def nbytes_wire(self) -> int:
        """Exact bytes on the wire: packed payload + scale bytes (the
        codec-registry accounting)."""
        numel = int(np.prod(self.shape)) if self.shape else 1
        scale_bytes = int(np.prod(self.scale.shape)) * 4 \
            if hasattr(self.scale, "shape") else 4
        return payload_nbytes(numel, self.bits) + scale_bytes


# ---------------------------------------------------------------------------
# Log-grid gradient quantizer (the paper's Q_g)
# ---------------------------------------------------------------------------

def _log_levels(k_g: int) -> int:
    """Number of representable levels: +/- 2^0..2^-k_g plus 0."""
    return 2 * (k_g + 1) + 1

def log_bits(k_g: int) -> int:
    """Packed lane bits for the log grid (codes in [-(k_g+1), k_g+1])."""
    return lane_bits_for(k_g + 1)


def log_encode(g: jax.Array, k_g: int) -> QTensor:
    """Nearest-in-linear-space log-grid quantization, per-tensor amax scale.

    Code layout (``grids.log_quantize``): 0 encodes the value 0; signed
    code c with |c| in [1, k_g+1] encodes magnitude 2^{-(k_g+1-|c|)}.
    """
    cd = comm.LogCodec(k_g=k_g)
    g = g.astype(jnp.float32)
    scale = cd.compute_scale(g)
    codes = cd.quantize(g, scale)
    return QTensor(codes=codes, scale=scale, kind="log", bits=cd.bits,
                   shape=tuple(g.shape))


def log_decode(qt: QTensor, k_g: int) -> jax.Array:
    return comm.LogCodec(k_g=k_g).dequantize(qt.codes, qt.scale)


# ---------------------------------------------------------------------------
# Uniform weight quantizer (the paper's Q_x)
# ---------------------------------------------------------------------------

def uniform_encode(x: jax.Array, k_x: int, absolute: bool = True) -> QTensor:
    """Uniform grid. `absolute=True` is the paper's Q_x: grid over [-0.5,0.5]
    with spacing 2^-(k_x+1), no data-dependent scale (Assumption 3 is an
    additive bound). `absolute=False` scales the grid by amax (robust mode
    for big-model configs)."""
    cd = comm.UniformCodec(k_x=k_x, absolute=absolute)
    x = x.astype(jnp.float32)
    scale = cd.compute_scale(x)
    codes = cd.quantize(x, scale)
    return QTensor(codes=codes, scale=scale, kind="uniform", bits=cd.bits,
                   shape=tuple(x.shape))


def uniform_decode(qt: QTensor, k_x: int) -> jax.Array:
    return comm.UniformCodec(k_x=k_x).dequantize(qt.codes, qt.scale)


# ---------------------------------------------------------------------------
# TernGrad (unbiased stochastic ternary) - baseline
# ---------------------------------------------------------------------------

def ternary_encode(g: jax.Array, key: jax.Array) -> QTensor:
    cd = comm.TernaryCodec()
    g = g.astype(jnp.float32)
    scale = cd.compute_scale(g)
    # pre-drawn uniforms == jax.random.bernoulli(key, |g|/scale) draws
    u = jax.random.uniform(key, g.shape)
    codes = cd.quantize(g, scale, u=u)
    return QTensor(codes=codes, scale=scale, kind="ternary", bits=cd.bits,
                   shape=tuple(g.shape))


def ternary_decode(qt: QTensor) -> jax.Array:
    return comm.TernaryCodec().dequantize(qt.codes, qt.scale)


# ---------------------------------------------------------------------------
# Blockwise sign compression (Zheng et al. '19) - baseline
# ---------------------------------------------------------------------------

def blockwise_encode(g: jax.Array, block: int = 256) -> QTensor:
    g32 = g.astype(jnp.float32).reshape(-1)
    pad = (-g32.shape[0]) % block
    gp = jnp.pad(g32, (0, pad)).reshape(-1, block)
    codes, scale = grids.blockwise_quantize(gp)
    return QTensor(codes=codes, scale=scale, kind="blockwise", bits=1,
                   shape=tuple(g.shape))


def blockwise_decode(qt: QTensor) -> jax.Array:
    vals = grids.blockwise_dequantize(qt.codes, qt.scale)
    numel = int(np.prod(qt.shape))
    return vals.reshape(-1)[:numel].reshape(qt.shape)


# ---------------------------------------------------------------------------
# Quantizer objects
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Quantizer:
    """A named quantization operator Q(.)."""

    name: str

    def encode(self, x, *, key=None) -> QTensor:
        raise NotImplementedError

    def decode(self, qt: QTensor) -> jax.Array:
        raise NotImplementedError

    def __call__(self, x, *, key=None) -> jax.Array:
        return self.decode(self.encode(x, key=key))

    @property
    def codec(self) -> comm.Codec:
        """The registry codec backing this operator."""
        raise NotImplementedError

    @property
    def wire_bits(self) -> float:
        """Average payload bits per element (excluding scales)."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class IdentityQuantizer(Quantizer):
    name: str = "identity"

    def encode(self, x, *, key=None):
        x = jnp.asarray(x)
        return QTensor(codes=x, scale=jnp.float32(1.0), kind="identity",
                       bits=x.dtype.itemsize * 8, shape=tuple(x.shape))

    def decode(self, qt):
        return qt.codes

    def __call__(self, x, *, key=None):
        return jnp.asarray(x)

    @property
    def codec(self):
        return comm.IdentityCodec()

    @property
    def wire_bits(self):
        return 32.0


@dataclasses.dataclass(frozen=True)
class LogGradQuantizer(Quantizer):
    """The paper's Q_g."""

    k_g: int = 6
    name: str = "log"

    def encode(self, x, *, key=None):
        return log_encode(x, self.k_g)

    def decode(self, qt):
        return log_decode(qt, self.k_g)

    @property
    def codec(self):
        return comm.LogCodec(k_g=self.k_g)

    @property
    def wire_bits(self):
        return float(log_bits(self.k_g))


@dataclasses.dataclass(frozen=True)
class UniformWeightQuantizer(Quantizer):
    """The paper's Q_x."""

    k_x: int = 7
    absolute: bool = True
    name: str = "uniform"

    def encode(self, x, *, key=None):
        return uniform_encode(x, self.k_x, absolute=self.absolute)

    def decode(self, qt):
        return uniform_decode(qt, self.k_x)

    @property
    def codec(self):
        return comm.UniformCodec(k_x=self.k_x, absolute=self.absolute)

    @property
    def wire_bits(self):
        return float(self.codec.bits)


@dataclasses.dataclass(frozen=True)
class TernGradQuantizer(Quantizer):
    name: str = "terngrad"

    def encode(self, x, *, key=None):
        assert key is not None, "TernGrad is stochastic; pass key="
        return ternary_encode(x, key)

    def decode(self, qt):
        return ternary_decode(qt)

    @property
    def codec(self):
        return comm.TernaryCodec()

    @property
    def wire_bits(self):
        return 2.0


@dataclasses.dataclass(frozen=True)
class BlockwiseQuantizer(Quantizer):
    block: int = 256
    name: str = "blockwise"

    def encode(self, x, *, key=None):
        return blockwise_encode(x, self.block)

    def decode(self, qt):
        return blockwise_decode(qt)

    @property
    def codec(self):
        return comm.BlockwiseCodec(block=self.block)

    @property
    def wire_bits(self):
        return 1.0 + 32.0 / self.block


def get_quantizer(spec: Optional[str]) -> Quantizer:
    """Parse a quantizer spec string: 'none', 'log:k', 'uniform:k',
    'uniform_amax:k', 'terngrad', 'blockwise:b' (the same grammar as
    ``repro.comm.get_codec``)."""
    if spec is None or spec in ("none", "identity", "fp32"):
        return IdentityQuantizer()
    head, _, arg = spec.partition(":")
    if head == "log":
        return LogGradQuantizer(k_g=int(arg or 6))
    if head == "uniform":
        return UniformWeightQuantizer(k_x=int(arg or 7), absolute=True)
    if head == "uniform_amax":
        return UniformWeightQuantizer(k_x=int(arg or 7), absolute=False)
    if head == "terngrad":
        return TernGradQuantizer()
    if head == "blockwise":
        return BlockwiseQuantizer(block=int(arg or 256))
    raise ValueError(f"unknown quantizer spec: {spec}")
