"""Fused Pallas TPU kernels for the compression codec stack.

One kernel launch per direction (the acceptance contract of the codec
subsystem):

  * **encode** = amax + quantize + bit-pack. Data-dependent scales use a
    two-phase grid over the SAME ``pallas_call`` - phase 0 streams the
    tensor and folds per-block amax partials into an SMEM scratch
    accumulator, phase 1 re-streams it, quantizes against the final
    scale, and packs the codes to their wire lanes in VMEM. (TPU grids
    iterate sequentially, which is what makes the scratch carry work.)
    Codecs with static scales (the paper's absolute Q_x) skip phase 0.
  * **decode** = unpack + dequantize, one pass.
  * **ef-encode** = quantize + pack + error-feedback residual
    ``e' = x - deq(codes)`` in one pass (the scale arrives from the Adam
    moment pass, see ``repro.kernels.adam_ef``).

The packed payload never exists as an unpacked int8 code tensor in HBM:
codes live only in VMEM registers between the quantize and pack steps.

Every kernel body calls the canonical math in ``repro.opt.grids`` and
``repro.comm.bits`` on its VMEM tile, so the fused path is bit-identical
to the jnp reference backend by construction (asserted across all lane
widths by ``tests/test_comm_codecs.py``).

Lane geometry: the input tile's lane count ``LANES_IN[bits]`` is chosen
so the packed output tile is a whole number of 128-lane VREGs (e.g.
3-bit lanes read (rows, 1024) floats and write (rows, 384) bytes).

The historical per-op kernels (separate amax / quantize / dequantize
passes) also live here now; ``repro.kernels.quantize`` and
``repro.kernels.pack`` re-export them for backward compatibility.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.comm import bits as B
from repro.opt import grids

# legacy two-pass tiling (kept: repro.opt.engine's update core uses it)
BLOCK_ROWS = 256
LANES = 128

# fused-codec tiling: rows per grid step (f32 sublane multiple; small so
# sub-tile tensors don't over-pad) and input lanes per lane width (the
# packed output tile is then a whole number of 128-lane VREGs).
ENC_ROWS = 32
LANES_IN = {2: 512, 3: 1024, 4: 256, 6: 512, 8: 128, 16: 128}

# per-backend tile-width override (autotuning hook): maps a
# ``jax.default_backend()`` name to the rows-per-grid-step the fused
# codec kernels should use there. ``repro.perf.autotune`` measures the
# candidates and installs the winner; unset backends fall back to
# ENC_ROWS. Callers must size/pad payloads with ``enc_rows()`` — never
# the bare constant — so a retune changes every tiling consistently.
_ENC_ROWS_OVERRIDE: dict = {}


def enc_rows() -> int:
    """Rows per fused-codec grid step for the active backend."""
    return _ENC_ROWS_OVERRIDE.get(jax.default_backend(), ENC_ROWS)


def set_enc_rows(rows, backend: str | None = None) -> None:
    """Install (or, with ``rows=None``, clear) a tile-rows override for
    ``backend`` (default: the active one). Rows must keep f32 sublane
    alignment (multiple of 8)."""
    key = backend or jax.default_backend()
    if rows is None:
        _ENC_ROWS_OVERRIDE.pop(key, None)
        return
    if rows % 8 != 0 or rows <= 0:
        raise ValueError(f"enc_rows must be a positive multiple of 8: {rows}")
    _ENC_ROWS_OVERRIDE[key] = int(rows)


def lanes_in(bits: int) -> int:
    return LANES_IN[bits]


def lanes_out(bits: int) -> int:
    return LANES_IN[bits] * bits // 8


# ---------------------------------------------------------------------------
# in-kernel quantize/dequantize dispatch (static kind)
# ---------------------------------------------------------------------------

def _quant(x, scale, u, *, kind: str, k: int, clip_abs):
    if kind == "log":
        codes = grids.log_quantize(x, scale, k)
    elif kind == "uniform":
        codes = grids.uniform_quantize(x, scale, k)
    elif kind == "ternary":
        codes = grids.ternary_quantize(x, u, scale)
    else:
        raise ValueError(kind)
    if clip_abs is not None:
        codes = jnp.clip(codes, -clip_abs, clip_abs)
    return codes


def _dequant(codes, scale, *, kind: str, k: int, lut=None):
    """Dequantize dispatch. For the log grid a precomputed table (see
    ``grids.log_dequant_table``) turns the per-element exp2 into a gather
    — the transcendental re-evaluated on every lane-strided unpacked code
    is what made fused log decode 0.23x of legacy. The other grids are
    already a single multiply (uniform/ternary/blockwise dequant is
    ``codes * scale``), so a table buys them nothing and ``lut`` only
    applies to ``kind == "log"``."""
    if kind == "log":
        if lut is not None:
            return grids.log_dequantize_lut(codes, scale, lut)
        return grids.log_dequantize(codes, scale, k)
    if kind == "uniform":
        return grids.uniform_dequantize(codes, scale, k)
    if kind == "ternary":
        return grids.ternary_dequantize(codes, scale)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# fused encode (single launch)
# ---------------------------------------------------------------------------

def _encode2_body(x_ref, payload_ref, scale_ref, acc_ref, *, kind, bits,
                  k, clip_abs):
    """Two-phase: (0, i) amax partials -> SMEM; (1, i) quantize + pack."""
    ph = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(ph == 0)
    def _():
        part = grids.block_amax(x_ref[...])

        @pl.when(i == 0)
        def _():
            acc_ref[0] = part

        @pl.when(i > 0)
        def _():
            acc_ref[0] = jnp.maximum(acc_ref[0], part)

    @pl.when(ph == 1)
    def _():
        amax = acc_ref[0]
        scale = jnp.where(amax > 0, amax, 1.0).astype(jnp.float32)

        @pl.when(i == 0)
        def _():
            scale_ref[0] = scale

        codes = _quant(x_ref[...], scale, None, kind=kind, k=k,
                       clip_abs=clip_abs)
        payload_ref[...] = B.pack_lanes(codes, bits)


def _encode2_ternary_body(x_ref, u_ref, payload_ref, scale_ref, acc_ref,
                          *, bits, clip_abs):
    ph = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(ph == 0)
    def _():
        part = grids.block_amax(x_ref[...])

        @pl.when(i == 0)
        def _():
            acc_ref[0] = part

        @pl.when(i > 0)
        def _():
            acc_ref[0] = jnp.maximum(acc_ref[0], part)

    @pl.when(ph == 1)
    def _():
        amax = acc_ref[0]
        scale = jnp.where(amax > 0, amax, 1.0).astype(jnp.float32)

        @pl.when(i == 0)
        def _():
            scale_ref[0] = scale

        codes = _quant(x_ref[...], scale, u_ref[...], kind="ternary", k=0,
                       clip_abs=clip_abs)
        payload_ref[...] = B.pack_lanes(codes, bits)


def _encode1_body(x_ref, scale_ref, payload_ref, *, kind, bits, k,
                  clip_abs):
    """Single-phase encode with a known scale (absolute grids)."""
    codes = _quant(x_ref[...], scale_ref[0], None, kind=kind, k=k,
                   clip_abs=clip_abs)
    payload_ref[...] = B.pack_lanes(codes, bits)


def encode_pallas(x2d: jax.Array, kind: str, bits: int, k: int, *,
                  scale=None, u2d=None, clip_abs=None,
                  interpret: bool):
    """Fused amax+quantize+pack, ONE ``pallas_call``.

    x2d: (R, LANES_IN[bits]) f32, R a multiple of ENC_ROWS. Returns
    ``(payload2d uint8 (R, lanes_out), scale ())``; with ``scale=`` given
    the amax phase is skipped and the same scale is returned.
    """
    rows = x2d.shape[0]
    er = enc_rows()
    li, lo = lanes_in(bits), lanes_out(bits)
    assert x2d.shape[1] == li and rows % er == 0, (x2d.shape, bits)
    nb = rows // er
    xblk = pl.BlockSpec((er, li), lambda p, i: (i, 0))
    pblk = pl.BlockSpec((er, lo), lambda p, i: (i, 0))
    payload_shape = jax.ShapeDtypeStruct((rows, lo), jnp.uint8)

    if scale is not None:
        scale = jnp.asarray(scale, jnp.float32)
        payload = pl.pallas_call(
            functools.partial(_encode1_body, kind=kind, bits=bits, k=k,
                              clip_abs=clip_abs),
            grid=(1, nb),
            in_specs=[xblk, pl.BlockSpec((1,), lambda p, i: (0,))],
            out_specs=pblk,
            out_shape=payload_shape,
            interpret=interpret,
        )(x2d, scale.reshape(1))
        return payload, scale

    sblk = pl.BlockSpec((1,), lambda p, i: (0,))
    if kind == "ternary":
        body = functools.partial(_encode2_ternary_body, bits=bits,
                                 clip_abs=clip_abs)
        operands = (x2d, u2d)
        in_specs = [xblk, pl.BlockSpec((er, li), lambda p, i: (i, 0))]
    else:
        body = functools.partial(_encode2_body, kind=kind, bits=bits, k=k,
                                 clip_abs=clip_abs)
        operands = (x2d,)
        in_specs = [xblk]
    payload, scale_out = pl.pallas_call(
        body,
        grid=(2, nb),
        in_specs=in_specs,
        out_specs=[pblk, sblk],
        out_shape=[payload_shape,
                   jax.ShapeDtypeStruct((1,), jnp.float32)],
        scratch_shapes=[pltpu.SMEM((1,), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return payload, scale_out[0]


# ---------------------------------------------------------------------------
# fused decode (single launch)
# ---------------------------------------------------------------------------

def _decode_body(payload_ref, scale_ref, o_ref, *, kind, bits, k,
                 out_dtype):
    li = o_ref.shape[-1]
    codes = B.unpack_lanes(payload_ref[...], bits, li)
    o_ref[...] = _dequant(codes, scale_ref[0], kind=kind,
                          k=k).astype(out_dtype)


def _decode_lut_body(payload_ref, scale_ref, lut_ref, o_ref, *, kind,
                     bits, k, out_dtype):
    """Decode with the dequant table resident in SMEM: unpack, then one
    gather per element instead of re-evaluating exp2 on every
    lane-strided code (the 0.23x fused-log-decode regression)."""
    li = o_ref.shape[-1]
    codes = B.unpack_lanes(payload_ref[...], bits, li)
    o_ref[...] = _dequant(codes, scale_ref[0], kind=kind, k=k,
                          lut=lut_ref[...]).astype(out_dtype)


def _lut_spec():
    """Whole-table SMEM placement for a (2^bits,) f32 dequant table."""
    return pl.BlockSpec(memory_space=pltpu.SMEM)


def decode_pallas(payload2d: jax.Array, scales: jax.Array, kind: str,
                  bits: int, k: int, *, tiles_per_scale: int = 0,
                  out_dtype=jnp.float32, lut=None,
                  interpret: bool) -> jax.Array:
    """Fused unpack+dequantize, ONE ``pallas_call``.

    payload2d: (R, lanes_out(bits)) uint8. ``scales`` is either a scalar
    (per-tensor) or a (n_rows,) vector with ``tiles_per_scale`` grid
    steps per wire row (the per-source-worker scales of the dist
    channels). ``lut`` (log grid only) is the (2^bits,) scale-1 dequant
    table from ``grids.log_dequant_table``; it rides in SMEM and turns
    the dequant into a gather.
    """
    rows = payload2d.shape[0]
    er = enc_rows()
    li, lo = lanes_in(bits), lanes_out(bits)
    assert payload2d.shape[1] == lo and rows % er == 0
    nb = rows // er
    scales = jnp.asarray(scales, jnp.float32).reshape(-1)
    if tiles_per_scale:
        t = tiles_per_scale
        sspec = pl.BlockSpec((1,), lambda i: (i // t,))
    else:
        sspec = pl.BlockSpec((1,), lambda i: (0,))
    if lut is not None:
        return pl.pallas_call(
            functools.partial(_decode_lut_body, kind=kind, bits=bits, k=k,
                              out_dtype=out_dtype),
            grid=(nb,),
            in_specs=[pl.BlockSpec((er, lo), lambda i: (i, 0)), sspec,
                      _lut_spec()],
            out_specs=pl.BlockSpec((er, li), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((rows, li), out_dtype),
            interpret=interpret,
        )(payload2d, scales, jnp.asarray(lut, jnp.float32))
    return pl.pallas_call(
        functools.partial(_decode_body, kind=kind, bits=bits, k=k,
                          out_dtype=out_dtype),
        grid=(nb,),
        in_specs=[pl.BlockSpec((er, lo), lambda i: (i, 0)), sspec],
        out_specs=pl.BlockSpec((er, li), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, li), out_dtype),
        interpret=interpret,
    )(payload2d, scales)


# ---------------------------------------------------------------------------
# fused EF encode (quantize + pack + residual, single launch)
# ---------------------------------------------------------------------------

def _ef_encode_body(x_ref, scale_ref, payload_ref, e_ref, *, kind, bits,
                    k, clip_abs):
    x = x_ref[...]
    s = scale_ref[0]
    codes = _quant(x, s, None, kind=kind, k=k, clip_abs=clip_abs)
    payload_ref[...] = B.pack_lanes(codes, bits)
    e_ref[...] = x - _dequant(codes, s, kind=kind, k=k)


def _ef_encode_lut_body(x_ref, scale_ref, lut_ref, payload_ref, e_ref, *,
                        kind, bits, k, clip_abs):
    """EF encode whose residual dequant gathers from the SMEM table (the
    residual pays the same per-element exp2 as decode otherwise)."""
    x = x_ref[...]
    s = scale_ref[0]
    codes = _quant(x, s, None, kind=kind, k=k, clip_abs=clip_abs)
    payload_ref[...] = B.pack_lanes(codes, bits)
    e_ref[...] = x - _dequant(codes, s, kind=kind, k=k, lut=lut_ref[...])


def ef_encode_pallas(x2d: jax.Array, scale: jax.Array, kind: str,
                     bits: int, k: int, *, clip_abs=None, lut=None,
                     interpret: bool):
    """(x, scale) -> (packed payload, EF residual e' = x - deq(codes)),
    one launch. The codes never leave VMEM."""
    rows = x2d.shape[0]
    er = enc_rows()
    li, lo = lanes_in(bits), lanes_out(bits)
    assert x2d.shape[1] == li and rows % er == 0
    nb = rows // er
    scale = jnp.asarray(scale, jnp.float32).reshape(1)
    out_specs = [pl.BlockSpec((er, lo), lambda i: (i, 0)),
                 pl.BlockSpec((er, li), lambda i: (i, 0))]
    out_shape = [jax.ShapeDtypeStruct((rows, lo), jnp.uint8),
                 jax.ShapeDtypeStruct((rows, li), jnp.float32)]
    if lut is not None:
        return pl.pallas_call(
            functools.partial(_ef_encode_lut_body, kind=kind, bits=bits,
                              k=k, clip_abs=clip_abs),
            grid=(nb,),
            in_specs=[pl.BlockSpec((er, li), lambda i: (i, 0)),
                      pl.BlockSpec((1,), lambda i: (0,)),
                      _lut_spec()],
            out_specs=out_specs,
            out_shape=out_shape,
            interpret=interpret,
        )(x2d, scale, jnp.asarray(lut, jnp.float32))
    return pl.pallas_call(
        functools.partial(_ef_encode_body, kind=kind, bits=bits, k=k,
                          clip_abs=clip_abs),
        grid=(nb,),
        in_specs=[pl.BlockSpec((er, li), lambda i: (i, 0)),
                  pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(x2d, scale)


# ---------------------------------------------------------------------------
# fused blockwise encode (sign + per-block scale + pack, single launch)
# ---------------------------------------------------------------------------

BLOCKWISE_ROWS = 8


def _blockwise_encode_body(x_ref, payload_ref, scale_ref, *, bits):
    codes, scale = grids.blockwise_quantize(x_ref[...])
    payload_ref[...] = B.pack_lanes(codes, bits)
    scale_ref[...] = scale


def encode_blockwise_pallas(x2d: jax.Array, *, bits: int = 2,
                            interpret: bool):
    """(nb, block) f32 -> ((nb, block*bits/8) uint8 payload, (nb,)
    scales) in one launch; nb must be a multiple of BLOCKWISE_ROWS."""
    nb, block = x2d.shape
    assert nb % BLOCKWISE_ROWS == 0
    lo = block * bits // 8
    grid = nb // BLOCKWISE_ROWS
    return pl.pallas_call(
        functools.partial(_blockwise_encode_body, bits=bits),
        grid=(grid,),
        in_specs=[pl.BlockSpec((BLOCKWISE_ROWS, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((BLOCKWISE_ROWS, lo), lambda i: (i, 0)),
                   pl.BlockSpec((BLOCKWISE_ROWS,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((nb, lo), jnp.uint8),
                   jax.ShapeDtypeStruct((nb,), jnp.float32)],
        interpret=interpret,
    )(x2d)


# ---------------------------------------------------------------------------
# standalone pack/unpack kernels (generic lane widths)
# ---------------------------------------------------------------------------

def _pack_body(codes_ref, payload_ref, *, bits):
    payload_ref[...] = B.pack_lanes(codes_ref[...], bits)


def pack_pallas(codes2d: jax.Array, bits: int, *, interpret: bool):
    """(R, lanes_in) codes -> (R, lanes_out) uint8, one launch."""
    rows = codes2d.shape[0]
    er = enc_rows()
    li, lo = lanes_in(bits), lanes_out(bits)
    assert codes2d.shape[1] == li and rows % er == 0
    return pl.pallas_call(
        functools.partial(_pack_body, bits=bits),
        grid=(rows // er,),
        in_specs=[pl.BlockSpec((er, li), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((er, lo), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, lo), jnp.uint8),
        interpret=interpret,
    )(codes2d)


def _unpack_body(payload_ref, codes_ref, *, bits):
    codes_ref[...] = B.unpack_lanes(payload_ref[...], bits,
                                    codes_ref.shape[-1])


def unpack_pallas(payload2d: jax.Array, bits: int, *, interpret: bool):
    rows = payload2d.shape[0]
    er = enc_rows()
    li, lo = lanes_in(bits), lanes_out(bits)
    assert payload2d.shape[1] == lo and rows % er == 0
    dtype = jnp.int16 if bits == 16 else jnp.int8
    return pl.pallas_call(
        functools.partial(_unpack_body, bits=bits),
        grid=(rows // er,),
        in_specs=[pl.BlockSpec((er, lo), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((er, li), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, li), dtype),
        interpret=interpret,
    )(payload2d)


# ---------------------------------------------------------------------------
# historical per-op kernels (separate passes), moved here from
# repro.kernels.quantize; that module re-exports them unchanged.
# ---------------------------------------------------------------------------

def _amax_kernel(x_ref, o_ref):
    o_ref[0] = grids.block_amax(x_ref[...])


def amax_pallas(x2d: jax.Array, *, interpret: bool) -> jax.Array:
    """Per-block amax -> (grid,) partials. x2d: (R, 128), R % BLOCK_ROWS == 0."""
    rows = x2d.shape[0]
    grid = rows // BLOCK_ROWS
    partials = pl.pallas_call(
        _amax_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((grid,), jnp.float32),
        interpret=interpret,
    )(x2d)
    return jnp.max(partials)


def _log_quantize_kernel(x_ref, scale_ref, codes_ref, *, k_g: int):
    codes_ref[...] = grids.log_quantize(x_ref[...], scale_ref[0], k_g)


def log_quantize_pallas(x2d: jax.Array, scale: jax.Array, k_g: int,
                        *, interpret: bool) -> jax.Array:
    rows = x2d.shape[0]
    grid = rows // BLOCK_ROWS
    return pl.pallas_call(
        functools.partial(_log_quantize_kernel, k_g=k_g),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int8),
        interpret=interpret,
    )(x2d, scale.reshape(1))


def _log_dequantize_kernel(codes_ref, scale_ref, o_ref, *, k_g: int,
                           out_dtype):
    o_ref[...] = grids.log_dequantize(
        codes_ref[...], scale_ref[0], k_g).astype(out_dtype)


def log_dequantize_pallas(codes2d: jax.Array, scale: jax.Array, k_g: int,
                          *, out_dtype=jnp.float32, interpret: bool) -> jax.Array:
    rows = codes2d.shape[0]
    grid = rows // BLOCK_ROWS
    return pl.pallas_call(
        functools.partial(_log_dequantize_kernel, k_g=k_g, out_dtype=out_dtype),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), out_dtype),
        interpret=interpret,
    )(codes2d, scale.reshape(1))


def _uniform_quantize_kernel(x_ref, scale_ref, codes_ref, *, k_x: int):
    codes_ref[...] = grids.uniform_quantize(x_ref[...], scale_ref[0], k_x)


def uniform_quantize_pallas(x2d: jax.Array, scale: jax.Array, k_x: int,
                            *, interpret: bool) -> jax.Array:
    """Codes dtype follows the grid width: int8 for k_x <= 6, int16 above
    (codes reach +/- 2^k_x, which overflows int8 at k_x = 7)."""
    rows = x2d.shape[0]
    grid = rows // BLOCK_ROWS
    return pl.pallas_call(
        functools.partial(_uniform_quantize_kernel, k_x=k_x),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES),
                                       grids.uniform_code_dtype(k_x)),
        interpret=interpret,
    )(x2d, scale.reshape(1))


def _uniform_dequantize_kernel(codes_ref, scale_ref, o_ref, *, k_x: int,
                               out_dtype):
    o_ref[...] = grids.uniform_dequantize(
        codes_ref[...], scale_ref[0], k_x).astype(out_dtype)


def uniform_dequantize_pallas(codes2d: jax.Array, scale: jax.Array, k_x: int,
                              *, out_dtype=jnp.float32,
                              interpret: bool) -> jax.Array:
    rows = codes2d.shape[0]
    grid = rows // BLOCK_ROWS
    return pl.pallas_call(
        functools.partial(_uniform_dequantize_kernel, k_x=k_x,
                          out_dtype=out_dtype),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), out_dtype),
        interpret=interpret,
    )(codes2d, scale.reshape(1))


def _ternary_quantize_kernel(x_ref, u_ref, scale_ref, codes_ref):
    codes_ref[...] = grids.ternary_quantize(x_ref[...], u_ref[...],
                                            scale_ref[0])


def ternary_quantize_pallas(x2d: jax.Array, u2d: jax.Array,
                            scale: jax.Array, *, interpret: bool) -> jax.Array:
    """TernGrad codes from pre-drawn uniforms (stochastic rounding bits are
    generated outside so the jnp backend sees identical draws)."""
    rows = x2d.shape[0]
    grid = rows // BLOCK_ROWS
    blk = lambda: pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _ternary_quantize_kernel,
        grid=(grid,),
        in_specs=[blk(), blk(), pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=blk(),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int8),
        interpret=interpret,
    )(x2d, u2d, scale.reshape(1))


def _blockwise_quantize_kernel(x_ref, codes_ref, scale_ref):
    codes, scale = grids.blockwise_quantize(x_ref[...])
    codes_ref[...] = codes
    scale_ref[...] = scale


def blockwise_quantize_pallas(x2d: jax.Array, *, interpret: bool):
    """(nb, block) -> (sign codes, per-block scales). The block dim rides
    the lane axis whole (one EF block per sublane row); nb must be a
    multiple of BLOCKWISE_ROWS (the engine pads with zero rows)."""
    nb, block = x2d.shape
    grid = nb // BLOCKWISE_ROWS
    codes, scales = pl.pallas_call(
        _blockwise_quantize_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((BLOCKWISE_ROWS, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((BLOCKWISE_ROWS, block), lambda i: (i, 0)),
                   pl.BlockSpec((BLOCKWISE_ROWS,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((nb, block), jnp.int8),
                   jax.ShapeDtypeStruct((nb,), jnp.float32)],
        interpret=interpret,
    )(x2d)
    return codes, scales
