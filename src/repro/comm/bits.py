"""Bit-lane packing: the byte layout every wire, residency, and checkpoint
payload in the repo ships.

Supported lane widths are ``SUPPORTED_BITS`` = (2, 3, 4, 6, 8, 16). The
odd widths pack across byte boundaries in *groups*: ``lcm(bits, 8)`` bits
of codes become whole bytes, so a group of ``group_codes(bits)`` codes
maps to ``group_nbytes(bits)`` bytes (3-bit: 8 codes -> 3 bytes; 6-bit:
4 codes -> 3 bytes). For the widths that divide 8 this degenerates to the
historical ``repro.core.packing`` layout byte-for-byte (little-endian
shifts within the byte, signed codes biased by ``2^(bits-1)``); 8-bit
lanes are the two's-complement int8 view, 16-bit lanes the little-endian
int16 view.

Everything here is pure jnp arithmetic (no dtype views), so the *same*
functions run inside the fused Pallas kernel bodies
(``repro.comm.kernels``) and in the jnp reference backend - the two
backends cannot drift.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

SUPPORTED_BITS = (2, 3, 4, 6, 8, 16)


def group_codes(bits: int) -> int:
    """Codes per whole-byte packing group: lcm(bits, 8) / bits."""
    return math.lcm(bits, 8) // bits


def group_nbytes(bits: int) -> int:
    """Bytes per packing group: lcm(bits, 8) / 8."""
    return math.lcm(bits, 8) // 8


def payload_nbytes(numel: int, bits: int) -> int:
    """Exact payload bytes for ``numel`` codes at a lane width: whole
    groups only (the tail group is padded with zero codes). Pure
    accounting - any positive width is accepted (the analytic 'Comm'
    tables quote 1-bit sign and 32-bit f32 rows); actual pack/unpack is
    restricted to SUPPORTED_BITS."""
    if bits <= 0:
        raise ValueError(f"bits={bits} must be positive")
    g, b = group_codes(bits), group_nbytes(bits)
    return -(-int(numel) // g) * b


def lane_bits_for(max_abs_code: int) -> int:
    """Smallest supported lane whose signed range [-(2^(b-1)),
    2^(b-1)-1] holds codes with |c| <= max_abs_code."""
    for b in SUPPORTED_BITS:
        if max_abs_code <= 2 ** (b - 1) - 1:
            return b
    raise ValueError(f"codes of magnitude {max_abs_code} exceed 16 bits")


def _bias(bits: int) -> int:
    # <8-bit lanes use the historical biased-unsigned layout; 8/16-bit
    # lanes are two's complement (byte-identical to an int8/int16 view).
    return (1 << (bits - 1)) if bits < 8 else 0


def pack_lanes(codes2d: jax.Array, bits: int) -> jax.Array:
    """(R, L) signed int codes -> (R, L*bits/8) uint8, each row packed
    independently. L must be a multiple of group_codes(bits)."""
    if bits not in SUPPORTED_BITS:
        raise ValueError(f"bits={bits} not in {SUPPORTED_BITS}")
    rows, L = codes2d.shape
    g, nb = group_codes(bits), group_nbytes(bits)
    assert L % g == 0, (L, g)
    u = codes2d.astype(jnp.int32) + _bias(bits)
    if bits == 16:
        u = u & 0xFFFF
        out = jnp.stack([u & 0xFF, (u >> 8) & 0xFF], axis=-1)
        return out.reshape(rows, 2 * L).astype(jnp.uint8)
    if bits == 8:
        return (u & 0xFF).astype(jnp.uint8)
    grp = u.reshape(rows, L // g, g)
    val = jnp.zeros((rows, L // g), jnp.int32)
    for j in range(g):  # <= 24 bits per group, fits int32
        val = val | (grp[:, :, j] << (j * bits))
    out = jnp.stack([(val >> (8 * b)) & 0xFF for b in range(nb)], axis=-1)
    return out.reshape(rows, (L // g) * nb).astype(jnp.uint8)


def unpack_lanes(payload2d: jax.Array, bits: int, L: int) -> jax.Array:
    """Inverse of pack_lanes -> (R, L) codes (int8, or int16 for 16-bit
    lanes)."""
    if bits not in SUPPORTED_BITS:
        raise ValueError(f"bits={bits} not in {SUPPORTED_BITS}")
    rows = payload2d.shape[0]
    g, nb = group_codes(bits), group_nbytes(bits)
    u = payload2d.astype(jnp.int32)
    if bits == 16:
        pair = u.reshape(rows, L, 2)
        val = pair[:, :, 0] | (pair[:, :, 1] << 8)
        return (((val + 0x8000) & 0xFFFF) - 0x8000).astype(jnp.int16)
    if bits == 8:
        return (((u + 0x80) & 0xFF) - 0x80).astype(jnp.int8)
    grp = u.reshape(rows, L // g, nb)
    val = jnp.zeros((rows, L // g), jnp.int32)
    for b in range(nb):
        val = val | (grp[:, :, b] << (8 * b))
    mask = (1 << bits) - 1
    cols = [((val >> (j * bits)) & mask) - _bias(bits) for j in range(g)]
    return jnp.stack(cols, axis=-1).reshape(rows, L).astype(jnp.int8)


# ---------------------------------------------------------------------------
# flat / row-chunked views (the shapes the wire and residency paths use)
# ---------------------------------------------------------------------------

def pack_flat(codes: jax.Array, bits: int) -> jax.Array:
    """Any-shape codes -> flat uint8 payload of payload_nbytes(numel)."""
    flat = codes.reshape(-1)
    numel = flat.shape[0]
    g = group_codes(bits)
    pad = (-numel) % g
    flat = jnp.pad(flat, (0, pad))
    return pack_lanes(flat.reshape(1, -1), bits).reshape(-1)


def unpack_flat(payload: jax.Array, bits: int, numel: int) -> jax.Array:
    """Inverse of pack_flat -> (numel,) codes."""
    g = group_codes(bits)
    padded = -(-numel // g) * g
    return unpack_lanes(payload.reshape(1, -1), bits, padded)[0, :numel]


def pack_rows(codes_rows: jax.Array, bits: int) -> jax.Array:
    """(n_rows, c) codes -> (n_rows, payload_nbytes(c)) uint8; each row
    packed independently so chunk boundaries stay byte-aligned on the
    wire (the all_to_all moves whole rows)."""
    n_rows, c = codes_rows.shape
    g = group_codes(bits)
    pad = (-c) % g
    rows = jnp.pad(codes_rows, ((0, 0), (0, pad)))
    return pack_lanes(rows, bits)


def unpack_rows(payload_rows: jax.Array, bits: int, c: int) -> jax.Array:
    """Inverse of pack_rows -> (n_rows, c) codes."""
    g = group_codes(bits)
    padded = -(-c // g) * g
    return unpack_lanes(payload_rows, bits, padded)[:, :c]


def pad_rows(x: jax.Array, n_rows: int) -> jax.Array:
    """Flatten and zero-pad into (n_rows, ceil(numel/n_rows)) ownership
    rows (the worker-chunk layout of Algorithm 2)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    c = -(-n // n_rows)
    return jnp.pad(flat, (0, n_rows * c - n)).reshape(n_rows, c)


def packed_nbytes(numel: int, bits: int) -> int:
    """Compat alias (the historical ``repro.core.packing`` name)."""
    return payload_nbytes(numel, bits)
