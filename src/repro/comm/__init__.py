"""repro.comm - the single compression subsystem.

Everything that quantizes, packs, or accounts for bytes goes through
here: the dist wire channels, serve residency, checkpoint compression,
and the ``repro.core.quantizers`` / ``repro.kernels`` compat shims.

  * :mod:`repro.comm.bits`    - lane packing math (2/3/4/6/8/16-bit)
  * :mod:`repro.comm.kernels` - fused single-launch Pallas kernels
  * :mod:`repro.comm.codec`   - the Codec registry + WireBuffer
  * :mod:`repro.comm.matmul`  - fused dequant-matmul (code-resident serving)
"""
from repro.comm.bits import (  # noqa: F401
    SUPPORTED_BITS,
    pack_flat,
    pack_lanes,
    pack_rows,
    packed_nbytes,
    pad_rows,
    payload_nbytes,
    unpack_flat,
    unpack_lanes,
    unpack_rows,
)
from repro.comm.codec import (  # noqa: F401
    BACKENDS,
    BlockwiseCodec,
    Codec,
    CODEC_NAMES,
    IdentityCodec,
    LogCodec,
    TernaryCodec,
    UniformCodec,
    WireBuffer,
    uniform_wire_codec,
    decode_rows,
    encode_rows,
    encode_rows_ef,
    get_codec,
    resolve_backend,
)
from repro.comm.matmul import (  # noqa: F401
    dequant_matmul,
    mm_cols,
    set_mm_cols,
)
