"""Fused dequant-matmul for code-resident Q_x weights (the serving hot
path).

``QuantizedLeaf.dequantize()`` runs unpack + dequant as a separate
memory-bound pass that materializes the full fp32 weight tensor before
every projection. Here the contraction consumes the codes directly: the
Pallas kernel tiles the OUTPUT columns, loads one packed code tile +
the scale per grid step, unpacks and dequantizes in VMEM (sub-8-bit
lanes gather from the PR-6 style SMEM dequant table instead of
re-deriving values per element), and feeds the tile straight into
``jnp.dot`` - the fp32 weight tensor never exists in HBM.

Bit-exactness contract (asserted by ``tests/test_comm_matmul.py``):
every backend returns *exactly* ``x @ leaf.dequantize().astype(dt)``.
Two properties make that cheap to guarantee:

  * tiling only the output columns keeps each output element's
    k-reduction identical to the full dot (column tiles of a dot equal
    the corresponding columns of the whole dot; splitting K would
    reorder the accumulation and is therefore never done);
  * uniform dequant is ``(codes / 2^k) * scale`` - the division is an
    exact power of two, so the SMEM table (scale-1 values) followed by
    one multiply rounds identically to the elementwise form.

Backend dispatch mirrors ``repro.comm.codec``: Pallas on TPU for
covered shapes, the jnp reference (one fused XLA program) everywhere
else, and an explicit ``backend=`` always wins ("pallas" off TPU runs
in interpret mode). Shapes the kernel doesn't cover - output width not
a multiple of the tile, 1-element tiles, oversized activations - fall
back to dequantize-then-matmul inside the same jit.

``mm_cols()`` is the per-backend output-tile width;
``repro.perf.autotune.tune_mm_cols`` measures candidates and installs
the winner via ``set_mm_cols``, exactly like ``tune_enc_rows`` does for
the codec kernels.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.comm import bits as B
from repro.comm import codec as C
from repro.opt import grids

# output columns per grid step (one N-tile of the result). 128 keeps the
# packed tile a whole number of VREG lanes at every supported lane width
# (group sizes divide it) and matches the MXU column width.
MM_COLS = 128

# per-backend tile-width override (autotuning hook), same shape as
# kernels._ENC_ROWS_OVERRIDE: ``repro.perf.autotune.tune_mm_cols``
# installs the measured winner for ``jax.default_backend()``.
_MM_COLS_OVERRIDE: dict = {}

# activations taller than this skip the Pallas path (the kernel holds
# the whole (M, K) activation in VMEM for every grid step)
_MAX_FUSED_ROWS = 1024


def mm_cols() -> int:
    """Output columns per fused dequant-matmul grid step."""
    return _MM_COLS_OVERRIDE.get(jax.default_backend(), MM_COLS)


def set_mm_cols(cols, backend: Optional[str] = None) -> None:
    """Install (or, with ``cols=None``, clear) the output-tile width for
    ``backend`` (default: the active one). Must be a positive multiple
    of 128 so packed tiles stay whole byte groups and whole VREGs."""
    key = backend or jax.default_backend()
    if cols is None:
        _MM_COLS_OVERRIDE.pop(key, None)
        return
    if cols % 128 != 0 or cols <= 0:
        raise ValueError(f"mm_cols must be a positive multiple of 128: {cols}")
    _MM_COLS_OVERRIDE[key] = int(cols)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# dequant helpers (both backends call the same repro.opt.grids math)
# ---------------------------------------------------------------------------

def _dequant_codes(codes, scale, *, k_x, w_dtype, cast_dtype, lut=None):
    """Signed codes -> weights, replicating the unfused cast chain
    ``dequantize() -> .astype(leaf.dtype) -> .astype(x.dtype)`` exactly
    (collapsing it would change values when the leaf dtype is narrower
    than the activation dtype)."""
    if lut is not None:
        w = grids.dequantize_lut(codes, scale, lut)
    else:
        w = grids.uniform_dequantize(codes, scale, k_x)
    w = w.astype(jnp.dtype(w_dtype))
    if cast_dtype is not None:
        w = w.astype(jnp.dtype(cast_dtype))
    return w


def _unpack_tile(codes, pack_bits, n):
    if pack_bits:
        return B.unpack_lanes(codes, pack_bits, n)
    return codes


# ---------------------------------------------------------------------------
# jnp reference backend (and universal fallback): dequantize-then-matmul
# in ONE jit program - the oracle the Pallas kernel must match bitwise
# ---------------------------------------------------------------------------

def _matmul_jnp(x2, codes, scale, *, k_x, pack_bits, n, w_dtype,
                cast_dtype, transpose):
    full = B.unpack_rows(codes, pack_bits, n) if pack_bits else codes
    w = _dequant_codes(full, scale, k_x=k_x, w_dtype=w_dtype,
                       cast_dtype=cast_dtype)
    return x2 @ (w.T if transpose else w)


# ---------------------------------------------------------------------------
# Pallas kernels: grid over output-column tiles, full (M, K) activation
# and one code tile per step; codes never leave VMEM unpacked
# ---------------------------------------------------------------------------

def _mm_body(x_ref, codes_ref, scale_ref, o_ref, *, k_x, pack_bits,
             w_dtype, cast_dtype):
    """One output tile: unpack + dequant the code tile, one MXU dot."""
    codes = _unpack_tile(codes_ref[...], pack_bits, o_ref.shape[-1])
    w = _dequant_codes(codes, scale_ref[0], k_x=k_x, w_dtype=w_dtype,
                       cast_dtype=cast_dtype)
    o_ref[...] = jnp.dot(x_ref[...], w)


def _mm_lut_body(x_ref, codes_ref, scale_ref, lut_ref, o_ref, *, k_x,
                 pack_bits, w_dtype, cast_dtype):
    """Sub-8-bit lanes: dequant gathers from the SMEM scale-1 table (the
    PR-6 ``dequant_lut`` pattern) instead of per-element arithmetic."""
    codes = _unpack_tile(codes_ref[...], pack_bits, o_ref.shape[-1])
    w = _dequant_codes(codes, scale_ref[0], k_x=k_x, w_dtype=w_dtype,
                       cast_dtype=cast_dtype, lut=lut_ref[...])
    o_ref[...] = jnp.dot(x_ref[...], w)


def _mm_t_body(x_ref, codes_ref, scale_ref, o_ref, *, k_x, pack_bits, n,
               w_dtype, cast_dtype):
    """Transposed orientation (``x @ W.T``, tied embedding heads): the
    grid tiles code ROWS; each step contracts x against a row tile of
    the dequantized weight (= a column tile of W.T)."""
    codes = _unpack_tile(codes_ref[...], pack_bits, n)
    w = _dequant_codes(codes, scale_ref[0], k_x=k_x, w_dtype=w_dtype,
                       cast_dtype=cast_dtype)
    o_ref[...] = jax.lax.dot_general(x_ref[...], w,
                                     (((1,), (1,)), ((), ())))


def _lut_spec():
    return pl.BlockSpec(memory_space=pltpu.SMEM)


def _matmul_pallas(x2, codes, scale, *, k_x, pack_bits, n, w_dtype,
                   cast_dtype, transpose, interpret):
    M, K = x2.shape
    tile = mm_cols()
    scale = jnp.asarray(scale, jnp.float32).reshape(1)
    out_dtype = jnp.result_type(x2.dtype,
                                jnp.dtype(cast_dtype or w_dtype))
    xspec = pl.BlockSpec((M, K), lambda i: (0, 0))
    sspec = pl.BlockSpec((1,), lambda i: (0,))
    if transpose:
        rows = codes.shape[0]
        cspec = pl.BlockSpec((tile, codes.shape[1]), lambda i: (i, 0))
        body = functools.partial(_mm_t_body, k_x=k_x, pack_bits=pack_bits,
                                 n=n, w_dtype=w_dtype, cast_dtype=cast_dtype)
        return pl.pallas_call(
            body,
            grid=(rows // tile,),
            in_specs=[xspec, cspec, sspec],
            out_specs=pl.BlockSpec((M, tile), lambda i: (0, i)),
            out_shape=jax.ShapeDtypeStruct((M, rows), out_dtype),
            interpret=interpret,
        )(x2, codes, scale)
    # normal orientation: tile the n output columns; a tile of `tile`
    # codes is `tile * bits / 8` payload bytes (tile is a multiple of
    # every group size, so tiles land on byte-group boundaries)
    cw = tile * pack_bits // 8 if pack_bits else tile
    cspec = pl.BlockSpec((K, cw), lambda i: (0, i))
    operands = [x2, codes, scale]
    in_specs = [xspec, cspec, sspec]
    if pack_bits:
        body = functools.partial(_mm_lut_body, k_x=k_x, pack_bits=pack_bits,
                                 w_dtype=w_dtype, cast_dtype=cast_dtype)
        in_specs.append(_lut_spec())
        operands.append(jnp.asarray(
            grids.uniform_dequant_table(k_x, pack_bits), jnp.float32))
    else:
        body = functools.partial(_mm_body, k_x=k_x, pack_bits=pack_bits,
                                 w_dtype=w_dtype, cast_dtype=cast_dtype)
    return pl.pallas_call(
        body,
        grid=(n // tile,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((M, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((M, n), out_dtype),
        interpret=interpret,
    )(*operands)


def _pallas_covers(x2, codes, *, pack_bits, n, transpose) -> bool:
    tile = mm_cols()
    if x2.shape[0] > _MAX_FUSED_ROWS:
        return False
    if transpose:
        return codes.shape[0] % tile == 0
    if n % tile != 0:
        return False
    # packed rows carry tail-group padding only when n isn't a whole
    # number of groups; n % tile == 0 already guarantees alignment
    return True


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def dequant_matmul(x, codes, scale, *, k_x: int, n: int, pack_bits: int = 0,
                   w_dtype: str = "float32", cast_dtype: Optional[str] = None,
                   transpose: bool = False,
                   backend: Optional[str] = None) -> jax.Array:
    """``x @ W`` (or ``x @ W.T``) where W exists only as integer codes.

    x: (..., K) activations ((..., d) against code rows for
        ``transpose=True``).
    codes: (K, payload|n) - packed uint8 rows (``pack_bits`` set) or raw
        int8/int16 codes; for ``transpose`` the roles flip ((rows, ...)
        codes contract along their unpacked width).
    scale: per-tensor () amax scale (per-layer stacks are vmapped by the
        caller, one scalar per layer).
    n: the LOGICAL last-dim length of the weight (the codes' aux shape -
        packed payloads and scan-sliced stacked leaves can't tell).
    w_dtype / cast_dtype: the leaf's dtype and the pending ``astype``
        target - the unfused cast chain, replicated exactly.

    Bitwise identical to ``x @ dequantize-then-cast`` on every backend.
    """
    lead = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    bk = C.resolve_backend(backend, codes.size, tile=x2.shape[1] * mm_cols())
    kw = dict(k_x=k_x, pack_bits=pack_bits, n=n, w_dtype=w_dtype,
              cast_dtype=cast_dtype, transpose=transpose)
    if bk == "pallas" and _pallas_covers(x2, codes, pack_bits=pack_bits,
                                         n=n, transpose=transpose):
        out2 = _matmul_pallas(x2, codes, scale, interpret=_interpret(), **kw)
    else:
        out2 = _matmul_jnp(x2, codes, scale, **kw)
    return out2.reshape(lead + (out2.shape[-1],))
