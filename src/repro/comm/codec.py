"""The codec registry: one compression stack behind every wire,
residency, and checkpoint path.

A :class:`Codec` is a named, registrable compression operator carrying

  * ``encode(x) -> WireBuffer``   (fused amax + quantize + bit-pack)
  * ``decode(wb) -> x_hat``       (fused unpack + dequantize)
  * exact byte accounting: ``payload_nbytes`` (packed codes only - what
    the collectives move and what ``comm_bytes_per_step`` counts) and
    ``wire_nbytes`` (payload + f32 scale side-channel - what a resident
    or checkpointed buffer actually occupies)
  * ``bits``: the packed lane width per element (see ``repro.comm.bits``)

plus the code-level primitives (``compute_scale`` / ``quantize`` /
``dequantize``) the thin shims in ``repro.core.quantizers`` and the
in-kernel bodies share. Backends: ``backend="jnp"`` is the reference
path (canonical ``repro.opt.grids`` math + ``repro.comm.bits`` packing
under one XLA fusion); ``backend="pallas"`` runs the fused single-launch
kernels in ``repro.comm.kernels`` (interpret mode off TPU) whose bodies
call the *same* functions, so payloads and scales are bit-identical;
``backend=None`` picks Pallas on TPU for tile-sized tensors.

Row-chunked entry points (``encode_rows`` / ``encode_rows_ef`` /
``decode_rows``) emit the worker-ownership layout of Algorithm 2: each
of ``n_rows`` chunks packs to a byte-aligned payload row, which is
exactly the array ``repro.dist.collectives`` moves - no unpacked code
tensor is materialized between quantize and the wire.

Registry specs: ``none|identity|fp32``, ``log:k``, ``uniform:k``,
``uniform_amax:k``, ``terngrad|ternary``, ``blockwise:b``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import bits as B
from repro.comm import kernels as K
from repro.opt import grids

BACKENDS = ("jnp", "pallas")


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def resolve_backend(backend: Optional[str], numel: Optional[int] = None,
                    tile: Optional[int] = None) -> str:
    """Auto: Pallas on TPU when the tensor fills at least one kernel tile
    (padding overhead dominates below that), jnp otherwise. An explicit
    ``backend=`` always wins - "pallas" off TPU runs in interpret mode."""
    if backend is not None:
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; "
                             f"expected one of {BACKENDS}")
        return backend
    if tile is None:
        tile = K.enc_rows() * K.LANES
    if jax.default_backend() == "tpu" and (numel is None or numel >= tile):
        return "pallas"
    return "jnp"


# ---------------------------------------------------------------------------
# wire buffer (the pytree the channels/residency/checkpoints hold)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class WireBuffer:
    """One tensor in wire form: packed uint8 payload + f32 scale(s).

    payload: uint8, ``codec.payload_nbytes(numel)`` bytes (flat) or
        ``(n_rows, payload_nbytes(c))`` for row-chunked buffers.
    scale: () per-tensor, or (nb,) per-block (blockwise codec).
    spec/shape: static - the codec spec string and the logical element
        shape, enough to decode without outside context.
    """

    payload: jax.Array
    scale: jax.Array
    spec: str = dataclasses.field(metadata=dict(static=True))
    shape: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True))

    def tree_flatten(self):
        return (self.payload, self.scale), (self.spec, self.shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        payload, scale = children
        spec, shape = aux
        return cls(payload=payload, scale=scale, spec=spec, shape=shape)

    @property
    def numel(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    @property
    def bits(self) -> int:
        return get_codec(self.spec).bits

    @property
    def nbytes(self) -> int:
        """Actual buffer bytes (payload + scales)."""
        return int(self.payload.nbytes) + int(self.scale.nbytes)

    def decode(self, *, backend: Optional[str] = None,
               out_dtype=jnp.float32) -> jax.Array:
        return get_codec(self.spec).decode(self, backend=backend,
                                           out_dtype=out_dtype)


# ---------------------------------------------------------------------------
# tiling helpers (pad to the fused kernels' (R, LANES_IN[bits]) layout)
# ---------------------------------------------------------------------------

def _tile_rows(n: int, bits: int) -> int:
    """Rows of the (R, lanes_in) tiling covering n elements."""
    li = K.lanes_in(bits)
    er = K.enc_rows()
    return -(-n // (er * li)) * er


def _to_tiles(flat: jax.Array, bits: int) -> jax.Array:
    li = K.lanes_in(bits)
    rows = _tile_rows(flat.shape[0], bits)
    pad = rows * li - flat.shape[0]
    return jnp.pad(flat, (0, pad)).reshape(rows, li)


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Codec:
    """Base: a scalar-scale grid codec (log / uniform / ternary)."""

    name = "base"
    kind = "base"          # fused-kernel dispatch key
    stochastic = False

    # -- static facts ------------------------------------------------------
    @property
    def spec(self) -> str:
        raise NotImplementedError

    @property
    def bits(self) -> int:
        """Packed payload bits per element (the wire lane width)."""
        raise NotImplementedError

    @property
    def k(self) -> int:
        """Grid parameter forwarded to the kernels (k_g / k_x)."""
        return 0

    @property
    def clip_abs(self) -> Optional[int]:
        """Clip codes to +/- this before packing (None = exact lanes)."""
        return None

    @property
    def static_scale(self) -> Optional[float]:
        """Data-independent scale (the paper's absolute Q_x grid), or
        None when the scale is an amax pass over the tensor."""
        return None

    # -- byte accounting ---------------------------------------------------
    def scale_numel(self, numel: int) -> int:
        return 1

    def payload_nbytes(self, numel: int) -> int:
        """Exact packed-code bytes (what the collectives move; scale
        side-channels excluded - see ``wire_nbytes``)."""
        return B.payload_nbytes(numel, self.bits)

    def wire_nbytes(self, numel: int) -> int:
        """Exact total buffer bytes: payload + f32 scales."""
        return self.payload_nbytes(numel) + 4 * self.scale_numel(numel)

    # -- code-level primitives (shared with QTensor shims and kernels) ----
    def compute_scale(self, x: jax.Array) -> jax.Array:
        if self.static_scale is not None:
            return jnp.float32(self.static_scale)
        return grids.amax_scale(x)

    def quantize(self, x: jax.Array, scale, *, u=None) -> jax.Array:
        codes = K._quant(x.astype(jnp.float32), scale, u, kind=self.kind,
                         k=self.k, clip_abs=self.clip_abs)
        return codes

    def dequant_lut(self):
        """(2^bits,) scale-1 dequant table for table-driven decode, or
        None for grids whose dequant is already a single multiply
        (uniform/ternary/blockwise: ``codes * scale``, no transcendental
        to amortize — evaluated and deliberately left table-free)."""
        return None

    def dequantize(self, codes: jax.Array, scale) -> jax.Array:
        return K._dequant(codes, scale, kind=self.kind, k=self.k,
                          lut=self.dequant_lut())

    # -- fused encode/decode ----------------------------------------------
    def _draw(self, key, shape):
        if not self.stochastic:
            return None
        assert key is not None, f"{self.name} codec is stochastic; pass key="
        return jax.random.uniform(key, shape)

    def encode(self, x: jax.Array, *, key=None,
               backend: Optional[str] = None) -> WireBuffer:
        """Fused amax+quantize+pack -> :class:`WireBuffer` (one kernel
        launch on the Pallas backend). Jitted whole, like the engine
        entry points: eager-vs-compiled float rounding (FMA contraction)
        would otherwise break the backend bit-parity contract."""
        if self.stochastic and key is None:
            raise ValueError(f"{self.name} codec is stochastic; pass key=")
        key = key if key is not None else jax.random.PRNGKey(0)
        return _encode_jit(x, key, codec=self, backend=backend)

    def decode(self, wb: WireBuffer, *, backend: Optional[str] = None,
               out_dtype=jnp.float32) -> jax.Array:
        return _decode_jit(wb, codec=self, backend=backend,
                           out_dtype=jnp.dtype(out_dtype).name)

    def _encode_impl(self, x: jax.Array, *, key,
                     backend: Optional[str]) -> WireBuffer:
        shape = tuple(x.shape)
        flat = x.reshape(-1).astype(jnp.float32)
        n = flat.shape[0]
        u = self._draw(key, flat.shape)
        if resolve_backend(backend, n) == "jnp":
            scale = self.compute_scale(flat)
            codes = self.quantize(flat, scale, u=u)
            # fence codes off from the packer: the lane packer reads G
            # strided slices of them, and XLA loop fusion would
            # otherwise duplicate the (transcendental) quantize work
            # into every slice read - measured 2x slower on CPU
            codes = jax.lax.optimization_barrier(codes)
            payload = B.pack_flat(codes, self.bits)
            return WireBuffer(payload=payload, scale=scale,
                              spec=self.spec, shape=shape)
        x2d = _to_tiles(flat, self.bits)
        u2d = _to_tiles(u, self.bits) if u is not None else None
        payload2d, scale = K.encode_pallas(
            x2d, self.kind, self.bits, self.k,
            scale=(None if self.static_scale is None
                   else jnp.float32(self.static_scale)),
            u2d=u2d, clip_abs=self.clip_abs, interpret=_interpret())
        payload = payload2d.reshape(-1)[:self.payload_nbytes(n)]
        return WireBuffer(payload=payload, scale=scale, spec=self.spec,
                          shape=shape)

    def _decode_impl(self, wb: WireBuffer, *, backend: Optional[str] = None,
                     out_dtype=jnp.float32) -> jax.Array:
        n = wb.numel
        if resolve_backend(backend, n) == "jnp":
            codes = B.unpack_flat(wb.payload, self.bits, n)
            return self.dequantize(codes, wb.scale).astype(
                out_dtype).reshape(wb.shape)
        lo = K.lanes_out(self.bits)
        rows = _tile_rows(n, self.bits)
        pad = rows * lo - wb.payload.shape[0]
        p2d = jnp.pad(wb.payload, (0, pad)).reshape(rows, lo)
        out = K.decode_pallas(p2d, wb.scale, self.kind, self.bits, self.k,
                              out_dtype=out_dtype, lut=self.dequant_lut(),
                              interpret=_interpret())
        return out.reshape(-1)[:n].reshape(wb.shape)


@dataclasses.dataclass(frozen=True)
class LogCodec(Codec):
    """The paper's Q_g: log grid, per-tensor amax scale. Codes live in
    [-(k_g+1), k_g+1] and pack to the smallest lane holding them."""

    k_g: int = 6
    name = "log"
    kind = "log"

    @property
    def spec(self):
        return f"log:{self.k_g}"

    @property
    def bits(self):
        return B.lane_bits_for(self.k_g + 1)

    @property
    def k(self):
        return self.k_g

    def dequant_lut(self):
        # 2k_g+3 representable values: decode is a gather, not an exp2
        # per element (the PR-5 0.23x fused-log-decode regression).
        return grids.log_dequant_table(self.k_g, self.bits)


@dataclasses.dataclass(frozen=True)
class UniformCodec(Codec):
    """The paper's Q_x: uniform grid over [-scale, scale].

    ``absolute=True`` pins scale = 0.5 (Assumption 3's additive grid);
    ``absolute=False`` uses a per-tensor amax scale. Codes reach
    +/- 2^k_x; by default they pack exactly into the next lane up
    (residency / QTensor semantics). ``wire_bits`` pins a narrower lane
    and clips the out-of-range extreme codes into it - the historical
    int8-wire semantics (``k_x=7`` rides 8-bit lanes at +/-127); see
    :func:`uniform_wire_codec` for the broadcast channel's choice."""

    k_x: int = 7
    absolute: bool = True
    wire_bits: Optional[int] = None
    name = "uniform"
    kind = "uniform"

    def __post_init__(self):
        if self.wire_bits is not None:
            assert self.wire_bits in B.SUPPORTED_BITS, self.wire_bits

    @property
    def spec(self):
        base = "uniform" if self.absolute else "uniform_amax"
        suffix = f":w{self.wire_bits}" if self.wire_bits else ""
        return f"{base}:{self.k_x}{suffix}"

    @property
    def bits(self):
        if self.wire_bits is not None:
            return self.wire_bits
        return B.lane_bits_for(2 ** self.k_x)

    @property
    def k(self):
        return self.k_x

    @property
    def clip_abs(self):
        top = 2 ** (self.bits - 1) - 1
        return top if 2 ** self.k_x > top else None

    @property
    def static_scale(self):
        return 0.5 if self.absolute else None


def uniform_wire_codec(k_x: int, absolute: bool = True) -> UniformCodec:
    """The weight-broadcast wire's Q_x lanes: the smallest lane whose
    clipped range loses only the two extreme codes (+/- 2^k_x -> the lane
    edge) - k_x=7 rides 8-bit lanes at +/-127 (the historical int8
    wire), k_x=3 rides 4-bit lanes."""
    return UniformCodec(k_x=k_x, absolute=absolute,
                        wire_bits=B.lane_bits_for(2 ** k_x - 1))


@dataclasses.dataclass(frozen=True)
class TernaryCodec(Codec):
    """TernGrad: unbiased stochastic ternary {-1, 0, +1}, 2-bit lanes."""

    name = "terngrad"
    kind = "ternary"
    stochastic = True

    @property
    def spec(self):
        return "terngrad"

    @property
    def bits(self):
        return 2


@dataclasses.dataclass(frozen=True)
class BlockwiseCodec(Codec):
    """Zheng et al. '19: sign codes + per-block mean-|.| scales.

    Deliberately outside the ``encode_rows``/``decode_rows`` contract:
    those assume one scale per source row, while blockwise scales ride a
    per-block side-channel whose decode slicing depends on the receiving
    worker's chunk OFFSET - mesh state, not codec state. The ef_sgd mode
    packs its rows through ``comm.pack_rows`` at this codec's lane width
    and handles the scale columns itself."""

    block: int = 256
    name = "blockwise"
    kind = "blockwise"

    @property
    def spec(self):
        return f"blockwise:{self.block}"

    @property
    def bits(self):
        return 2

    def scale_numel(self, numel: int) -> int:
        return -(-int(numel) // self.block)

    def compute_scale(self, x):
        raise NotImplementedError("blockwise scales ride encode()")

    def quantize(self, x, scale, *, u=None):
        return jnp.sign(x.astype(jnp.float32)).astype(jnp.int8)

    def dequantize(self, codes, scale):
        # scale: per-block, broadcast over the block dim by the caller
        return codes.astype(jnp.float32) * scale

    def _blocks(self, flat):
        n = flat.shape[0]
        nb = -(-n // self.block)
        return jnp.pad(flat, (0, nb * self.block - n)).reshape(
            nb, self.block), nb

    def _encode_impl(self, x, *, key, backend) -> WireBuffer:
        shape = tuple(x.shape)
        flat = x.reshape(-1).astype(jnp.float32)
        n = flat.shape[0]
        x2d, nb = self._blocks(flat)
        if resolve_backend(backend, n) == "jnp":
            codes, scales = grids.blockwise_quantize(x2d)
            codes = jax.lax.optimization_barrier(codes)  # see Codec
            payload = B.pack_flat(codes, self.bits)[:self.payload_nbytes(n)]
            return WireBuffer(payload=payload, scale=scales,
                              spec=self.spec, shape=shape)
        rpad = (-nb) % K.BLOCKWISE_ROWS
        x2dp = jnp.pad(x2d, ((0, rpad), (0, 0)))
        payload2d, scales = K.encode_blockwise_pallas(
            x2dp, bits=self.bits, interpret=_interpret())
        payload = payload2d.reshape(-1)[:self.payload_nbytes(n)]
        return WireBuffer(payload=payload, scale=scales[:nb],
                          spec=self.spec, shape=shape)

    def _decode_impl(self, wb: WireBuffer, *, backend=None,
                     out_dtype=jnp.float32) -> jax.Array:
        n = wb.numel
        nb = self.scale_numel(n)
        padded = nb * self.block
        codes = B.unpack_flat(wb.payload, self.bits, n)
        codes2d = jnp.pad(codes, (0, padded - n)).reshape(nb, self.block)
        vals = grids.blockwise_dequantize(codes2d, wb.scale)
        return vals.reshape(-1)[:n].astype(out_dtype).reshape(wb.shape)


@dataclasses.dataclass(frozen=True)
class IdentityCodec(Codec):
    """No compression: the payload is the f32 bytes (4 bytes/element)."""

    name = "identity"
    kind = "identity"

    @property
    def spec(self):
        return "identity"

    @property
    def bits(self):
        return 32

    def scale_numel(self, numel: int) -> int:
        return 0

    def payload_nbytes(self, numel: int) -> int:
        return 4 * int(numel)

    def compute_scale(self, x):
        return jnp.float32(1.0)

    def quantize(self, x, scale, *, u=None):
        return x.astype(jnp.float32)

    def dequantize(self, codes, scale):
        return codes.astype(jnp.float32)

    def _encode_impl(self, x, *, key, backend) -> WireBuffer:
        flat = x.reshape(-1).astype(jnp.float32)
        payload = jax.lax.bitcast_convert_type(flat, jnp.uint8).reshape(-1)
        return WireBuffer(payload=payload, scale=jnp.zeros((0,), jnp.float32),
                          spec=self.spec, shape=tuple(x.shape))

    def _decode_impl(self, wb: WireBuffer, *, backend=None,
                     out_dtype=jnp.float32) -> jax.Array:
        vals = jax.lax.bitcast_convert_type(
            wb.payload.reshape(-1, 4), jnp.float32)
        return vals.astype(out_dtype).reshape(wb.shape)


# jitted entry points: the codec (a hashable frozen dataclass) rides as a
# static argument, so each (codec, backend) pair compiles once. Both
# backends then see the SAME compilation mode - comparing an eager jnp
# run against a compiled Pallas kernel would pick up FMA-contraction
# rounding differences that are compilation artifacts, not codec bugs.

@functools.partial(jax.jit, static_argnames=("codec", "backend"))
def _encode_jit(x, key, *, codec, backend):
    return codec._encode_impl(x, key=key, backend=backend)


@functools.partial(jax.jit, static_argnames=("codec", "backend", "out_dtype"))
def _decode_jit(wb, *, codec, backend, out_dtype):
    return codec._decode_impl(wb, backend=backend,
                              out_dtype=jnp.dtype(out_dtype))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def get_codec(spec: Optional[str]) -> Codec:
    """Parse a codec spec string (same grammar as the historical
    quantizer specs): 'none', 'log:k', 'uniform:k', 'uniform_amax:k',
    'terngrad', 'blockwise:b'; a trailing ':wire' on the uniform specs
    selects the clipped wire lanes."""
    if spec is None or spec in ("none", "identity", "fp32"):
        return IdentityCodec()
    parts = spec.split(":")
    head, args = parts[0], parts[1:]
    wire_bits = None
    if "wire" in args:
        args.remove("wire")
        wire_bits = "wire"
    for a in list(args):
        if a.startswith("w") and a[1:].isdigit():
            wire_bits = int(a[1:])
            args.remove(a)
    arg = args[0] if args else ""
    if head == "log":
        return LogCodec(k_g=int(arg or 6))
    if head in ("uniform", "uniform_amax"):
        k_x = int(arg or 7)
        absolute = head == "uniform"
        if wire_bits == "wire":
            return uniform_wire_codec(k_x, absolute)
        return UniformCodec(k_x=k_x, absolute=absolute, wire_bits=wire_bits)
    if head in ("terngrad", "ternary"):
        return TernaryCodec()
    if head == "blockwise":
        return BlockwiseCodec(block=int(arg or 256))
    raise ValueError(f"unknown codec spec: {spec}")


CODEC_NAMES = ("identity", "log", "uniform", "uniform_amax", "terngrad",
               "blockwise")


# ---------------------------------------------------------------------------
# row-chunked wire entry points (the layout the dist collectives move)
# ---------------------------------------------------------------------------

def _rows_tiling(c: int, bits: int):
    """Per-row padded length and tile count for the fused kernels."""
    li = K.lanes_in(bits)
    er = K.enc_rows()
    t = -(-c // (er * li))                   # (er, li) tiles per row
    return t * er * li, t * er


def encode_rows(x: jax.Array, codec: Codec, n_rows: int, *, key=None,
                backend: Optional[str] = None):
    """Fused encode into worker-ownership rows: flat x -> ``(n_rows,
    payload_nbytes(c))`` uint8 payload (byte-aligned per row - exactly
    the array the all_to_all moves) plus the per-tensor scale. One
    kernel launch on the Pallas backend."""
    if codec.stochastic and key is None:
        raise ValueError(f"{codec.name} codec is stochastic; pass key=")
    key = key if key is not None else jax.random.PRNGKey(0)
    return _encode_rows_jit(x, key, codec=codec, n_rows=n_rows,
                            backend=backend)


@functools.partial(jax.jit, static_argnames=("codec", "n_rows", "backend"))
def _encode_rows_jit(x, key, *, codec, n_rows, backend):
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    c = -(-n // n_rows)
    u = codec._draw(key, flat.shape)
    if resolve_backend(backend, n) == "jnp":
        scale = codec.compute_scale(flat)
        codes = codec.quantize(flat, scale, u=u)
        codes = jax.lax.optimization_barrier(codes)  # see _encode_impl
        return B.pack_rows(B.pad_rows(codes, n_rows), codec.bits), scale
    lrow, rrow = _rows_tiling(c, codec.bits)
    rows_f = B.pad_rows(flat, n_rows)
    rows_f = jnp.pad(rows_f, ((0, 0), (0, lrow - c)))
    x2d = rows_f.reshape(n_rows * rrow, K.lanes_in(codec.bits))
    if u is not None:
        ru = jnp.pad(B.pad_rows(u, n_rows), ((0, 0), (0, lrow - c)))
        u2d = ru.reshape(n_rows * rrow, K.lanes_in(codec.bits))
    else:
        u2d = None
    payload2d, scale = K.encode_pallas(
        x2d, codec.kind, codec.bits, codec.k,
        scale=(None if codec.static_scale is None
               else jnp.float32(codec.static_scale)),
        u2d=u2d, clip_abs=codec.clip_abs, interpret=_interpret())
    payload = payload2d.reshape(n_rows, -1)[:, :codec.payload_nbytes(c)]
    return payload, scale


def encode_rows_ef(x: jax.Array, scale, codec: Codec, n_rows: int, *,
                   backend: Optional[str] = None):
    """Fused encode + error feedback: flat x -> (payload rows, residual
    ``e' = x - deq(codes)`` in x's shape). The scale arrives from the
    caller (the Adam moment pass); codes never hit HBM unpacked."""
    return _encode_rows_ef_jit(x, scale, codec=codec, n_rows=n_rows,
                               backend=backend)


@functools.partial(jax.jit, static_argnames=("codec", "n_rows", "backend"))
def _encode_rows_ef_jit(x, scale, *, codec, n_rows, backend):
    shape = x.shape
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    c = -(-n // n_rows)
    if resolve_backend(backend, n) == "jnp":
        codes = codec.quantize(flat, scale)
        # the codes feed BOTH the packer (G strided reads) and the
        # residual - fence them so neither consumer re-runs quantize
        codes = jax.lax.optimization_barrier(codes)
        e_new = flat - codec.dequantize(codes, scale)
        return (B.pack_rows(B.pad_rows(codes, n_rows), codec.bits),
                e_new.reshape(shape))
    lrow, rrow = _rows_tiling(c, codec.bits)
    rows_f = jnp.pad(B.pad_rows(flat, n_rows), ((0, 0), (0, lrow - c)))
    x2d = rows_f.reshape(n_rows * rrow, K.lanes_in(codec.bits))
    payload2d, e2d = K.ef_encode_pallas(x2d, scale, codec.kind, codec.bits,
                                        codec.k, clip_abs=codec.clip_abs,
                                        lut=codec.dequant_lut(),
                                        interpret=_interpret())
    payload = payload2d.reshape(n_rows, -1)[:, :codec.payload_nbytes(c)]
    e_new = e2d.reshape(n_rows, lrow)[:, :c].reshape(-1)[:n]
    return payload, e_new.reshape(shape)


def decode_rows(payload_rows: jax.Array, scales, codec: Codec, c: int, *,
                backend: Optional[str] = None,
                out_dtype=jnp.float32) -> jax.Array:
    """Fused decode of received payload rows: ``(n_rows, nbytes)`` uint8
    + per-source-row scales -> ``(n_rows, c)`` dequantized values."""
    return _decode_rows_jit(payload_rows, scales, codec=codec, c=c,
                            backend=backend,
                            out_dtype=jnp.dtype(out_dtype).name)


@functools.partial(jax.jit,
                   static_argnames=("codec", "c", "backend", "out_dtype"))
def _decode_rows_jit(payload_rows, scales, *, codec, c, backend, out_dtype):
    out_dtype = jnp.dtype(out_dtype)
    n_rows = payload_rows.shape[0]
    scales = jnp.asarray(scales, jnp.float32).reshape(n_rows)
    if resolve_backend(backend, n_rows * c) == "jnp":
        codes = B.unpack_rows(payload_rows, codec.bits, c)
        return codec.dequantize(codes, scales[:, None]).astype(out_dtype)
    lo = K.lanes_out(codec.bits)
    li = K.lanes_in(codec.bits)
    lrow, rrow = _rows_tiling(c, codec.bits)
    brow = rrow * lo                                  # payload bytes/row
    p = jnp.pad(payload_rows,
                ((0, 0), (0, brow - payload_rows.shape[1])))
    p2d = p.reshape(n_rows * rrow, lo)
    out = K.decode_pallas(p2d, scales, codec.kind, codec.bits, codec.k,
                          tiles_per_scale=rrow // K.enc_rows(),
                          out_dtype=out_dtype, lut=codec.dequant_lut(),
                          interpret=_interpret())
    return out.reshape(n_rows, rrow * li)[:, :c]
