"""Distributed QAdam-EF (Algorithms 2+3): sharding plan, quantized wire,
and the parameter-server train/serve steps.

  sharding     - parameter layout: model-axis shard dims + worker chunking
  collectives  - the quantized wire (packed uint8 exchange / broadcast)
  step         - make_train_step / make_serve_step on top of the above
"""
from repro.dist import sharding, collectives, step  # noqa: F401
