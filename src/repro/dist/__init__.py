"""Distributed QAdam-EF (Algorithms 2+3): sharding plan, quantized wire,
and the parameter-server train/serve steps.

  sharding     - parameter layout: model-axis shard dims + worker chunking
  topology     - pluggable link-tier topologies (flat / hierarchical)
  collectives  - the quantized wire (packed uint8 exchange / broadcast)
  modes        - per-mode optimizer plugins (qadam/dp_adam/terngrad/ef_sgd)
  step         - make_train_step: the mode-independent worker-step template
  serve        - make_serve_step: the sharded serving step
"""
from repro.dist import (sharding, topology, collectives, modes, step,  # noqa: F401
                        serve)
