"""Distributed QAdam-EF train step (Algorithms 2+3): quantized parameter
server over the mesh's worker axes, context/model parallelism over its
model axis.

This module owns the mode-independent worker-step TEMPLATE:

  1. weight broadcast: every server quantizes its chunk with Q_x, packed
     8-bit codes are all-gathered over the worker axes, each worker
     reassembles Q_x(x_t) for its model shard (small leaves ride f32).
  2. forward/backward at Q_x(x_t) (Assumption 3), sequence sharded over
     the model axis, per-layer FSDP weight gather; each worker gets the
     gradient of *its own* mean loss.
  3. per-worker engine update (``repro.opt.engine``; fused Pallas on TPU).
  4. update exchange: each mode's wire (packed codes all-to-all for the
     quantized modes) so each server receives all workers' updates for
     its chunk; it averages the dequantized deltas into its master chunk.

Steps 3-4 are the per-mode plugins in ``repro.dist.modes`` ("qadam" - the
paper, "dp_adam", "terngrad", "ef_sgd"); the serve step lives in
``repro.dist.serve``.

State layout (matches ``repro.launch.dryrun`` and the equivalence tests):
every leaf of the train state is *chunked* - shape
``worker_sizes + (n_model_shards, X)`` sharded
``P(*worker_axes, "model", None)`` - so each device holds a 1-D slice:

  * ``master``: worker w's f32 chunk of model-shard m (X = chunk size c).
    Worker w is the Algorithm-2 "server" for its chunk.
  * ``m, v, e``: per-worker Adam moments / EF residual. Workers see
    different gradients, so each keeps moments for the *whole* shard
    (X = shard numel); in ``dp_adam`` mode gradients are averaged first
    and the moments are chunk-sharded like ``master`` (ZeRO-style).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import comm
from repro.adapt import stats as astats
from repro.core.qadam import QAdamConfig, _alpha_t, _theta_t
from repro.dist import sharding as SH
from repro.dist import collectives as C
from repro.dist import topology as T
from repro.dist.modes import WorkerCtx, get_mode
from repro.models.layers import ShardCtx

MODEL_AXIS = "model"


# ---------------------------------------------------------------------------
# configs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrainConfig:
    alpha: float = 1e-3
    beta: float = 0.99
    theta: float = 0.999
    eps: float = 1e-5
    schedule: str = "constant"          # "sqrt" | "constant" | "halving:K"
    grad_k: Optional[int] = 6           # log-grid k_g; None = f32 wire
    weight_k: Optional[int] = None      # uniform k_x; None = f32 broadcast
    weight_absolute: bool = True        # paper's absolute [-0.5,0.5] grid
    weight_q_min_numel: int = 2 ** 14   # small leaves skip Q_x (biases/norms)
    error_feedback: bool = True
    mode: str = "qadam"                 # any repro.dist.modes name
    # per-leaf wire plan (adaptive mode): one registry codec spec per
    # state leaf in metas_flat order, e.g. ("log:6", "blockwise:256",
    # ...). TrainConfig is a static jit argument and rides in the AOT
    # facts, so every distinct plan is its own compiled/cached step.
    bit_plan: Optional[Tuple[str, ...]] = None
    # update-exchange bucketing: leaves are fenced (optimization_barrier)
    # and dispatched to the wire in buckets of about this many payload
    # bytes instead of behind one whole-tree end-of-step barrier, so XLA
    # may overlap an early bucket's quantized exchange with the rest of
    # the backward. <= 0 restores the single whole-tree fence.
    exchange_bucket_bytes: int = 4 << 20
    worker_axes: Tuple[str, ...] = ("pod", "data")
    # link-tier topology (repro.dist.topology): FlatTopology keeps
    # today's single-tier wire; HierarchicalTopology(nodes, d) runs an
    # fp intra-node gradient reduce and keeps the quantized+EF exchange
    # on the node axis only. A frozen dataclass field, so every
    # topology is its own jit/AOT cache key like any config change.
    topology: T.Topology = T.FlatTopology()
    batch_dim_shardable: bool = True
    model_gather_quant: Optional[int] = None  # int8 FSDP gather bits
    fused_kernels: Optional[bool] = None      # None = auto (TPU only)
    seed: int = 0

    @property
    def engine_backend(self) -> Optional[str]:
        """repro.opt.engine backend for the update core."""
        if self.fused_kernels is None:
            return None
        return "pallas" if self.fused_kernels else "jnp"


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    weight_k: Optional[int] = None      # int8 weight-gather bits
    weight_absolute: bool = False
    worker_axes: Tuple[str, ...] = ("pod", "data")
    batch_dim_shardable: bool = True


@dataclasses.dataclass(frozen=True)
class LeafMeta:
    """Per-leaf wire geometry. `shp` is the local model-shard shape,
    `numel` its element count, `c` the per-worker chunk length."""
    shp: Tuple[int, ...]
    c: int
    numel: int
    dim: int
    stacked: bool
    shape: Tuple[int, ...]

    @property
    def full_numel(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


def _leaf_meta(layout: SH.Layout, n_workers: int):
    """Tree of LeafMeta mirroring the parameter tree."""
    def one(l, d, s):
        shp = SH.local_shard_shape(tuple(l.shape), d, s, layout.n_shards)
        n = int(np.prod(shp)) if shp else 1
        return LeafMeta(shp=shp, c=SH.chunk_size(n, n_workers), numel=n,
                        dim=d, stacked=s, shape=tuple(l.shape))
    return jax.tree.map(one, layout._leaves, layout.dims, layout.stacked)


class StepArtifacts(NamedTuple):
    init_state: Callable
    step_fn: Callable
    layout: SH.Layout
    n_workers: int
    worker_axes: Tuple[str, ...]
    mesh: Any
    config: Any
    # resolved topology (topology.Tiers); None on artifacts built by
    # older callers - accounting treats that as flat.
    tiers: Any = None


def weight_wire_codec(tc, full_numel: int) -> comm.Codec:
    """The weight-broadcast channel's codec for one leaf - THE source of
    truth for what moves on channel 2 (``comm_bytes_per_step`` and the
    dryrun accounting read the same function). Small / unquantized
    leaves ride f32 (identity)."""
    if tc.weight_k is None or full_numel < tc.weight_q_min_numel:
        return comm.IdentityCodec()
    return comm.uniform_wire_codec(tc.weight_k, tc.weight_absolute)


def _exchange_buckets(metas_flat, mode, tc, n_workers, tiers=None):
    """Group consecutive leaves into wire buckets of about
    ``tc.exchange_bucket_bytes`` payload each. Each bucket gets its own
    gradient fence, so the first bucket's quantized exchange can be
    scheduled while the backward of later leaves is still running;
    ``<= 0`` collapses to one whole-tree bucket (the pre-bucketing
    end-of-step barrier). Bucket fill counts the payload that actually
    crosses the exchange (inter) tier, so hierarchical topologies pack
    ~``devices_per_node`` times more leaves per dispatch."""
    if tc.exchange_bucket_bytes <= 0 or len(metas_flat) <= 1:
        return [list(range(len(metas_flat)))]
    buckets, cur, cur_bytes = [], [], 0
    for i, meta in enumerate(metas_flat):
        cur.append(i)
        cur_bytes += mode.leaf_tier_nbytes(tc, i, meta.c, meta.numel,
                                           n_workers, tiers)["inter"]
        if cur_bytes >= tc.exchange_bucket_bytes:
            buckets.append(cur)
            cur, cur_bytes = [], 0
    if cur:
        buckets.append(cur)
    return buckets


def state_template(art: StepArtifacts):
    """Sharded ShapeDtypeStructs of ``art.init_state``'s output - the one
    description of the chunked state layout (master/m/v/e plus the
    mode's ``extra_state``) that dryrun lowering, resume plumbing and
    tests consume instead of hand-reconstructing shapes."""
    tc = art.config
    mode = get_mode(tc.mode)
    ms = dict(zip(art.mesh.axis_names, art.mesh.devices.shape))
    Nm = int(ms.get(MODEL_AXIS, 1))
    wdims = tuple(ms[a] for a in art.worker_axes)
    spec = P(*art.worker_axes, MODEL_AXIS, None) if MODEL_AXIS in ms \
        else P(*art.worker_axes, None, None)
    sh = NamedSharding(art.mesh, spec)
    metas = _leaf_meta(art.layout, art.n_workers)

    def sds(meta, x):
        return jax.ShapeDtypeStruct(wdims + (Nm, x), jnp.float32,
                                    sharding=sh)

    def tree(xfn):
        return jax.tree.map(lambda _, m: sds(m, xfn(m)),
                            art.layout._leaves, metas)

    moment_x = (lambda m: m.c) if mode.chunk_sharded_moments \
        else (lambda m: m.numel)
    state = {"master": tree(lambda m: m.c)}
    for k in ("m", "v", "e"):
        state[k] = tree(moment_x)
    for k in mode.extra_state:
        state[k] = tree(lambda m: m.c)
    state["count"] = jax.ShapeDtypeStruct(
        (), jnp.int32, sharding=NamedSharding(art.mesh, P()))
    return state


def batch_shardings(art: StepArtifacts, batch, stacked: bool = False):
    """NamedShardings the train step expects for a (host numpy) batch -
    lets a prefetcher stage batches onto the mesh ahead of dispatch so
    the step consumes pre-placed buffers. ``stacked``: the batch carries
    a leading scan-chunk axis (replicated)."""
    ex = jax.tree.map(lambda x: x[0], batch) if stacked else batch
    ms = dict(zip(art.mesh.axis_names, art.mesh.devices.shape))
    Nm = int(ms.get(MODEL_AXIS, 1))
    Wb, cp = _batch_geometry(ex, Nm, art.worker_axes, art.n_workers,
                             art.config.batch_dim_shardable)
    specs = _batch_specs(ex, Wb, cp)
    if stacked:
        specs = {k: P(None, *s) for k, s in specs.items()}
    return {k: NamedSharding(art.mesh, s) for k, s in specs.items()}


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------

def _dims_by_path(layout: SH.Layout):
    flat = jax.tree_util.tree_flatten_with_path(layout.dims)[0]
    dims = {SH._path_keys(p): d for p, d in flat}
    st = {SH._path_keys(p): s for p, s in
          jax.tree_util.tree_flatten_with_path(layout.stacked)[0]}
    return dims, st


def _make_param_gather(layout: SH.Layout, Nm: int, expert_local: bool,
                       quant_k: Optional[int], quant_absolute: bool,
                       quant_min_numel: int = 0,
                       stacked_at_static: bool = False):
    """ctx.param_gather hook: reconstruct full weights from model-axis
    shards, leaving expert tensors local when the MoE layer is sharded.

    ``stacked_at_static`` (serve): gather the scan-stacked ``blocks``
    leaves whole during the "static" pass - the Q_x scale is then one
    per-shard amax across all layers of a leaf (matching the serve
    equivalence reference), and the per-layer gather inside the scan
    becomes a no-op. Training keeps the per-layer (FSDP-style) gather.
    """
    dims_by_path, stacked_by_path = _dims_by_path(layout)

    def gather_leaf(dim: int, stacked: bool, leaf):
        if dim == SH.REPLICATED:
            return leaf
        ax = SH.axis_of(dim, stacked)
        if dim == SH.EXPERT_MARKER and expert_local:
            if quant_k is not None and leaf.size >= quant_min_numel:
                # keep resident experts on the same Q_x wire semantics
                return C.quantized_gather_shard(leaf, ax, 1, quant_k,
                                                quant_absolute)
            return leaf
        if quant_k is not None and leaf.size * Nm >= quant_min_numel:
            return C.quantized_gather_shard(leaf, ax, Nm, quant_k,
                                            quant_absolute)
        return C.gather_shard(leaf, ax, Nm)

    def gather(subtree, kind: str):
        if Nm <= 1 and quant_k is None:
            return subtree
        if stacked_at_static and kind != "static":
            return subtree  # already gathered whole in the static pass

        def one(path, leaf):
            keys = SH._path_keys(path)
            if kind == "static":
                if keys and keys[0] in SH._STACKED_KEYS:
                    if not stacked_at_static:
                        return leaf  # per-layer gather inside the scan
                    return gather_leaf(dims_by_path[keys],
                                       stacked_by_path[keys], leaf)
                full = keys
            else:
                full = (kind,) + keys
            return gather_leaf(dims_by_path[full], False, leaf)

        return jax.tree_util.tree_map_with_path(one, subtree)

    return gather


def _batch_geometry(batch, Nm: int, worker_axes, n_workers: int,
                    shardable: bool):
    """Static decisions: shard batch over workers / sequence over model."""
    if "tokens" in batch:
        B, S = batch["tokens"].shape
    else:
        B, S = batch["embeds"].shape[:2]
    Wb = worker_axes if (shardable and worker_axes
                         and B % n_workers == 0) else ()
    cp = Nm > 1 and S % Nm == 0
    if "audio" in batch and batch["audio"].shape[1] % Nm != 0:
        cp = False
    return Wb, cp


def _batch_specs(batch, Wb, cp):
    b0 = Wb if Wb else None
    sa = MODEL_AXIS if cp else None
    specs = {}
    for k, v in batch.items():
        ent = [None] * v.ndim
        ent[0] = b0
        if v.ndim >= 2:
            ent[1] = sa
        specs[k] = P(*ent)
    return specs


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

def make_train_step(model, mesh, tc: TrainConfig) -> StepArtifacts:
    ms = dict(zip(mesh.axis_names, mesh.devices.shape))
    worker_axes, wsizes, n_workers = SH.worker_info(mesh, tc.worker_axes)
    Nm = int(ms.get(MODEL_AXIS, 1))
    model_in_mesh = MODEL_AXIS in ms

    pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    layout = SH.build_layout(pshapes, Nm)
    metas = _leaf_meta(layout, n_workers)
    qcfg = QAdamConfig(alpha=tc.alpha, beta=tc.beta, theta=tc.theta,
                       eps=tc.eps, schedule=tc.schedule)
    mode = get_mode(tc.mode)
    topo = tc.topology if tc.topology is not None else T.FlatTopology()
    # non-tiered modes (dp_adam) run flat collectives on any topology;
    # resolving their tiers flat keeps the updater/accounting honest.
    tiers = topo.tiers(worker_axes, wsizes) if mode.tiered \
        else T.flat_tiers(worker_axes, wsizes)
    updater = mode.make_updater(tc, WorkerCtx(
        worker_axes=worker_axes, wsizes=wsizes, n_workers=n_workers,
        backend=tc.engine_backend, tiers=tiers))

    treedef = jax.tree_util.tree_structure(layout._leaves)
    metas_flat = treedef.flatten_up_to(metas)
    if tc.bit_plan is not None and len(tc.bit_plan) != len(metas_flat):
        raise ValueError(
            f"bit_plan has {len(tc.bit_plan)} specs for "
            f"{len(metas_flat)} state leaves")
    buckets = _exchange_buckets(metas_flat, mode, tc, n_workers, tiers)
    chunk_sharded = mode.chunk_sharded_moments  # moments chunked vs full-shard
    state_spec = P(*worker_axes, MODEL_AXIS, None) if model_in_mesh \
        else P(*worker_axes, None, None)

    def _state_x(meta):  # per-leaf trailing dim of m/v/e
        return meta.c if chunk_sharded else meta.numel

    # ---------------- init ----------------
    def init_state(key):
        params = model.init(key)
        p_flat = treedef.flatten_up_to(params)
        sh = NamedSharding(mesh, state_spec)
        master, zs, chunk_zs = [], [], []
        for p, meta in zip(p_flat, metas_flat):
            rows = [SH.flatten_pad(
                SH.shard_of(p, meta.dim, meta.stacked, Nm, mi)
                .reshape(-1).astype(jnp.float32), n_workers)
                for mi in range(Nm)]
            arr = jnp.stack(rows, axis=1)            # (n_workers, Nm, c)
            master.append(jax.device_put(
                arr.reshape(wsizes + (Nm, meta.c)), sh))
            # m/v/e exist for every mode even where unused (terngrad
            # reads none, ef_sgd skips v): the chunked state layout is a
            # fixed contract with repro.launch.dryrun (state_template)
            # and with checkpoint round-trips.
            zs.append(jax.device_put(
                jnp.zeros(wsizes + (Nm, _state_x(meta)), jnp.float32), sh))
            chunk_zs.append(jax.device_put(
                jnp.zeros(wsizes + (Nm, meta.c), jnp.float32), sh))
        mtree = jax.tree_util.tree_unflatten(treedef, master)
        ztree = jax.tree_util.tree_unflatten(treedef, zs)
        ctree = jax.tree_util.tree_unflatten(treedef, chunk_zs)
        zero = lambda t: jax.tree.map(jnp.copy, t)
        state = {"master": mtree, "m": zero(ztree), "v": zero(ztree),
                 "e": zero(ztree),
                 "count": jax.device_put(jnp.zeros((), jnp.int32),
                                         NamedSharding(mesh, P()))}
        for k in mode.extra_state:   # efadam: server broadcast residual
            state[k] = zero(ctree)
        return state

    # ---------------- weight-broadcast channel ----------------
    def chunks_to_shard(chunk, meta, es=None):
        """My master chunk -> full f32 shard over the codec wire.

        With ``es`` (the ``broadcast_ef`` modes), the server sends
        ``Q(chunk + es)`` and keeps the residual; the returned es' feeds
        the next step. Identity-codec leaves broadcast f32 rows (their
        residual is exactly zero)."""
        codec = weight_wire_codec(tc, meta.full_numel)
        if isinstance(codec, comm.IdentityCodec):
            rows = C.gather_rows_tiered(chunk, tiers)
            return SH.unflatten_chunked(rows, meta.shp), es
        send = chunk if es is None else chunk + es
        scale = codec.compute_scale(send)
        payload, e_new = comm.encode_rows_ef(send, scale, codec, 1,
                                             backend=tc.engine_backend)
        rows = C.broadcast_decode_tiered(payload[0], scale, codec, meta.c,
                                         tiers, backend=tc.engine_backend)
        return (SH.unflatten_chunked(rows, meta.shp),
                e_new if es is not None else None)

    # ---------------- the sharded step ----------------
    def _impl(state, batch, cp: bool):
        masters = [x.reshape(m.c) for x, m in
                   zip(treedef.flatten_up_to(state["master"]), metas_flat)]
        ms_ = [x.reshape(_state_x(m)) for x, m in
               zip(treedef.flatten_up_to(state["m"]), metas_flat)]
        vs_ = [x.reshape(_state_x(m)) for x, m in
               zip(treedef.flatten_up_to(state["v"]), metas_flat)]
        es_ = [x.reshape(_state_x(m)) for x, m in
               zip(treedef.flatten_up_to(state["e"]), metas_flat)]
        t = state["count"] + 1
        a_t = _alpha_t(qcfg, t)
        th_t = _theta_t(qcfg, t)

        # 1. weight broadcast: chunks -> Q_x(x_t) shards. broadcast_ef
        # modes thread the per-chunk server residual through the codec.
        if mode.broadcast_ef:
            srv = [x.reshape(m.c) for x, m in
                   zip(treedef.flatten_up_to(state["es"]), metas_flat)]
            pairs = [chunks_to_shard(ch, m, es)
                     for ch, m, es in zip(masters, metas_flat, srv)]
            new_es = [p[1] for p in pairs]
        else:
            pairs = [chunks_to_shard(ch, m)
                     for ch, m in zip(masters, metas_flat)]
            new_es = None
        xs = [p[0] for p in pairs]
        # fence the forward/backward off from the channel/update code so
        # XLA compiles it like a standalone value_and_grad: its float
        # rounding then matches the single-machine reference path instead
        # of shifting with unrelated fusion decisions.
        xs = jax.lax.optimization_barrier(xs)
        x_tree = jax.tree_util.tree_unflatten(treedef, xs)

        # 2. forward/backward at Q_x(x_t)
        ctx = ShardCtx(
            cp_axis=MODEL_AXIS if cp else None,
            cp_size=Nm if cp else 1, dp_axes=worker_axes,
            param_gather=_make_param_gather(
                layout, Nm, expert_local=cp,
                quant_k=tc.model_gather_quant, quant_absolute=False,
                quant_min_numel=2 ** 14))
        maxes = (MODEL_AXIS,) if model_in_mesh and Nm > 1 else ()
        all_axes = worker_axes + maxes

        def lfn(pt):
            s, nt = model.loss(pt, batch, ctx)
            if tc.mode == "dp_adam":
                # local sum / global count; the weight-gather transpose
                # already sums model-axis contributions, the worker-axis
                # average happens on chunk rows in the dp_adam updater.
                gden = jax.lax.psum(nt, all_axes) if all_axes else nt
                return s / gden, (s, nt)
            # per-worker mean loss (Algorithm 2). psum's transpose is psum,
            # so a psum'd objective over-counts cotangents by the axis
            # size - divide it back out (value is unused, only grads).
            sw = jax.lax.psum(s, maxes) if maxes else s
            nw_ = jax.lax.psum(nt, maxes) if maxes else nt
            return sw / nw_ / (Nm if maxes else 1), (s, nt)

        grads, (s_loc, n_loc) = jax.grad(lfn, has_aux=True)(x_tree)
        loss = (jax.lax.psum(s_loc, all_axes) /
                jax.lax.psum(n_loc, all_axes)) if all_axes \
            else s_loc / n_loc

        gs = []
        for g, meta in zip(treedef.flatten_up_to(grads), metas_flat):
            g = g.reshape(-1).astype(jnp.float32)
            if Nm > 1 and meta.dim == SH.REPLICATED:
                # replicated leaves skip the gather, so their grads miss
                # the gather-transpose psum over the model axis
                g = jax.lax.psum(g, MODEL_AXIS)
            gs.append(g)

        # fence the forward/backward off from the channel/update code so
        # XLA compiles it like a standalone value_and_grad (float
        # rounding then matches the single-machine reference path) - but
        # per BUCKET rather than one whole-tree end-of-step barrier: a
        # bucket's quantized exchange only waits on its own gradients,
        # so the wire of early buckets can overlap the remaining
        # backward.
        for bucket in buckets:
            fenced = jax.lax.optimization_barrier([gs[i] for i in bucket])
            for i, g in zip(bucket, fenced):
                gs[i] = g

        # 3+4. per-worker engine update + per-mode quantized exchange.
        # The PRNG folds the *inter-tier* worker index: flat tiers make
        # it the plain flat worker id (unchanged), hierarchical tiers
        # fold the node id only, so a node's devices draw identical
        # stochastic codes for their identical node-mean gradients.
        base = jax.random.fold_in(jax.random.PRNGKey(tc.seed), t)
        widx = C.worker_index(tiers.inter_axes, tiers.inter_sizes)
        new_m, new_mm, new_vv, new_ee, stat_rows = [], [], [], [], []
        for i, meta in enumerate(metas_flat):
            key = jax.random.fold_in(jax.random.fold_in(base, i), widx)
            out = updater(gs[i], ms_[i], vs_[i], es_[i],
                          masters[i], meta, a_t, th_t, key, i)
            if mode.emits_stats:
                nc, nm, nv, ne, row = out
                stat_rows.append(row)
            else:
                nc, nm, nv, ne = out
            lead = (1,) * (len(worker_axes) + 1)
            new_m.append(nc.reshape(lead + (meta.c,)))
            new_mm.append(nm.reshape(lead + (_state_x(meta),)))
            new_vv.append(nv.reshape(lead + (_state_x(meta),)))
            new_ee.append(ne.reshape(lead + (_state_x(meta),)))

        unf = lambda ls: jax.tree_util.tree_unflatten(treedef, ls)
        new_state = {"master": unf(new_m), "m": unf(new_mm),
                     "v": unf(new_vv), "e": unf(new_ee), "count": t}
        if mode.broadcast_ef:
            lead = (1,) * (len(worker_axes) + 1)
            new_state["es"] = unf([
                es.reshape(lead + (m.c,))
                for es, m in zip(new_es, metas_flat)])
        metrics = {"loss": loss}
        if mode.emits_stats:
            rows = jnp.stack(stat_rows)          # (n_leaves, N_FIELDS)
            metrics["gstats"] = (astats.reduce_stats(rows, all_axes)
                                 if all_axes else rows)
        return new_state, metrics

    def step_fn(state, batch):
        Wb, cp = _batch_geometry(batch, Nm, worker_axes, n_workers,
                                 tc.batch_dim_shardable)
        sspec = {"master": jax.tree.map(lambda _: state_spec,
                                        layout._leaves),
                 "count": P()}
        for k in ("m", "v", "e") + mode.extra_state:
            sspec[k] = jax.tree.map(lambda _: state_spec, layout._leaves)
        bspec = _batch_specs(batch, Wb, cp)
        mspec = {"loss": P()}
        if mode.emits_stats:
            mspec["gstats"] = P()
        fn = shard_map(functools.partial(_impl, cp=cp), mesh=mesh,
                       in_specs=(sspec, bspec),
                       out_specs=(sspec, mspec),
                       check_rep=False)
        return fn(state, batch)

    return StepArtifacts(init_state=init_state, step_fn=step_fn,
                         layout=layout, n_workers=n_workers,
                         worker_axes=worker_axes, mesh=mesh, config=tc,
                         tiers=tiers)


def __getattr__(name):
    # compat: the serve step moved to repro.dist.serve
    if name in ("make_serve_step", "_cache_specs_for"):
        from repro.dist import serve
        return getattr(serve, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
