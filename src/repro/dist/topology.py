"""Pluggable parameter-server topologies.

The paper's Algorithms 2+3 assume one flat worker<->server wire, but a
real cluster is hierarchical: fast intra-node links (NVLink/ICI), slow
inter-node links (DCN/ethernet). The quantized exchange only pays for
itself on the slow tier, so a :class:`HierarchicalTopology` splits the
worker axes into two tiers:

  * **intra tier** (fast): gradients are fp all-reduced (deterministic
    pairwise tree mean - see ``modes.base.tier_grad_mean``) across the
    devices of one node *before* the optimizer update, so every device
    in a node computes bit-identical moments, EF residuals and codes.
  * **inter tier** (slow): the quantized+EF update exchange and the
    leading leg of the weight broadcast run across nodes only. Each
    device all-to-alls the ``n_inter`` payload rows for its intra
    position instead of all ``n_workers`` rows, so inter-tier wire
    bytes drop by exactly ``1/devices_per_node`` and the EF residual
    effectively lives at node-leader granularity (replicated across
    the node's devices).

:class:`FlatTopology` resolves to a single tier spanning all worker
axes; every tiered code path then degenerates to the legacy flat
collectives op-for-op, so flat results are bit-identical to the
pre-topology code.

Resolution contract (:meth:`HierarchicalTopology.tiers`): the node
(inter) tier must be a *prefix* of the worker axes whose sizes multiply
to ``nodes``, the remaining suffix to ``devices_per_node`` - e.g. a
``(pod=2, data=4)`` mesh with ``worker_axes=("pod", "data")`` maps to
2 nodes of 4 devices. Splitting in the middle of one axis is rejected;
reshape the mesh instead (``--topology 2x4`` in ``repro.launch.train``
builds the matching mesh for you).

Topology objects are small frozen dataclasses: they hash and digest
(``perf.aot._canon``) like any other ``TrainConfig`` field, so every
topology is its own jit/AOT cache key.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

from repro.dist import sharding as SH


@dataclasses.dataclass(frozen=True)
class Tiers:
    """A topology resolved against concrete worker axes: the inter
    (exchange) tier and the intra (fp-reduce) tier, both in mesh axis
    order. ``intra_axes == ()`` means flat (single-tier) operation."""
    inter_axes: Tuple[str, ...]
    inter_sizes: Tuple[int, ...]
    intra_axes: Tuple[str, ...]
    intra_sizes: Tuple[int, ...]

    @property
    def n_inter(self) -> int:
        n = 1
        for s in self.inter_sizes:
            n *= int(s)
        return n

    @property
    def n_intra(self) -> int:
        n = 1
        for s in self.intra_sizes:
            n *= int(s)
        return n

    @property
    def hierarchical(self) -> bool:
        return bool(self.intra_axes)


@dataclasses.dataclass(frozen=True)
class Topology:
    """How the worker axes map onto link tiers. Subclasses resolve
    themselves against the mesh's worker axes via :meth:`tiers`."""

    def tiers(self, worker_axes: Sequence[str],
              wsizes: Sequence[int]) -> Tiers:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class FlatTopology(Topology):
    """Today's behavior: one tier, every collective spans all worker
    axes. Bit-identical to the pre-topology code by construction."""

    def tiers(self, worker_axes, wsizes) -> Tiers:
        return Tiers(inter_axes=tuple(worker_axes),
                     inter_sizes=tuple(int(s) for s in wsizes),
                     intra_axes=(), intra_sizes=())


@dataclasses.dataclass(frozen=True)
class HierarchicalTopology(Topology):
    """``nodes`` groups of ``devices_per_node`` workers: fp intra-node
    gradient reduce, quantized+EF exchange across nodes only."""
    nodes: int
    devices_per_node: int

    def tiers(self, worker_axes, wsizes) -> Tiers:
        inter_a, inter_s, intra_a, intra_s = SH.split_worker_axes(
            worker_axes, wsizes, self.nodes, self.devices_per_node)
        return Tiers(inter_axes=inter_a, inter_sizes=inter_s,
                     intra_axes=intra_a, intra_sizes=intra_s)


def flat_tiers(worker_axes: Sequence[str],
               wsizes: Sequence[int]) -> Tiers:
    """Single-tier resolution - what ``None``/absent topologies mean."""
    return FlatTopology().tiers(worker_axes, wsizes)


def parse_topology(spec) -> Topology:
    """CLI/str form: ``"flat"``/``None`` -> FlatTopology, ``"NxD"``
    (e.g. ``"2x4"``) -> HierarchicalTopology(N, D). Topology instances
    pass through."""
    if spec is None or isinstance(spec, Topology):
        return spec if isinstance(spec, Topology) else FlatTopology()
    s = str(spec).strip().lower()
    if s in ("", "flat"):
        return FlatTopology()
    parts = s.split("x")
    if len(parts) == 2 and all(p.isdigit() for p in parts):
        return HierarchicalTopology(nodes=int(parts[0]),
                                    devices_per_node=int(parts[1]))
    raise ValueError(f"bad topology spec {spec!r}: expected 'flat' or "
                     f"'NxD' (e.g. '2x4')")
