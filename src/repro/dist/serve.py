"""Sharded serving step (moved out of the train-step module).

Params stay model-axis sharded per the layout; the KV cache is sequence-
sharded over the model axis and batch-sharded over the worker axes; the
weight gather optionally ships int8 Q_x codes (``ServeConfig.weight_k``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as SH
from repro.dist.step import (MODEL_AXIS, ServeConfig, _batch_geometry,
                             _batch_specs, _make_param_gather)
from repro.models.layers import ShardCtx


def _cache_specs_for(cfg, b0):
    specs = {}
    if cfg.arch_type != "ssm":
        specs["k"] = P(None, b0, MODEL_AXIS, None, None)
        specs["v"] = P(None, b0, MODEL_AXIS, None, None)
        # paged cache (repro.serve.paged): the physical pool shards its
        # page axis over the model axis - the sequence sharding's paged
        # analogue (requires num_pages % model-axis size == 0) - while
        # every shard holds the full page table (global ids; shards own
        # the rows that land in their local page range, see
        # models.model.decode_step's ownership mask)
        specs["pk"] = P(None, MODEL_AXIS, None, None, None)
        specs["pv"] = P(None, MODEL_AXIS, None, None, None)
        specs["ptab"] = P(b0, None)
    if cfg.arch_type in ("ssm", "hybrid"):
        specs["ssm"] = P(None, b0, None, None, None)
        specs["conv"] = P(None, b0, None, None)
    if cfg.arch_type == "encdec":
        specs["ck"] = P(None, b0, MODEL_AXIS, None, None)
        specs["cv"] = P(None, b0, MODEL_AXIS, None, None)
    return specs


def make_serve_step(model, mesh, sc: ServeConfig, kind: str = "decode"):
    """Sharded serving step.

    Returns ``(step, param_specs, (input_specs, cache_specs))``. Params
    stay model-axis sharded per the layout; the KV cache is sequence-
    sharded over the model axis and batch-sharded over the worker axes;
    the weight gather optionally ships int8 Q_x codes (``sc.weight_k``).
    """
    cfg = model.cfg
    ms = dict(zip(mesh.axis_names, mesh.devices.shape))
    worker_axes, wsizes, n_workers = SH.worker_info(mesh, sc.worker_axes)
    Nm = int(ms.get(MODEL_AXIS, 1))

    pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    layout = SH.build_layout(pshapes, Nm)
    param_specs = layout.param_specs(MODEL_AXIS)
    b0 = worker_axes if (sc.batch_dim_shardable and worker_axes) else None
    input_specs = {"token": P(b0, None), "embeds": P(b0, None, None)}
    cache_specs = _cache_specs_for(cfg, b0)

    ctx = ShardCtx(
        cp_axis=MODEL_AXIS if Nm > 1 else None,
        cp_size=Nm if Nm > 1 else 1, dp_axes=worker_axes,
        param_gather=_make_param_gather(
            layout, Nm, expert_local=Nm > 1,
            quant_k=sc.weight_k, quant_absolute=sc.weight_absolute,
            stacked_at_static=True))

    if kind == "decode":
        def step(params, inputs, cache, pos):
            pos = jnp.asarray(pos)
            ispec = {k: input_specs["token" if k == "token" else "embeds"]
                     for k in inputs}
            cspec = {k: cache_specs[k] for k in cache}
            # pos: scalar (batch-synchronous) or (B,) per-slot positions
            # (ServeSession continuous batching) - sharded with the batch
            pspec = P() if pos.ndim == 0 else P(b0)
            fn = shard_map(
                lambda p, i, c, q: model.decode_step(p, i, c, q, ctx),
                mesh=mesh,
                in_specs=(param_specs, ispec, cspec, pspec),
                out_specs=(P(b0, None), cspec), check_rep=False)
            return fn(params, inputs, cache, pos)
        return step, param_specs, (input_specs, cache_specs)

    if kind == "prefill":
        if cfg.arch_type == "encdec":
            raise NotImplementedError(
                "enc-dec prefill goes through prefill_encoder + decode")
        pf_cache = {k: v for k, v in cache_specs.items()
                    if k in ("k", "v", "ssm", "conv")}

        def step(params, batch):
            Wb, cp = _batch_geometry(batch, Nm, worker_axes, n_workers,
                                     sc.batch_dim_shardable)
            if "tokens" in batch:
                S = batch["tokens"].shape[1]
            else:
                S = batch["embeds"].shape[1]
            S_loc = S // Nm if cp else S
            lctx = ctx if cp else dataclasses.replace(
                ctx, cp_axis=None, cp_size=1,
                param_gather=_make_param_gather(
                    layout, Nm, expert_local=False, quant_k=sc.weight_k,
                    quant_absolute=sc.weight_absolute,
                    stacked_at_static=True))
            bspec = _batch_specs(batch, Wb, cp)
            out_logits = P(Wb if Wb else None, MODEL_AXIS if cp else None,
                           None)
            fn = shard_map(
                lambda p, b: model.prefill(p, b, max_seq_local=S_loc,
                                           ctx=lctx),
                mesh=mesh, in_specs=(param_specs, bspec),
                out_specs=(out_logits, pf_cache), check_rep=False)
            return fn(params, batch)
        return step, param_specs, (input_specs, pf_cache)

    raise ValueError(f"unknown serve kind {kind!r}")
