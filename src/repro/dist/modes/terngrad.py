"""TernGrad baseline (Wen et al. '17): unbiased stochastic ternary SGD,
2-bit codes on the wire, no error feedback."""
from __future__ import annotations

from repro.core.packing import packed_nbytes
from repro.dist import collectives as C
from repro.dist.modes.base import ModeSpec, WorkerCtx, worker_mean
from repro.opt import engine, grids


def make_updater(tc, ctx: WorkerCtx):
    def upd(g, m, v, e, chunk, meta, a_t, th_t, key):
        codes, scale = engine.quantize_ternary(g, key, backend=ctx.backend)
        codes_rows, _ = C.exchange_packed(codes, 2, ctx.n_workers,
                                          ctx.worker_axes, ctx.wsizes)
        scales = C.gather_rows(scale, ctx.worker_axes)
        recv = grids.ternary_dequantize(codes_rows, scales[:, None])
        return chunk - a_t * worker_mean(recv), m, v, e
    return upd


def wire_nbytes(c: int, n_workers: int, grad_k=None) -> int:
    return n_workers * packed_nbytes(c, 2)


SPEC = ModeSpec(name="terngrad", chunk_sharded_moments=False,
                make_updater=make_updater, wire_nbytes=wire_nbytes)
