"""TernGrad baseline (Wen et al. '17): unbiased stochastic ternary SGD,
2-bit codes on the wire, no error feedback."""
from __future__ import annotations

from repro import comm
from repro.dist import collectives as C
from repro.dist.modes.base import (ModeSpec, WorkerCtx, ctx_tiers,
                                   tier_grad_mean, worker_mean)


def wire_codec(grad_k=None) -> comm.Codec:
    return comm.TernaryCodec()


def make_updater(tc, ctx: WorkerCtx):
    codec = wire_codec()
    tiers = ctx_tiers(ctx)

    def upd(g, m, v, e, chunk, meta, a_t, th_t, key, idx):
        # hierarchical: the step template folds the PRNG key on the
        # *inter* worker index, so a node's devices draw identical
        # stochastic ternary codes for the node-mean gradient.
        g = tier_grad_mean(g, tiers)
        payload, scale = comm.encode_rows(g, codec, ctx.n_workers,
                                          key=key, backend=ctx.backend)
        recv = C.exchange_decode_tiered(payload, scale, codec, meta.c,
                                        tiers, backend=ctx.backend)
        return chunk - a_t * worker_mean(recv), m, v, e
    return upd


SPEC = ModeSpec(name="terngrad", chunk_sharded_moments=False,
                make_updater=make_updater, wire_codec=wire_codec)
