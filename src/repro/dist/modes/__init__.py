"""Per-mode distributed optimizer plugins.

``repro.dist.step`` owns the worker-step template (weight broadcast ->
fwd/bwd -> engine update -> update exchange); each module here owns one
mode's per-leaf math + wire accounting. Adding a mode = one new module
exporting a ``SPEC`` (see ``base.ModeSpec``) + a registry entry below.
"""
from repro.dist.modes.base import ModeSpec, WorkerCtx, worker_mean  # noqa: F401
from repro.dist.modes import qadam, dp_adam, terngrad, ef_sgd

MODES = {m.SPEC.name: m.SPEC for m in (qadam, dp_adam, terngrad, ef_sgd)}


def get_mode(name: str) -> ModeSpec:
    if name not in MODES:
        raise ValueError(f"unknown mode {name!r}; "
                         f"available: {sorted(MODES)}")
    return MODES[name]
