"""Per-mode distributed optimizer plugins.

``repro.dist.step`` owns the worker-step template (weight broadcast ->
fwd/bwd -> engine update -> update exchange); each module here owns one
mode's per-leaf math and declares its wire as a ``repro.comm`` codec.
Adding a mode = one new module exporting a ``SPEC`` (see
``base.ModeSpec``) + a registry entry below.
"""
from repro.dist.modes.base import (  # noqa: F401
    ModeSpec,
    WorkerCtx,
    blockwise_exchange,
    ctx_tiers,
    identity_codec,
    tier_grad_mean,
    worker_mean,
)
from repro.dist.modes import (qadam, dp_adam, terngrad, ef_sgd, efadam,
                              adaptive)

MODES = {m.SPEC.name: m.SPEC
         for m in (qadam, dp_adam, terngrad, ef_sgd, efadam, adaptive)}


def get_mode(name: str) -> ModeSpec:
    if name not in MODES:
        raise ValueError(f"unknown mode {name!r}; "
                         f"available: {sorted(MODES)}")
    return MODES[name]
