"""Adaptive mode: qadam's Adam+EF math with a *per-leaf* wire plan.

Each leaf rides the codec named by ``tc.bit_plan[idx]`` (a tuple of
registry specs produced by :mod:`repro.adapt.allocate`; ``TrainConfig``
is a static jit argument, so a new plan is simply a new compiled step,
keyed into the AOT cache like any other config change). Scalar-scale
lanes (log / uniform-amax) reuse qadam's fused encode+EF exchange;
2-bit blockwise lanes reuse ef_sgd's sign-code exchange with the
per-block scale side-channel, but keep qadam's Adam moments and carry
the true EF residual ``de - deq(own codes)``.

The updater also emits one :mod:`repro.adapt.stats` row per leaf
(``emits_stats``): the step template reduces the rows across the mesh
and the session banks them in the device stats ring - the controller
harvests at replan boundaries, so steady state adds no host syncs.

Without a ``bit_plan`` the mode degenerates to qadam (every leaf on
``log:grad_k``), which is what a fresh adaptive session runs before
the first replan.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro import comm
from repro.adapt import stats as astats
from repro.dist import collectives as C
from repro.dist.modes import qadam
from repro.dist.modes.base import (ModeSpec, WorkerCtx, blockwise_exchange,
                                   ctx_tiers, tier_grad_mean, worker_mean)
from repro.opt import engine


def leaf_codec(tc, idx: int) -> comm.Codec:
    if getattr(tc, "bit_plan", None) is not None:
        return comm.get_codec(tc.bit_plan[idx])
    return qadam.wire_codec(tc.grad_k if tc.grad_k is not None else 6)


def make_updater(tc, ctx: WorkerCtx):
    tiers = ctx_tiers(ctx)

    def upd(g, m, v, e, chunk, meta, a_t, th_t, key, idx):
        codec = leaf_codec(tc, idx)
        g = tier_grad_mean(g, tiers)
        m2, v2, de = engine.adam_ef_moments(
            g, m, v, e, a_t, tc.beta, th_t, tc.eps, backend=ctx.backend)
        if isinstance(codec, comm.BlockwiseCodec):
            recv, e2 = blockwise_exchange(de, codec, meta, ctx, tiers)
        else:
            scale = codec.compute_scale(de)
            payload, e2 = comm.encode_rows_ef(de, scale, codec,
                                              ctx.n_workers,
                                              backend=ctx.backend)
            recv = C.exchange_decode_tiered(payload, scale, codec, meta.c,
                                            tiers, backend=ctx.backend)
        if not tc.error_feedback:
            e2 = jnp.zeros_like(e)
        # stats see the node-mean gradient under a hierarchical
        # topology - the quantity the wire actually carries.
        row = astats.local_stats(de, g)
        return chunk - worker_mean(recv), m2, v2, e2, row
    return upd


SPEC = ModeSpec(name="adaptive", chunk_sharded_moments=False,
                make_updater=make_updater, wire_codec=qadam.wire_codec,
                per_leaf=leaf_codec, emits_stats=True)
