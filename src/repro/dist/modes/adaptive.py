"""Adaptive mode: qadam's Adam+EF math with a *per-leaf* wire plan.

Each leaf rides the codec named by ``tc.bit_plan[idx]`` (a tuple of
registry specs produced by :mod:`repro.adapt.allocate`; ``TrainConfig``
is a static jit argument, so a new plan is simply a new compiled step,
keyed into the AOT cache like any other config change). Scalar-scale
lanes (log / uniform-amax) reuse qadam's fused encode+EF exchange;
2-bit blockwise lanes reuse ef_sgd's sign-code exchange with the
per-block scale side-channel, but keep qadam's Adam moments and carry
the true EF residual ``de - deq(own codes)``.

The updater also emits one :mod:`repro.adapt.stats` row per leaf
(``emits_stats``): the step template reduces the rows across the mesh
and the session banks them in the device stats ring - the controller
harvests at replan boundaries, so steady state adds no host syncs.

Without a ``bit_plan`` the mode degenerates to qadam (every leaf on
``log:grad_k``), which is what a fresh adaptive session runs before
the first replan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import comm
from repro.adapt import stats as astats
from repro.dist import collectives as C
from repro.dist.modes import qadam
from repro.dist.modes.base import ModeSpec, WorkerCtx, worker_mean
from repro.opt import engine, grids


def leaf_codec(tc, idx: int) -> comm.Codec:
    if getattr(tc, "bit_plan", None) is not None:
        return comm.get_codec(tc.bit_plan[idx])
    return qadam.wire_codec(tc.grad_k if tc.grad_k is not None else 6)


def _blockwise_exchange(de, e, codec, meta, ctx):
    """ef_sgd's wire (sign codes + per-block scale gather), EF residual
    against this worker's own dequantized codes."""
    n = de.shape[0]
    block = codec.block
    codes2d, scale_b = engine.quantize_blockwise(de, block,
                                                 backend=ctx.backend)
    deq_own = grids.blockwise_dequantize(codes2d, scale_b).reshape(-1)[:n]
    e2 = de - deq_own
    rows = comm.pad_rows(codes2d.reshape(-1)[:n], ctx.n_workers)
    payload = comm.pack_rows(rows, codec.bits)
    codes_rows = comm.unpack_rows(
        C.exchange_rows(payload, ctx.worker_axes, ctx.wsizes),
        codec.bits, meta.c)
    scales = C.gather_rows(scale_b, ctx.worker_axes)       # (nw, nb)
    elem = jnp.repeat(scales, block, axis=1)               # (nw, nb*block)
    c = meta.c
    total = ctx.n_workers * c
    if elem.shape[1] < total:
        elem = jnp.pad(elem, ((0, 0), (0, total - elem.shape[1])))
    w = C.worker_index(ctx.worker_axes, ctx.wsizes)
    scale_cols = jax.lax.dynamic_slice(
        elem, (jnp.int32(0), w * c), (ctx.n_workers, c))
    recv = codes_rows.astype(jnp.float32) * scale_cols
    return recv, e2


def make_updater(tc, ctx: WorkerCtx):
    def upd(g, m, v, e, chunk, meta, a_t, th_t, key, idx):
        codec = leaf_codec(tc, idx)
        m2, v2, de = engine.adam_ef_moments(
            g, m, v, e, a_t, tc.beta, th_t, tc.eps, backend=ctx.backend)
        if isinstance(codec, comm.BlockwiseCodec):
            recv, e2 = _blockwise_exchange(de, e, codec, meta, ctx)
        else:
            scale = codec.compute_scale(de)
            payload, e2 = comm.encode_rows_ef(de, scale, codec,
                                              ctx.n_workers,
                                              backend=ctx.backend)
            recv = C.exchange_decode(payload, scale, codec, meta.c,
                                     ctx.worker_axes, ctx.wsizes,
                                     backend=ctx.backend)
        if not tc.error_feedback:
            e2 = jnp.zeros_like(e)
        row = astats.local_stats(de, g)
        return chunk - worker_mean(recv), m2, v2, e2, row
    return upd


SPEC = ModeSpec(name="adaptive", chunk_sharded_moments=False,
                make_updater=make_updater, wire_codec=qadam.wire_codec,
                per_leaf=leaf_codec, emits_stats=True)
