"""Blockwise-EF momentum SGD baseline (Zheng et al. '19): sign codes with
per-256-block mean-|.| scales, error feedback on the residual. The wire
itself (shared with the adaptive 2-bit lanes) lives in
``base.blockwise_exchange`` and is topology-aware: hierarchical tiers
ship one sign-code row per node."""
from __future__ import annotations

from repro import comm
from repro.dist.modes.base import (ModeSpec, WorkerCtx, blockwise_exchange,
                                   ctx_tiers, tier_grad_mean, worker_mean)

BLOCK = 256


def wire_codec(grad_k=None) -> comm.Codec:
    return comm.BlockwiseCodec(block=BLOCK)


def make_updater(tc, ctx: WorkerCtx):
    codec = wire_codec()
    tiers = ctx_tiers(ctx)

    def upd(g, m, v, e, chunk, meta, a_t, th_t, key, idx):
        g = tier_grad_mean(g, tiers)
        m2 = tc.beta * m + g
        de = a_t * m2 + e
        recv, e2 = blockwise_exchange(de, codec, meta, ctx, tiers)
        return chunk - worker_mean(recv), m2, v, e2
    return upd


SPEC = ModeSpec(name="ef_sgd", chunk_sharded_moments=False,
                make_updater=make_updater, wire_codec=wire_codec)
