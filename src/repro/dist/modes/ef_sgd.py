"""Blockwise-EF momentum SGD baseline (Zheng et al. '19): sign codes with
per-256-block mean-|.| scales, error feedback on the residual."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.packing import packed_nbytes
from repro.dist import collectives as C
from repro.dist.modes.base import ModeSpec, WorkerCtx, worker_mean
from repro.opt import engine, grids

BLOCK = 256


def make_updater(tc, ctx: WorkerCtx):
    def upd(g, m, v, e, chunk, meta, a_t, th_t, key):
        m2 = tc.beta * m + g
        de = a_t * m2 + e
        n = de.shape[0]
        codes2d, scale_b = engine.quantize_blockwise(de, BLOCK,
                                                     backend=ctx.backend)
        deq_own = grids.blockwise_dequantize(codes2d,
                                             scale_b).reshape(-1)[:n]
        e2 = de - deq_own
        codes_rows, _ = C.exchange_packed(codes2d.reshape(-1)[:n], 2,
                                          ctx.n_workers, ctx.worker_axes,
                                          ctx.wsizes)
        scales = C.gather_rows(scale_b, ctx.worker_axes)   # (nw, nb)
        elem = jnp.repeat(scales, BLOCK, axis=1)           # (nw, nb*BLOCK)
        c = meta.c
        total = ctx.n_workers * c
        if elem.shape[1] < total:
            elem = jnp.pad(elem, ((0, 0), (0, total - elem.shape[1])))
        w = C.worker_index(ctx.worker_axes, ctx.wsizes)
        scale_cols = jax.lax.dynamic_slice(
            elem, (jnp.int32(0), w * c), (ctx.n_workers, c))
        recv = codes_rows.astype(jnp.float32) * scale_cols
        return chunk - worker_mean(recv), m2, v, e2
    return upd


def wire_nbytes(c: int, n_workers: int, grad_k=None) -> int:
    return n_workers * packed_nbytes(c, 2)


SPEC = ModeSpec(name="ef_sgd", chunk_sharded_moments=False,
                make_updater=make_updater, wire_nbytes=wire_nbytes)
