"""Blockwise-EF momentum SGD baseline (Zheng et al. '19): sign codes with
per-256-block mean-|.| scales, error feedback on the residual."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import comm
from repro.dist import collectives as C
from repro.dist.modes.base import ModeSpec, WorkerCtx, worker_mean
from repro.opt import engine, grids

BLOCK = 256


def wire_codec(grad_k=None) -> comm.Codec:
    return comm.BlockwiseCodec(block=BLOCK)


def make_updater(tc, ctx: WorkerCtx):
    codec = wire_codec()

    def upd(g, m, v, e, chunk, meta, a_t, th_t, key, idx):
        m2 = tc.beta * m + g
        de = a_t * m2 + e
        n = de.shape[0]
        codes2d, scale_b = engine.quantize_blockwise(de, BLOCK,
                                                     backend=ctx.backend)
        deq_own = grids.blockwise_dequantize(codes2d,
                                             scale_b).reshape(-1)[:n]
        e2 = de - deq_own
        # wire: codec-packed 2-bit sign rows; the per-block scale
        # side-channel is gathered whole and column-sliced below.
        rows = comm.pad_rows(codes2d.reshape(-1)[:n], ctx.n_workers)
        payload = comm.pack_rows(rows, codec.bits)
        codes_rows = comm.unpack_rows(
            C.exchange_rows(payload, ctx.worker_axes, ctx.wsizes),
            codec.bits, meta.c)
        scales = C.gather_rows(scale_b, ctx.worker_axes)   # (nw, nb)
        elem = jnp.repeat(scales, BLOCK, axis=1)           # (nw, nb*BLOCK)
        c = meta.c
        total = ctx.n_workers * c
        if elem.shape[1] < total:
            elem = jnp.pad(elem, ((0, 0), (0, total - elem.shape[1])))
        w = C.worker_index(ctx.worker_axes, ctx.wsizes)
        scale_cols = jax.lax.dynamic_slice(
            elem, (jnp.int32(0), w * c), (ctx.n_workers, c))
        recv = codes_rows.astype(jnp.float32) * scale_cols
        return chunk - worker_mean(recv), m2, v, e2
    return upd


SPEC = ModeSpec(name="ef_sgd", chunk_sharded_moments=False,
                make_updater=make_updater, wire_codec=wire_codec)
