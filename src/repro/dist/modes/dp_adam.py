"""fp32 data-parallel Adam baseline: gradients all-reduced over the
worker axes, moments chunk-sharded (ZeRO-style), no quantized wire.

Declared ``tiered=False``: the psum below is one reduction over all
worker axes, which the runtime already executes hierarchically on any
physical topology - an explicit intra-tier pre-mean would double-count
node contributions. Accounting keeps its f32 wire on the inter tier."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist import collectives as C
from repro.dist import sharding as SH
from repro.dist.modes.base import ModeSpec, WorkerCtx, identity_codec
from repro.opt import engine


def make_updater(tc, ctx: WorkerCtx):
    def upd(g, m, v, e, chunk, meta, a_t, th_t, key, idx):
        rows = SH.flatten_pad(g, ctx.n_workers)
        if ctx.worker_axes:
            rows = jax.lax.psum(rows, ctx.worker_axes)
        w = C.worker_index(ctx.worker_axes, ctx.wsizes)
        gc = jax.lax.dynamic_index_in_dim(rows, w, 0, keepdims=False)
        # the engine's moment pass with a zero EF residual: de is exactly
        # alpha_t * m' / sqrt(v' + eps)
        m2, v2, de = engine.adam_ef_moments(
            gc, m, v, jnp.zeros_like(m), a_t, tc.beta, th_t, tc.eps,
            backend=ctx.backend)
        return chunk - de, m2, v2, e
    return upd


SPEC = ModeSpec(name="dp_adam", chunk_sharded_moments=True,
                make_updater=make_updater, wire_codec=identity_codec,
                tiered=False)
