"""The paper's mode (Algorithms 2+3): Adam+EF per worker, log-grid Q_g
codes on the update-exchange wire."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.packing import packed_nbytes
from repro.dist import collectives as C
from repro.dist import sharding as SH
from repro.dist.modes.base import ModeSpec, WorkerCtx, worker_mean
from repro.opt import engine, grids


def make_updater(tc, ctx: WorkerCtx):
    def upd(g, m, v, e, chunk, meta, a_t, th_t, key):
        m2, v2, de = engine.adam_ef_moments(
            g, m, v, e, a_t, tc.beta, th_t, tc.eps, backend=ctx.backend)
        if tc.grad_k is None:
            rows = SH.flatten_pad(de, ctx.n_workers)
            recv = C.exchange_rows(rows, ctx.worker_axes, ctx.wsizes)
            e2 = jnp.zeros_like(e)
        else:
            scale = grids.amax_scale(de)
            codes, e2 = engine.ef_quantize(de, scale, tc.grad_k,
                                           backend=ctx.backend)
            if not tc.error_feedback:
                e2 = jnp.zeros_like(e)
            codes_rows, _ = C.exchange_packed(
                codes, C.wire_bits_for_log(tc.grad_k), ctx.n_workers,
                ctx.worker_axes, ctx.wsizes)
            scales = C.gather_rows(scale, ctx.worker_axes)
            recv = grids.log_dequantize(codes_rows, scales[:, None],
                                        tc.grad_k)
        return chunk - worker_mean(recv), m2, v2, e2
    return upd


def wire_nbytes(c: int, n_workers: int, grad_k=None) -> int:
    """Log-grid codes packed to wire_bits_for_log(grad_k); f32 rows when
    the wire is unquantized."""
    if grad_k is None:
        return n_workers * c * 4
    return n_workers * packed_nbytes(c, C.wire_bits_for_log(grad_k))


SPEC = ModeSpec(name="qadam", chunk_sharded_moments=False,
                make_updater=make_updater, wire_nbytes=wire_nbytes)
