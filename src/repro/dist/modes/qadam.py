"""The paper's mode (Algorithms 2+3): Adam+EF per worker, log-grid Q_g
codes on the update-exchange wire (fused encode straight to payload
rows - the codes never hit HBM unpacked)."""
from __future__ import annotations

import jax.numpy as jnp

from repro import comm
from repro.dist import collectives as C
from repro.dist import sharding as SH
from repro.dist.modes.base import (ModeSpec, WorkerCtx, ctx_tiers,
                                   tier_grad_mean, worker_mean)
from repro.opt import engine, grids


def wire_codec(grad_k=None) -> comm.Codec:
    """Log-grid codec packed to its lane width; identity (f32 rows) when
    the wire is unquantized."""
    if grad_k is None:
        return comm.IdentityCodec()
    return comm.LogCodec(k_g=grad_k)


def make_updater(tc, ctx: WorkerCtx):
    codec = wire_codec(tc.grad_k)
    tiers = ctx_tiers(ctx)

    def upd(g, m, v, e, chunk, meta, a_t, th_t, key, idx):
        # hierarchical: fp node-mean gradient first; the quantized
        # exchange below then ships one row per node over the slow tier.
        g = tier_grad_mean(g, tiers)
        m2, v2, de = engine.adam_ef_moments(
            g, m, v, e, a_t, tc.beta, th_t, tc.eps, backend=ctx.backend)
        if tc.grad_k is None:
            rows = SH.flatten_pad(de, ctx.n_workers)
            recv = C.exchange_rows_tiered(rows, tiers)
            e2 = jnp.zeros_like(e)
        else:
            scale = grids.amax_scale(de)
            payload, e2 = comm.encode_rows_ef(de, scale, codec,
                                              ctx.n_workers,
                                              backend=ctx.backend)
            if not tc.error_feedback:
                e2 = jnp.zeros_like(e)
            recv = C.exchange_decode_tiered(payload, scale, codec, meta.c,
                                            tiers, backend=ctx.backend)
        return chunk - worker_mean(recv), m2, v2, e2
    return upd


SPEC = ModeSpec(name="qadam", chunk_sharded_moments=False,
                make_updater=make_updater, wire_codec=wire_codec)
