"""Efficient-Adam-style two-way compression (Chen et al. '22, PAPERS.md):
the paper's qadam worker channel (log-grid Q_g + worker-side EF) PLUS
server-side error feedback on the weight-broadcast channel.

The worker->server direction is exactly qadam's updater. The
server->worker direction quantizes ``x_t + e_srv`` instead of ``x_t``
(``e_srv`` is this server's broadcast residual for its chunk, the new
``es`` state leaf) and carries the quantization error to the next step:

    q_t     = Q_x(x_t + e_srv_t)        (what every worker computes at)
    e_srv'  = (x_t + e_srv_t) - q_t

With ``weight_k=None`` the broadcast is f32 and ``es`` stays zero, so
the mode degenerates to qadam. The channel implementation lives in the
step template (``repro.dist.step``), keyed off ``broadcast_ef``; with
identical workers the whole scheme is bit-exact against a sequential
two-way reference (``tests/dist_scripts/train_equiv_single.py``).

One-line codec swaps: the broadcast codec is the registry's uniform wire
codec, so e.g. 4-bit broadcasts (``weight_k=3``) need no new code.
"""
from __future__ import annotations

from repro.dist.modes import qadam
from repro.dist.modes.base import ModeSpec

SPEC = ModeSpec(name="efadam", chunk_sharded_moments=False,
                make_updater=qadam.make_updater,
                wire_codec=qadam.wire_codec,
                extra_state=("es",), broadcast_ef=True)
