"""Shared pieces of the per-mode distributed updaters.

A mode is a ~50-line plugin: it owns the per-leaf optimizer math (via the
``repro.opt`` engine) and *declares* its update-exchange wire as a
``repro.comm`` codec, while ``repro.dist.step`` owns the mode-independent
worker-step template (weight broadcast -> fwd/bwd -> engine update ->
update exchange).

Updater contract: ``updater(g, m, v, e, chunk, meta, a_t, th_t, key,
idx)`` with the flat per-shard gradient/moments, this worker's master
chunk and its LeafMeta, the scheduled scalars, a per-(leaf, worker,
step) PRNG key, and the leaf's flat index (``metas_flat`` order - what
per-leaf wire plans key on); returns ``(new_chunk, m', v', e')``, or
``(new_chunk, m', v', e', stats_row)`` when the mode sets
``emits_stats`` (one ``adapt.stats`` row per leaf, reduced and ringed
by the step template).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

from repro import comm


@dataclasses.dataclass(frozen=True)
class WorkerCtx:
    """Static worker-axis geometry + engine backend for one train step."""
    worker_axes: Tuple[str, ...]
    wsizes: Tuple[int, ...]
    n_workers: int
    backend: Optional[str] = None   # engine backend; None = auto


@dataclasses.dataclass(frozen=True)
class ModeSpec:
    """One optimizer mode: updater factory + wire declaration + state
    layout.

    ``wire_codec(grad_k)`` names the update-exchange codec; the byte
    accounting behind ``train.loop.comm_bytes_per_step`` derives from it
    (``wire_nbytes`` below - packed codes only, scale side-channels
    excluded), so the figure agrees byte-for-byte with the payload the
    collectives actually move. ``extra_state`` adds chunk-sized state
    leaves; ``broadcast_ef`` turns on server-side error feedback on the
    weight-broadcast channel (the ``efadam`` mode).

    ``per_leaf`` (adaptive modes) maps ``(tc, leaf_idx) -> Codec`` so
    different leaves ride different lanes; ``leaf_codec`` /
    ``leaf_wire_nbytes`` are the indexed entry points every accounting
    and bucketing path goes through - they fall back to the uniform
    ``wire_codec`` when no per-leaf plan is declared. ``emits_stats``
    marks updaters returning a trailing ``adapt.stats`` row.
    """
    name: str
    chunk_sharded_moments: bool
    make_updater: Callable          # (tc, ctx: WorkerCtx) -> updater
    wire_codec: Callable            # (grad_k) -> comm.Codec
    extra_state: Tuple[str, ...] = ()
    broadcast_ef: bool = False
    per_leaf: Optional[Callable] = None   # (tc, leaf_idx) -> comm.Codec
    emits_stats: bool = False

    def wire_nbytes(self, c: int, n_workers: int, grad_k=None) -> int:
        """Per-device, per-leaf update-exchange payload bytes - the
        single source of truth, derived from the declared codec."""
        return n_workers * self.wire_codec(grad_k).payload_nbytes(c)

    def leaf_codec(self, tc, idx: int) -> comm.Codec:
        """Wire codec for leaf ``idx`` (metas_flat order)."""
        if self.per_leaf is not None:
            return self.per_leaf(tc, idx)
        return self.wire_codec(tc.grad_k)

    def leaf_wire_nbytes(self, tc, idx: int, c: int, n_workers: int) -> int:
        """Per-device update-exchange payload bytes for leaf ``idx``."""
        return n_workers * self.leaf_codec(tc, idx).payload_nbytes(c)


def identity_codec(grad_k=None) -> comm.Codec:
    """Wire declaration of the uncompressed (f32 rows) modes."""
    return comm.IdentityCodec()


def worker_mean(rows):
    """Mean over worker rows via pairwise (tree) summation: with n a
    power of two and identical rows (the paper's identical-worker
    equivalence), the result is bit-exact - a sequential reduce
    (((x+x)+x)+x) is not, and its ulp bias flips quantizer codes."""
    def psum_rows(x):
        k = x.shape[0]
        if k == 1:
            return x[0]
        h = k // 2
        return psum_rows(x[:h]) + psum_rows(x[h:])
    return psum_rows(rows) / rows.shape[0]
