"""Shared pieces of the per-mode distributed updaters.

A mode is a ~50-line plugin: it owns the per-leaf optimizer math (via the
``repro.opt`` engine) and *declares* its update-exchange wire as a
``repro.comm`` codec, while ``repro.dist.step`` owns the mode-independent
worker-step template (weight broadcast -> fwd/bwd -> engine update ->
update exchange).

Updater contract: ``updater(g, m, v, e, chunk, meta, a_t, th_t, key)``
with the flat per-shard gradient/moments, this worker's master chunk and
its LeafMeta, the scheduled scalars, and a per-(leaf, worker, step) PRNG
key; returns ``(new_chunk, m', v', e')``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

from repro import comm


@dataclasses.dataclass(frozen=True)
class WorkerCtx:
    """Static worker-axis geometry + engine backend for one train step."""
    worker_axes: Tuple[str, ...]
    wsizes: Tuple[int, ...]
    n_workers: int
    backend: Optional[str] = None   # engine backend; None = auto


@dataclasses.dataclass(frozen=True)
class ModeSpec:
    """One optimizer mode: updater factory + wire declaration + state
    layout.

    ``wire_codec(grad_k)`` names the update-exchange codec; the byte
    accounting behind ``train.loop.comm_bytes_per_step`` derives from it
    (``wire_nbytes`` below - packed codes only, scale side-channels
    excluded), so the figure agrees byte-for-byte with the payload the
    collectives actually move. ``extra_state`` adds chunk-sized state
    leaves; ``broadcast_ef`` turns on server-side error feedback on the
    weight-broadcast channel (the ``efadam`` mode).
    """
    name: str
    chunk_sharded_moments: bool
    make_updater: Callable          # (tc, ctx: WorkerCtx) -> updater
    wire_codec: Callable            # (grad_k) -> comm.Codec
    extra_state: Tuple[str, ...] = ()
    broadcast_ef: bool = False

    def wire_nbytes(self, c: int, n_workers: int, grad_k=None) -> int:
        """Per-device, per-leaf update-exchange payload bytes - the
        single source of truth, derived from the declared codec."""
        return n_workers * self.wire_codec(grad_k).payload_nbytes(c)


def identity_codec(grad_k=None) -> comm.Codec:
    """Wire declaration of the uncompressed (f32 rows) modes."""
    return comm.IdentityCodec()


def worker_mean(rows):
    """Mean over worker rows via pairwise (tree) summation: with n a
    power of two and identical rows (the paper's identical-worker
    equivalence), the result is bit-exact - a sequential reduce
    (((x+x)+x)+x) is not, and its ulp bias flips quantizer codes."""
    def psum_rows(x):
        k = x.shape[0]
        if k == 1:
            return x[0]
        h = k // 2
        return psum_rows(x[:h]) + psum_rows(x[h:])
    return psum_rows(rows) / rows.shape[0]
