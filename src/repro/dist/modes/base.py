"""Shared pieces of the per-mode distributed updaters.

A mode is a ~50-line plugin: it owns the per-leaf optimizer math (via the
``repro.opt`` engine) and its update-exchange wire format, while
``repro.dist.step`` owns the mode-independent worker-step template
(weight broadcast -> fwd/bwd -> engine update -> update exchange).

Updater contract: ``updater(g, m, v, e, chunk, meta, a_t, th_t, key)``
with the flat per-shard gradient/moments, this worker's master chunk and
its LeafMeta, the scheduled scalars, and a per-(leaf, worker, step) PRNG
key; returns ``(new_chunk, m', v', e')``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class WorkerCtx:
    """Static worker-axis geometry + engine backend for one train step."""
    worker_axes: Tuple[str, ...]
    wsizes: Tuple[int, ...]
    n_workers: int
    backend: Optional[str] = None   # engine backend; None = auto


@dataclasses.dataclass(frozen=True)
class ModeSpec:
    """One optimizer mode: updater factory + wire accounting + state
    layout. ``wire_nbytes(c, n_workers, grad_k)`` is the per-device,
    per-leaf update-exchange payload (packed codes only, scale
    side-channels excluded) - the single source of truth behind
    ``train.loop.comm_bytes_per_step``."""
    name: str
    chunk_sharded_moments: bool
    make_updater: Callable          # (tc, ctx: WorkerCtx) -> updater
    wire_nbytes: Callable           # (c, n_workers, grad_k) -> int


def worker_mean(rows):
    """Mean over worker rows via pairwise (tree) summation: with n a
    power of two and identical rows (the paper's identical-worker
    equivalence), the result is bit-exact - a sequential reduce
    (((x+x)+x)+x) is not, and its ulp bias flips quantizer codes."""
    def psum_rows(x):
        k = x.shape[0]
        if k == 1:
            return x[0]
        h = k // 2
        return psum_rows(x[:h]) + psum_rows(x[h:])
    return psum_rows(rows) / rows.shape[0]
