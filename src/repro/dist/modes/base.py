"""Shared pieces of the per-mode distributed updaters.

A mode is a ~50-line plugin: it owns the per-leaf optimizer math (via the
``repro.opt`` engine) and *declares* its update-exchange wire as a
``repro.comm`` codec, while ``repro.dist.step`` owns the mode-independent
worker-step template (weight broadcast -> fwd/bwd -> engine update ->
update exchange).

Updater contract: ``updater(g, m, v, e, chunk, meta, a_t, th_t, key,
idx)`` with the flat per-shard gradient/moments, this worker's master
chunk and its LeafMeta, the scheduled scalars, a per-(leaf, worker,
step) PRNG key, and the leaf's flat index (``metas_flat`` order - what
per-leaf wire plans key on); returns ``(new_chunk, m', v', e')``, or
``(new_chunk, m', v', e', stats_row)`` when the mode sets
``emits_stats`` (one ``adapt.stats`` row per leaf, reduced and ringed
by the step template).

Topology (``repro.dist.topology``): tiered modes open their updater
with :func:`tier_grad_mean` and route the exchange through the
``*_tiered`` collectives. On a flat topology both degenerate to the
legacy ops, so flat results stay bit-identical.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import comm
from repro.dist import collectives as C
from repro.dist.topology import Tiers, flat_tiers
from repro.opt import engine, grids


@dataclasses.dataclass(frozen=True)
class WorkerCtx:
    """Static worker-axis geometry + engine backend for one train step.

    ``tiers`` is the resolved topology (``repro.dist.topology.Tiers``);
    ``None`` means flat over all worker axes (``ctx_tiers`` resolves
    it), so pre-topology callers constructing a WorkerCtx directly keep
    their behavior."""
    worker_axes: Tuple[str, ...]
    wsizes: Tuple[int, ...]
    n_workers: int
    backend: Optional[str] = None   # engine backend; None = auto
    tiers: Optional[Tiers] = None


def ctx_tiers(ctx: WorkerCtx) -> Tiers:
    """The context's resolved tiers, defaulting to flat."""
    if ctx.tiers is not None:
        return ctx.tiers
    return flat_tiers(ctx.worker_axes, ctx.wsizes)


@dataclasses.dataclass(frozen=True)
class ModeSpec:
    """One optimizer mode: updater factory + wire declaration + state
    layout.

    ``wire_codec(grad_k)`` names the update-exchange codec; the byte
    accounting behind ``train.loop.comm_bytes_per_step`` derives from it
    (``wire_nbytes`` below - packed codes only, scale side-channels
    excluded), so the figure agrees byte-for-byte with the payload the
    collectives actually move. ``extra_state`` adds chunk-sized state
    leaves; ``broadcast_ef`` turns on server-side error feedback on the
    weight-broadcast channel (the ``efadam`` mode).

    ``per_leaf`` (adaptive modes) maps ``(tc, leaf_idx) -> Codec`` so
    different leaves ride different lanes; ``leaf_codec`` /
    ``leaf_wire_nbytes`` are the indexed entry points every accounting
    and bucketing path goes through - they fall back to the uniform
    ``wire_codec`` when no per-leaf plan is declared. ``emits_stats``
    marks updaters returning a trailing ``adapt.stats`` row.

    ``tiered``: the updater understands hierarchical topologies (intra
    fp reduce + inter-only exchange). ``dp_adam`` opts out - its psum
    over all worker axes is the same reduction on any topology, so
    tiering it would double-count the intra contributions; accounting
    keeps its wire on the inter tier at flat semantics.
    """
    name: str
    chunk_sharded_moments: bool
    make_updater: Callable          # (tc, ctx: WorkerCtx) -> updater
    wire_codec: Callable            # (grad_k) -> comm.Codec
    extra_state: Tuple[str, ...] = ()
    broadcast_ef: bool = False
    per_leaf: Optional[Callable] = None   # (tc, leaf_idx) -> comm.Codec
    emits_stats: bool = False
    tiered: bool = True

    def wire_nbytes(self, c: int, n_workers: int, grad_k=None) -> int:
        """Per-device, per-leaf update-exchange payload bytes - the
        single source of truth, derived from the declared codec."""
        return n_workers * self.wire_codec(grad_k).payload_nbytes(c)

    def leaf_codec(self, tc, idx: int) -> comm.Codec:
        """Wire codec for leaf ``idx`` (metas_flat order)."""
        if self.per_leaf is not None:
            return self.per_leaf(tc, idx)
        return self.wire_codec(tc.grad_k)

    def leaf_wire_nbytes(self, tc, idx: int, c: int, n_workers: int) -> int:
        """Per-device update-exchange payload bytes for leaf ``idx``."""
        return n_workers * self.leaf_codec(tc, idx).payload_nbytes(c)

    def leaf_tier_nbytes(self, tc, idx: int, c: int, numel: int,
                         n_workers: int, tiers: Optional[Tiers]) -> dict:
        """Per-device update-path bytes for leaf ``idx`` split by link
        tier: ``inter`` is the all-to-all'd payload (packed codes),
        ``intra`` the fp rows the hierarchical gradient pre-reduce
        gathers (``tier_grad_mean``: ``n_intra`` f32 rows of the shard).
        Flat topologies and non-``tiered`` modes report everything on
        the inter tier - exactly ``leaf_wire_nbytes``."""
        if not self.tiered or tiers is None or not tiers.intra_axes:
            return {"inter": self.leaf_wire_nbytes(tc, idx, c, n_workers),
                    "intra": 0}
        codec = self.leaf_codec(tc, idx)
        return {"inter": tiers.n_inter * codec.payload_nbytes(c),
                "intra": tiers.n_intra * numel * 4}


def identity_codec(grad_k=None) -> comm.Codec:
    """Wire declaration of the uncompressed (f32 rows) modes."""
    return comm.IdentityCodec()


def worker_mean(rows):
    """Mean over worker rows via pairwise (tree) summation: with n a
    power of two and identical rows (the paper's identical-worker
    equivalence), the result is bit-exact - a sequential reduce
    (((x+x)+x)+x) is not, and its ulp bias flips quantizer codes."""
    def psum_rows(x):
        k = x.shape[0]
        if k == 1:
            return x[0]
        h = k // 2
        return psum_rows(x[:h]) + psum_rows(x[h:])
    return psum_rows(rows) / rows.shape[0]


def tier_grad_mean(g, tiers: Optional[Tiers]):
    """Hierarchical pre-reduce: all-gather this leaf's flat gradient
    over the intra (fast) axes and tree-mean the rows, so every device
    of a node continues the step with the bit-identical node-mean
    gradient (moments, EF residuals and quantizer codes then agree
    across the node - the exchange can ship one row per node).

    ``worker_mean``'s pairwise tree keeps the mean deterministic and,
    with a power-of-two node width, exact for identical rows - a psum
    would leave reduction order (and therefore ulps) to the compiler.
    Identity on flat tiers."""
    if tiers is None or not tiers.intra_axes:
        return g
    return worker_mean(C.gather_rows(g, tiers.intra_axes))


def blockwise_exchange(de, codec, meta, ctx: WorkerCtx,
                       tiers: Optional[Tiers] = None):
    """The blockwise wire shared by ``ef_sgd`` and the adaptive 2-bit
    lanes: sign codes packed to the codec's lane width with a per-block
    scale side-channel, EF residual against this worker's own
    dequantized codes. The payload all-to-all and the scale gather run
    over the exchange (inter) tier; the received codes are rescaled by
    the *source* worker's scale columns for my chunk. Returns
    ``(recv_rows, e2)`` with ``recv_rows`` of shape ``(n_src, c)``
    (``n_src = n_inter``; ``n_workers`` when flat)."""
    tiers = tiers if tiers is not None else ctx_tiers(ctx)
    n = de.shape[0]
    block = codec.block
    codes2d, scale_b = engine.quantize_blockwise(de, block,
                                                 backend=ctx.backend)
    deq_own = grids.blockwise_dequantize(codes2d, scale_b).reshape(-1)[:n]
    e2 = de - deq_own
    rows = comm.pad_rows(codes2d.reshape(-1)[:n], ctx.n_workers)
    payload = comm.pack_rows(rows, codec.bits)
    codes_rows = comm.unpack_rows(
        C.exchange_rows_tiered(payload, tiers), codec.bits, meta.c)
    scales = C.gather_rows(scale_b, tiers.inter_axes)      # (n_src, nb)
    elem = jnp.repeat(scales, block, axis=1)               # (n_src, nb*block)
    c = meta.c
    total = ctx.n_workers * c
    if elem.shape[1] < total:
        elem = jnp.pad(elem, ((0, 0), (0, total - elem.shape[1])))
    # the scale columns of MY chunk: w indexes over all worker axes -
    # chunk ownership is flat regardless of topology.
    w = C.worker_index(ctx.worker_axes, ctx.wsizes)
    n_src = codes_rows.shape[0]
    scale_cols = jax.lax.dynamic_slice(
        elem, (jnp.int32(0), w * c), (n_src, c))
    return codes_rows.astype(jnp.float32) * scale_cols, e2
