"""The quantized wire of Algorithm 3: every cross-worker collective ships
bit-packed uint8 payloads (plus f32 scales), never raw floats.

Two worker-axis channels (both error-compensated in ``repro.dist.step``):

  * **update exchange** (worker -> server): each worker quantizes its
    update ``Delta_t + e_t`` for the whole model-shard, packs the codes to
    ``wire_bits_for_log(k_g)`` bits each, and all-to-alls chunk rows so
    that worker ``w`` (the "server" for chunk ``w``) receives every
    worker's packed codes for its chunk. Per leaf this moves
    ``n_workers * packed_nbytes(c, bits)`` bytes per device.
  * **weight broadcast** (server -> worker): each server quantizes its
    updated master chunk with Q_x, packs to 8-bit codes and all-gathers,
    so every worker reassembles Q_x(x_{t+1}) for the full shard.

One model-axis channel:

  * **weight gather** (FSDP / serve): per-layer all_gather of weight
    shards, optionally int8 (per-shard amax scale) - the serve path's
    "int8 weight gather" and the train path's ``model_gather_quant``.

All functions that touch ``jax.lax`` collectives must run inside
``shard_map``; the pack/unpack helpers are pure and unit-tested directly
(``tests/test_packing.py``).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import pack_codes, unpack_codes, packed_nbytes
from repro.dist.sharding import chunk_size, flatten_pad
from repro.opt import grids


# ---------------------------------------------------------------------------
# wire format (pure helpers)
# ---------------------------------------------------------------------------

def wire_bits_for_log(k_g: int) -> int:
    """Packed bits/code for the log grid: smallest of {2,4,8} whose signed
    range [-2^(b-1), 2^(b-1)-1] holds codes in [-(k_g+1), k_g+1]."""
    for b in (2, 4, 8):
        if k_g + 1 <= 2 ** (b - 1) - 1:
            return b
    return 8


def pack_rows(codes_rows: jax.Array, bits: int) -> jax.Array:
    """Pack each worker row independently: (n_workers, c) int codes ->
    (n_workers, packed_nbytes(c, bits)) uint8. Row-wise packing keeps
    chunk boundaries byte-aligned for the all_to_all."""
    return jax.vmap(lambda r: pack_codes(r, bits))(codes_rows)


def unpack_rows(packed_rows: jax.Array, bits: int, c: int) -> jax.Array:
    """Inverse of pack_rows -> (n_workers, c) int8."""
    return jax.vmap(lambda r: unpack_codes(r, bits, c))(packed_rows)


amax_scale = grids.amax_scale  # shared zero-guarded scale (one definition)


def uniform_wire_codes(x: jax.Array, scale, k_x: int) -> jax.Array:
    """Q_x codes clipped into int8 wire range. Only k_x=7 can clip (codes
    reach +/-128 when |x| rides the grid edge); the paper's weights live
    well inside [-0.5, 0.5], so the clip is a no-op in practice."""
    codes = grids.uniform_quantize(x, scale, k_x)
    if k_x >= 7:
        codes = jnp.clip(codes, -127, 127)
    return codes.astype(jnp.int8)


# ---------------------------------------------------------------------------
# byte accounting. Counts packed *code* payloads only; the f32 scale
# side-channels (one scalar per leaf per worker, per-256-block for
# ef_sgd) are excluded. The per-mode update-exchange wire math lives on
# each ``repro.dist.modes`` ModeSpec (``wire_nbytes``); only the
# mode-independent weight-broadcast channel is accounted here.
# ---------------------------------------------------------------------------

def weight_broadcast_nbytes(c: int, n_workers: int, full_numel: int,
                            weight_k: Optional[int],
                            min_numel: int = 0) -> int:
    """Per-device bytes of the weight-broadcast payload for one leaf
    (8-bit Q_x codes, or f32 rows for small / unquantized leaves)."""
    if weight_k is None or full_numel < min_numel:
        return n_workers * c * 4
    return n_workers * packed_nbytes(c, 8)


# ---------------------------------------------------------------------------
# worker-axis collectives (inside shard_map)
# ---------------------------------------------------------------------------

def worker_index(axes: Sequence[str], sizes: Sequence[int]) -> jax.Array:
    """Flat worker id, row-major over the worker axes."""
    idx = jnp.int32(0)
    for a, s in zip(axes, sizes):
        idx = idx * s + jax.lax.axis_index(a)
    return idx


def gather_rows(x: jax.Array, axes: Sequence[str]) -> jax.Array:
    """All-gather one per-worker value -> (n_workers, *x.shape), rows in
    flat worker order (same order as worker_index)."""
    r = x[None]
    for a in reversed(tuple(axes)):
        r = jax.lax.all_gather(r, a, axis=0, tiled=True)
    return r


def exchange_rows(rows: jax.Array, axes: Sequence[str],
                  sizes: Sequence[int]) -> jax.Array:
    """All-to-all of worker-ownership rows: send row j to worker j; the
    result's row i is worker i's row for *this* worker. Implemented as one
    transposing all_to_all per worker axis."""
    axes = tuple(axes)
    if not axes:
        return rows
    nw = int(np.prod(sizes))
    x = rows.reshape(tuple(sizes) + rows.shape[1:])
    for i, a in enumerate(axes):
        x = jax.lax.all_to_all(x, a, split_axis=i, concat_axis=i)
    return x.reshape((nw,) + rows.shape[1:])


def exchange_packed(codes: jax.Array, bits: int, n_workers: int,
                    axes: Sequence[str], sizes: Sequence[int]
                    ) -> Tuple[jax.Array, jax.Array]:
    """Update-exchange channel for one leaf: flat int codes -> packed
    uint8 all_to_all -> (n_workers, c) int8 codes received for my chunk.
    Returns (codes_rows, packed_payload) - the payload is returned so the
    wire dtype/size is assertable in tests."""
    c = chunk_size(codes.shape[0], n_workers)
    rows = flatten_pad(codes, n_workers)
    packed = pack_rows(rows, bits)
    assert packed.dtype == jnp.uint8
    recv = exchange_rows(packed, axes, sizes)
    return unpack_rows(recv, bits, c), packed


def broadcast_packed(codes_chunk: jax.Array, axes: Sequence[str]
                     ) -> jax.Array:
    """Weight-broadcast channel for one leaf: my chunk's 8-bit codes ->
    packed uint8 all_gather -> (n_workers, c) int8 codes of every chunk."""
    c = codes_chunk.shape[0]
    packed = pack_codes(codes_chunk, 8)
    assert packed.dtype == jnp.uint8
    rows = gather_rows(packed, axes)
    return unpack_rows(rows, 8, c)


# ---------------------------------------------------------------------------
# model-axis weight gather (FSDP / serve), optionally int8
# ---------------------------------------------------------------------------

def gather_shard(leaf: jax.Array, ax: int, n_shards: int,
                 axis_name: str = "model") -> jax.Array:
    """Plain full-precision all_gather of a weight shard along `ax`."""
    if n_shards <= 1:
        return leaf
    return jax.lax.all_gather(leaf, axis_name, axis=ax, tiled=True)


def quantized_gather_shard(leaf: jax.Array, ax: int, n_shards: int,
                           k_x: int, absolute: bool,
                           axis_name: str = "model") -> jax.Array:
    """Int8 weight gather: quantize the local shard (per-shard scale),
    all_gather codes + scales, dequantize each received segment with its
    source scale. With n_shards == 1 this degenerates to local Q_x."""
    leaf32 = leaf.astype(jnp.float32)
    scale = jnp.float32(0.5) if absolute else amax_scale(leaf32)
    codes = uniform_wire_codes(leaf32, scale, k_x)
    if n_shards <= 1:
        return grids.uniform_dequantize(codes, scale, k_x)
    seg = jax.lax.all_gather(codes, axis_name, axis=0,
                             tiled=False)          # (n_shards, *shard)
    scales = jax.lax.all_gather(scale, axis_name)  # (n_shards,)
    bshape = (n_shards,) + (1,) * leaf.ndim
    deq = grids.uniform_dequantize(seg, scales.reshape(bshape), k_x)
    out = jnp.moveaxis(deq, 0, ax)                 # (..., n_shards, loc, ...)
    shape = list(leaf.shape)
    shape[ax] = shape[ax] * n_shards
    return out.reshape(shape)
