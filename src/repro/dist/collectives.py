"""The quantized wire of Algorithm 3: every cross-worker collective ships
bit-packed uint8 payloads (plus f32 scales), never raw floats.

All compression goes through the ``repro.comm`` codec registry; this
module owns only the mesh topology - which rows move where. The fused
codec entry points (``comm.encode_rows*`` / ``comm.decode_rows``) emit
and consume the exact payload arrays the collectives move, so no
unpacked code tensor is ever materialized between quantize and the wire.

Two worker-axis channels (both error-compensated in ``repro.dist.step``):

  * **update exchange** (worker -> server): each worker fuse-encodes its
    update ``Delta_t + e_t`` for the whole model-shard into per-chunk
    payload rows and all-to-alls them, so worker ``w`` (the "server" for
    chunk ``w``) receives every worker's packed codes for its chunk.
    Per leaf this moves ``n_workers * codec.payload_nbytes(c)`` bytes
    per device.
  * **weight broadcast** (server -> worker): each server encodes its
    updated master chunk with the weight codec (Q_x wire lanes) and
    all-gathers the payload, so every worker reassembles Q_x(x_{t+1})
    for the full shard. The ``efadam`` mode adds server-side error
    feedback on this channel.

One model-axis channel:

  * **weight gather** (FSDP / serve): per-layer all_gather of weight
    shards, optionally int8 (per-shard amax scale) - the serve path's
    "int8 weight gather" and the train path's ``model_gather_quant``.

All functions that touch ``jax.lax`` collectives must run inside
``shard_map``; the codec helpers are pure and unit-tested directly
(``tests/test_packing.py``, ``tests/test_comm_codecs.py``).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import comm
from repro.comm.bits import pack_rows, unpack_rows  # noqa: F401  (compat)
from repro.opt import grids


def wire_bits_for_log(k_g: int) -> int:
    """Packed lane width of the log-grid wire (codec-derived)."""
    return comm.LogCodec(k_g=k_g).bits


amax_scale = grids.amax_scale  # shared zero-guarded scale (one definition)


# ---------------------------------------------------------------------------
# worker-axis collectives (inside shard_map)
# ---------------------------------------------------------------------------

def worker_index(axes: Sequence[str], sizes: Sequence[int]) -> jax.Array:
    """Flat worker id, row-major over the worker axes."""
    idx = jnp.int32(0)
    for a, s in zip(axes, sizes):
        idx = idx * s + jax.lax.axis_index(a)
    return idx


def gather_rows(x: jax.Array, axes: Sequence[str]) -> jax.Array:
    """All-gather one per-worker value -> (n_workers, *x.shape), rows in
    flat worker order (same order as worker_index)."""
    r = x[None]
    for a in reversed(tuple(axes)):
        r = jax.lax.all_gather(r, a, axis=0, tiled=True)
    return r


def exchange_rows(rows: jax.Array, axes: Sequence[str],
                  sizes: Sequence[int]) -> jax.Array:
    """All-to-all of worker-ownership rows: send row j to worker j; the
    result's row i is worker i's row for *this* worker. Implemented as one
    transposing all_to_all per worker axis."""
    axes = tuple(axes)
    if not axes:
        return rows
    nw = int(np.prod(sizes))
    x = rows.reshape(tuple(sizes) + rows.shape[1:])
    for i, a in enumerate(axes):
        x = jax.lax.all_to_all(x, a, split_axis=i, concat_axis=i)
    return x.reshape((nw,) + rows.shape[1:])


# ---------------------------------------------------------------------------
# codec-backed channels: the wire arrays are codec payload rows
# ---------------------------------------------------------------------------

def exchange_decode(payload_rows: jax.Array, scale, codec: comm.Codec,
                    c: int, axes: Sequence[str], sizes: Sequence[int],
                    *, backend: Optional[str] = None) -> jax.Array:
    """Update-exchange channel for one leaf: my per-chunk payload rows
    (from ``comm.encode_rows*``) -> all_to_all -> fused decode of every
    worker's codes for MY chunk with its source scale. Returns
    ``(n_workers, c)`` dequantized rows."""
    assert payload_rows.dtype == jnp.uint8
    recv = exchange_rows(payload_rows, axes, sizes)
    scales = gather_rows(scale, axes)
    return comm.decode_rows(recv, scales, codec, c, backend=backend)


# ---------------------------------------------------------------------------
# per-tier channels (repro.dist.topology): flat tiers route through the
# legacy collectives above op-for-op; hierarchical tiers keep the slow
# (inter/node) links to n_inter rows per leaf
# ---------------------------------------------------------------------------

def exchange_rows_tiered(rows: jax.Array, tiers) -> jax.Array:
    """Tier-aware ``exchange_rows``. Flat: all_to_all over every worker
    axis, unchanged. Hierarchical: gradients were intra-reduced first,
    so every device of a node holds bit-identical rows - each device
    slices the ``n_inter`` rows destined for its intra position
    (``w = node * n_intra + intra``, row-major) and all-to-alls them
    across the node axes only. The slow tier moves ``n_inter`` rows per
    leaf instead of ``n_workers``; the result's row ``k`` is node
    ``k``'s row for this worker's chunk."""
    if not tiers.intra_axes:
        return exchange_rows(rows, tiers.inter_axes, tiers.inter_sizes)
    j = worker_index(tiers.intra_axes, tiers.intra_sizes)
    grid = rows.reshape((tiers.n_inter, tiers.n_intra) + rows.shape[1:])
    mine = jax.lax.dynamic_index_in_dim(grid, j, axis=1, keepdims=False)
    return exchange_rows(mine, tiers.inter_axes, tiers.inter_sizes)


def exchange_decode_tiered(payload_rows: jax.Array, scale,
                           codec: comm.Codec, c: int, tiers,
                           *, backend: Optional[str] = None) -> jax.Array:
    """Tier-aware ``exchange_decode``: payload all-to-all over the
    exchange (inter) tier, source scales gathered over the same tier.
    Returns ``(n_inter, c)`` dequantized rows - one row per exchange
    peer (``n_inter == n_workers`` on a flat topology)."""
    assert payload_rows.dtype == jnp.uint8
    recv = exchange_rows_tiered(payload_rows, tiers)
    scales = gather_rows(scale, tiers.inter_axes)
    return comm.decode_rows(recv, scales, codec, c, backend=backend)


def gather_rows_tiered(x: jax.Array, tiers) -> jax.Array:
    """Tier-aware ``gather_rows``: (n_workers, *x.shape) in flat worker
    order. Hierarchical topologies gather the inter (node) axes first -
    only ``n_inter`` rows cross the slow tier - then fan the stacked
    rows out within each node over the fast links."""
    if not tiers.intra_axes:
        return gather_rows(x, tiers.inter_axes)
    r = gather_rows(x, tiers.inter_axes)     # (n_inter, ...)
    r = gather_rows(r, tiers.intra_axes)     # (n_intra, n_inter, ...)
    r = jnp.swapaxes(r, 0, 1)                # flat (node, intra) order
    return r.reshape((tiers.n_inter * tiers.n_intra,) + x.shape)


def broadcast_decode(payload: jax.Array, scale, codec: comm.Codec, c: int,
                     axes: Sequence[str],
                     *, backend: Optional[str] = None) -> jax.Array:
    """Weight-broadcast channel for one leaf: my chunk's packed payload
    -> all_gather -> fused decode of every chunk with its source scale.
    Returns ``(n_workers, c)`` dequantized rows."""
    assert payload.dtype == jnp.uint8
    rows = gather_rows(payload, axes)
    scales = gather_rows(scale, axes)
    return comm.decode_rows(rows, scales, codec, c, backend=backend)


def broadcast_decode_tiered(payload: jax.Array, scale, codec: comm.Codec,
                            c: int, tiers,
                            *, backend: Optional[str] = None) -> jax.Array:
    """Tier-aware ``broadcast_decode``: hierarchical topologies run the
    payload/scale gathers inter-first (``gather_rows_tiered``), so each
    chunk's packed codes cross the slow tier once per node instead of
    once per device. Returns ``(n_workers, c)`` dequantized rows in flat
    worker order either way."""
    assert payload.dtype == jnp.uint8
    rows = gather_rows_tiered(payload, tiers)
    scales = gather_rows_tiered(scale, tiers)
    return comm.decode_rows(rows, scales, codec, c, backend=backend)


# ---------------------------------------------------------------------------
# model-axis weight gather (FSDP / serve), optionally int8
# ---------------------------------------------------------------------------

def gather_shard(leaf: jax.Array, ax: int, n_shards: int,
                 axis_name: str = "model") -> jax.Array:
    """Plain full-precision all_gather of a weight shard along `ax`."""
    if n_shards <= 1:
        return leaf
    return jax.lax.all_gather(leaf, axis_name, axis=ax, tiled=True)


def quantized_gather_shard(leaf: jax.Array, ax: int, n_shards: int,
                           k_x: int, absolute: bool,
                           axis_name: str = "model") -> jax.Array:
    """Int8 weight gather: quantize the local shard (per-shard scale),
    all_gather codes + scales, dequantize each received segment with its
    source scale. With n_shards == 1 this degenerates to local Q_x."""
    codec = comm.UniformCodec(k_x=k_x, absolute=absolute, wire_bits=8)
    leaf32 = leaf.astype(jnp.float32)
    scale = codec.compute_scale(leaf32)
    # int8 on the wire: the clip above guarantees the int8 range
    codes = codec.quantize(leaf32, scale).astype(jnp.int8)
    if n_shards <= 1:
        return codec.dequantize(codes, scale)
    seg = jax.lax.all_gather(codes, axis_name, axis=0,
                             tiled=False)          # (n_shards, *shard)
    scales = jax.lax.all_gather(scale, axis_name)  # (n_shards,)
    bshape = (n_shards,) + (1,) * leaf.ndim
    deq = codec.dequantize(seg, scales.reshape(bshape))
    out = jnp.moveaxis(deq, 0, ax)                 # (..., n_shards, loc, ...)
    shape = list(leaf.shape)
    shape[ax] = shape[ax] * n_shards
    return out.reshape(shape)
