"""Parameter-layout planner for the distributed step.

Two orthogonal partitions are planned here:

  1. **Model-axis sharding** (context/tensor parallelism): each parameter
     leaf is assigned a shard dim along which it is split over the mesh's
     ``model`` axis. The forward pass holds only the local shard and
     gathers full weights per layer (FSDP-style; see
     ``repro.dist.collectives``). MoE expert tensors are *expert-sharded*
     and never gathered - ``repro.models.layers.moe`` consumes the local
     expert slice directly.

  2. **Worker chunking** (the parameter-server partition of Algorithms
     2+3): each model-shard is flattened, zero-padded and split into
     ``n_workers`` equal chunks; worker ``w`` is the "server" that owns
     chunk ``w``, applies the averaged quantized updates to it, and
     broadcasts its quantized weights back.

Shard-dim encoding (the ``dims`` tree of a :class:`Layout`):

  * ``REPLICATED`` (-1): leaf is not sharded over the model axis.
  * ``ROW`` (-2): sharded along axis 0 of the *unstacked* shape (axis 1 of
    a scan-stacked ``blocks`` leaf).
  * ``EXPERT_MARKER`` (0): MoE expert tensor; sharded along the expert
    axis (axis 0 unstacked) and kept local during the forward gather.
  * ``d >= 1``: sharded along unstacked axis ``d``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

REPLICATED = -1
ROW = -2
EXPERT_MARKER = 0

# pytrees whose top-level key means "leading dim is the scan-over-layers
# stack, not a real parameter axis"
_STACKED_KEYS = ("blocks", "enc_blocks")
_EXPERT_LEAVES = ("w_gate", "w_up", "w_down")


# ---------------------------------------------------------------------------
# worker chunking
# ---------------------------------------------------------------------------

def chunk_size(numel: int, n_workers: int) -> int:
    """Per-worker chunk length: ceil(numel / n_workers)."""
    return -(-int(numel) // int(n_workers))


def flatten_pad(x: jax.Array, n_workers: int) -> jax.Array:
    """Flatten a leaf (or shard) and split it into the worker-ownership
    rows of Algorithm 2: (n_workers, chunk_size), zero padded."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    c = chunk_size(n, n_workers)
    flat = jnp.pad(flat, (0, n_workers * c - n))
    return flat.reshape(n_workers, c)


def unflatten_chunked(rows: jax.Array, shape: Tuple[int, ...]) -> jax.Array:
    """Inverse of flatten_pad: (n_workers, c) -> original shape."""
    numel = int(np.prod(shape)) if shape else 1
    return rows.reshape(-1)[:numel].reshape(shape)


# ---------------------------------------------------------------------------
# model-axis shard dims
# ---------------------------------------------------------------------------

def _is_expert_path(path: Tuple[str, ...]) -> bool:
    return ("moe" in path and "shared" not in path
            and bool(path) and path[-1] in _EXPERT_LEAVES)


def shard_dim_for(path: Tuple[str, ...], shape: Tuple[int, ...],
                  n_shards: int, stacked: bool) -> int:
    """Choose the model-axis shard dim for one leaf (see module docstring
    for the encoding). Replicates anything with no divisible axis."""
    un = tuple(shape[1:]) if stacked else tuple(shape)
    if not un:
        return REPLICATED
    if _is_expert_path(path) and un[0] % n_shards == 0:
        return EXPERT_MARKER
    if n_shards <= 1:
        return REPLICATED
    if un[0] % n_shards == 0:
        return ROW
    for d in range(1, len(un)):
        if un[d] % n_shards == 0:
            return d
    return REPLICATED


def axis_of(dim: int, stacked: bool):
    """Array axis (in the possibly-stacked shape) a shard dim refers to,
    or None for REPLICATED."""
    if dim == REPLICATED:
        return None
    off = 1 if stacked else 0
    return off if dim in (ROW, EXPERT_MARKER) else dim + off


def local_shard_shape(shape: Tuple[int, ...], dim: int, stacked: bool,
                      n_shards: int) -> Tuple[int, ...]:
    """Shape of one model-axis shard of a leaf with the given shape."""
    ax = axis_of(dim, stacked)
    if ax is None:
        return tuple(shape)
    out = list(shape)
    out[ax] = out[ax] // n_shards
    return tuple(out)


def shard_of(leaf: jax.Array, dim: int, stacked: bool, n_shards: int,
             index: int) -> jax.Array:
    """Static slice of model-shard `index` out of a full leaf."""
    ax = axis_of(dim, stacked)
    if ax is None:
        return leaf
    size = leaf.shape[ax] // n_shards
    return jax.lax.slice_in_dim(leaf, index * size, (index + 1) * size,
                                axis=ax)


def leaf_pspec(shape: Tuple[int, ...], dim: int, stacked: bool,
               model_axis: str = "model") -> P:
    """PartitionSpec placing a full leaf on a mesh: shard dim -> model
    axis, everything else replicated (worker axes never shard weights)."""
    ax = axis_of(dim, stacked)
    if ax is None:
        return P()
    ent = [None] * len(shape)
    ent[ax] = model_axis
    return P(*ent)


# ---------------------------------------------------------------------------
# layout
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Layout:
    """Per-leaf sharding plan for one parameter pytree.

    ``_leaves``/``dims``/``stacked`` mirror the params tree; leaves are
    jax.ShapeDtypeStruct / shard-dim int / stacked bool respectively.
    """
    _leaves: Any
    dims: Any
    stacked: Any
    n_shards: int

    def param_specs(self, model_axis: str = "model"):
        """PartitionSpec tree for the *full* (stacked) parameter leaves."""
        return jax.tree.map(
            lambda l, d, s: leaf_pspec(tuple(l.shape), d, s, model_axis),
            self._leaves, self.dims, self.stacked)


def _path_keys(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        out.append(str(getattr(k, "key", getattr(k, "name", k))))
    return tuple(out)


def build_layout(params: Any, n_shards: int) -> Layout:
    """Plan model-axis sharding for a parameter pytree (concrete arrays or
    ShapeDtypeStructs). ``n_shards`` is the mesh's model-axis size."""
    def sds(leaf):
        dtype = getattr(leaf, "dtype", jnp.float32)
        return jax.ShapeDtypeStruct(tuple(leaf.shape), dtype)

    leaves = jax.tree_util.tree_map_with_path(lambda p, l: sds(l), params)
    stacked = jax.tree_util.tree_map_with_path(
        lambda p, l: bool(_path_keys(p)) and
        _path_keys(p)[0] in _STACKED_KEYS, params)
    dims = jax.tree_util.tree_map_with_path(
        lambda p, l: shard_dim_for(
            _path_keys(p), tuple(l.shape), n_shards,
            bool(_path_keys(p)) and _path_keys(p)[0] in _STACKED_KEYS),
        params)
    return Layout(_leaves=leaves, dims=dims, stacked=stacked,
                  n_shards=int(n_shards))


def worker_info(mesh, worker_axes) -> Tuple[Tuple[str, ...],
                                            Tuple[int, ...], int]:
    """Filter requested worker axes to ones present in the mesh; return
    (axes, sizes, n_workers)."""
    ms = dict(zip(mesh.axis_names, mesh.devices.shape))
    axes = tuple(a for a in worker_axes if a in ms)
    sizes = tuple(ms[a] for a in axes)
    return axes, sizes, int(np.prod(sizes)) if sizes else 1


def split_worker_axes(worker_axes, wsizes, n_outer: int, n_inner: int):
    """Plan the per-tier layout of a hierarchical topology: split the
    worker axes into an (outer, inner) tier pair - the prefix whose
    sizes multiply to ``n_outer`` and the suffix multiplying to
    ``n_inner``. Worker ``w = outer_idx * n_inner + inner_idx`` in the
    flat row-major order of ``collectives.worker_index``, so chunk
    ownership and state layout are unchanged by the split.

    Raises when the factorization doesn't land on an axis boundary
    (e.g. asking for 2 nodes out of a single 8-wide ``data`` axis) -
    reshape the mesh so the node tier has its own axis instead.
    """
    axes = tuple(worker_axes)
    sizes = tuple(int(s) for s in wsizes)
    total = int(np.prod(sizes)) if sizes else 1
    if int(n_outer) * int(n_inner) != total:
        raise ValueError(
            f"topology ({n_outer} nodes x {n_inner} devices) needs "
            f"{n_outer * n_inner} workers but the mesh's worker axes "
            f"{dict(zip(axes, sizes))} give {total}")
    prod, k = 1, 0
    while k < len(axes) and prod < n_outer:
        prod *= sizes[k]
        k += 1
    if prod != n_outer:
        raise ValueError(
            f"cannot split worker axes {dict(zip(axes, sizes))} into "
            f"({n_outer} x {n_inner}) tiers on an axis boundary; give "
            f"the node tier its own mesh axis (e.g. pod={n_outer}, "
            f"data={n_inner})")
    return axes[:k], sizes[:k], axes[k:], sizes[k:]
