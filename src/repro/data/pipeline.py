"""Deterministic synthetic data pipelines.

No datasets ship offline, so the pipelines generate structured synthetic
data with a fixed PRNG stream, sharding-aware and reproducible:

  * `lm_batches` - token streams with Zipf-ish unigram structure plus
    copy/induction patterns (so a real LM can actually reduce loss).
  * `classification_batches` - Gaussian-cluster k-class problems (stand-in
    for the paper's MNIST/CIFAR experiments; see benchmarks/).
  * `vlm_batches` / `audio_batches` - embedding front-end stand-ins for
    the llava/whisper input stubs.

Every batch also carries `targets` (next token) and `mask`, pre-shifted so
sequence sharding never needs cross-shard target access.

The token/embedding generators yield **host numpy** batches: device
placement does not belong on the generator's critical path. The
``TrainSession`` prefetcher stages them to device (sharded ``device_put``)
on a background thread; jitted consumers also accept numpy directly.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class LMDataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    copy_period: int = 64   # induction structure: token repeats each period


def _zipf_probs(vocab: int, a: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-a)
    return (p / p.sum()).astype(np.float64)


def lm_batches(cfg: LMDataConfig) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(cfg.seed)
    probs = _zipf_probs(cfg.vocab_size, cfg.zipf_a)
    B, S, P = cfg.global_batch, cfg.seq_len, cfg.copy_period
    while True:
        toks = rng.choice(cfg.vocab_size, size=(B, S + 1), p=probs)
        # induction heads: second half of each period copies the first
        half = P // 2
        for start in range(0, S + 1 - P, P):
            toks[:, start + half:start + P] = toks[:, start:start + half]
        toks = toks.astype(np.int32)
        yield {
            "tokens": np.ascontiguousarray(toks[:, :-1]),
            "targets": np.ascontiguousarray(toks[:, 1:]),
            "mask": np.ones((B, S), np.float32),
        }


def batch_for_model(mcfg: ModelConfig, seq_len: int, global_batch: int,
                    seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Model-aware synthetic batches (handles the stubbed frontends)."""
    base = lm_batches(LMDataConfig(vocab_size=mcfg.vocab_size,
                                   seq_len=seq_len,
                                   global_batch=global_batch, seed=seed))
    rng = np.random.default_rng(seed + 1)
    for b in base:
        if mcfg.input_mode == "embeddings":
            b = dict(b)
            b.pop("tokens")
            b["embeds"] = rng.normal(
                size=(global_batch, seq_len, mcfg.d_model),
                scale=0.7).astype(np.float32)
        elif mcfg.input_mode == "audio+tokens":
            b = dict(b)
            b["audio"] = rng.normal(
                size=(global_batch, mcfg.encoder_seq, mcfg.d_model),
                scale=0.7).astype(np.float32)
        yield b


@dataclasses.dataclass
class ClsDataConfig:
    # defaults tuned so full-precision 8-worker Adam lands ~60-70% test
    # accuracy in a few hundred steps - the regime where the paper's
    # method comparisons (Tables 2-3) actually differentiate
    n_features: int = 32
    n_classes: int = 50
    n_train: int = 8192
    n_test: int = 2048
    cluster_std: float = 2.2
    seed: int = 0


def classification_dataset(cfg: ClsDataConfig):
    """Gaussian clusters with class-dependent low-rank structure."""
    rng = np.random.default_rng(cfg.seed)
    centers = rng.normal(size=(cfg.n_classes, cfg.n_features)) * 1.5
    mix = rng.normal(size=(cfg.n_features, cfg.n_features)) / np.sqrt(
        cfg.n_features)

    def sample(n):
        y = rng.integers(0, cfg.n_classes, size=n)
        x = centers[y] + rng.normal(size=(n, cfg.n_features)) * cfg.cluster_std
        x = np.tanh(x @ mix)  # nonconvex twist
        return x.astype(np.float32), y.astype(np.int32)

    xtr, ytr = sample(cfg.n_train)
    xte, yte = sample(cfg.n_test)
    return (jnp.asarray(xtr), jnp.asarray(ytr),
            jnp.asarray(xte), jnp.asarray(yte))


def classification_batches(x, y, batch: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    n = int(x.shape[0])
    replace = batch > n
    if replace:
        warnings.warn(
            f"classification_batches: batch={batch} exceeds dataset size "
            f"n={n}; sampling with replacement", stacklevel=2)
    while True:
        idx = rng.choice(n, size=batch, replace=replace)
        yield x[idx], y[idx]
