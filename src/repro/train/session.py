"""TrainSession: the async, resumable, prefetching training substrate.

One session owns the training hot loop for BOTH drivers:

  * the distributed path (``repro.dist.step`` ``StepArtifacts`` - any
    ``TrainConfig.mode``): ``TrainSession.from_artifacts(art, batches)``
  * the single-machine path (``repro.core.qadam`` optimizers):
    ``TrainSession.from_optimizer(opt, loss_fn, params, batches)``

replacing the three partially-overlapping drivers that used to exist
(``train.loop.train``'s per-step and scan-chunk branches, the
``opt.multistep`` chunked drivers, and the ad-hoc ``launch.train`` loop -
all now thin shims over this class). The hot loop never stalls on the
host in steady state:

  * **prefetch** - a background host thread pulls numpy batches from the
    generator, stacks scan chunks, and stages them to device
    (``device_put`` with the step's shardings), ``prefetch`` batches deep
    (double-buffered by default). The critical path just picks up
    pre-placed buffers.
  * **device-resident metrics** - per-step losses land in a device ring
    buffer written *inside* the jitted step; the host harvests them with
    one ``device_get`` per log boundary, never per step. ``stats`` counts
    ``dispatches`` and ``syncs`` exactly like ``ServeSession`` so tests
    can assert steady-state training performs ZERO host syncs.
  * **scan chunking** - ``scan_chunk > 1`` compiles K steps into one
    ``lax.scan`` program (state buffers donated), one Python dispatch per
    chunk.
  * **async checkpoints** - at a checkpoint boundary the session snapshots
    the state on device (``jnp.copy`` - an async dispatch, not a sync)
    and hands the snapshot to a writer thread; ``checkpoint/store`` makes
    each write atomic (temp dir + rename) with keep-last-N pruning.
  * **auto-resume** - ``resume(ckpt_dir)`` restores the step counter, the
    optimizer/PRNG state, and the data-stream position (the manifest
    records batches consumed; the fresh generator is fast-forwarded), so
    resumed training is bit-identical to never having stopped
    (``tests/test_train_session.py`` asserts it).
"""
from __future__ import annotations

import dataclasses
import math
import queue
import threading
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.perf import aot
from repro.perf import cache as perf_cache


@dataclasses.dataclass
class SessionConfig:
    log_every: int = 10        # history/log cadence; 0 = never harvest
    eval_every: int = 0
    eval_fn: Optional[Callable] = None   # eval_fn(state) -> loggable
    ckpt_every: int = 0
    ckpt_dir: Optional[str] = None
    ckpt_keep: int = 3         # keep-last-N versioned checkpoints
    ckpt_async: bool = True    # background writer thread
    # repro.comm codec spec for compressed optimizer-moment snapshots
    # (e.g. "uniform_amax:7:w8"); None = raw f32. Master weights and
    # counters always stay exact; see repro.checkpoint.store.
    ckpt_codec: Optional[str] = None
    scan_chunk: int = 1        # K steps per compiled dispatch
    prefetch: int = 2          # staged batches in flight; 0 = synchronous
    check_finite: bool = True  # raise on non-finite harvested loss
    # stats-ring coverage in steps (modes with ``emits_stats``, e.g. the
    # adaptive controller's replan window). The per-step gradient-stats
    # rows stay device-resident for at least this many steps between
    # ``harvest_stats()`` calls; 0 sizes the ring off log_every alone.
    stats_ring: int = 0
    # AOT step artifacts (repro.perf.aot): serialized compiled train
    # steps keyed on (config digest, mesh, mode, codec, arg signature).
    # A warm dir skips trace+lower+compile entirely on restart; None
    # keeps plain jit (which still hits the persistent XLA cache when
    # REPRO_COMPILE_CACHE is set).
    aot_dir: Optional[str] = None


def stack_batches(batch_list):
    """Stack a list of same-shape batch pytrees along a new leading axis
    (the scan axis)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *batch_list)


def _stack_host(batch_list):
    """Host-side (numpy) stack for the prefetch thread."""
    return jax.tree.map(
        lambda *xs: np.stack([np.asarray(x) for x in xs]), *batch_list)


# ---------------------------------------------------------------------------
# the two unified training programs
# ---------------------------------------------------------------------------

class _DistProgram:
    """Distributed path: wraps ``dist.step.StepArtifacts``. State is the
    chunk-sharded dict (master/m/v/e/count); checkpoints store it as-is
    and restore onto the mesh with the original shardings."""

    def __init__(self, art):
        self.art = art
        self._shardings = None

    def init_state(self, key):
        return self.art.init_state(key)

    def step_fn(self):
        return self.art.step_fn

    def place(self, batch, stacked: bool):
        from repro.dist.step import batch_shardings
        if self._shardings is None:
            self._shardings = batch_shardings(self.art, batch,
                                              stacked=stacked)
        return jax.device_put(batch, self._shardings)

    def to_ckpt(self, state):
        return state

    def from_ckpt(self, tree):
        return tree

    def ckpt_shardings(self, state):
        return jax.tree.map(lambda x: x.sharding, state)

    def ring_sharding(self):
        from jax.sharding import NamedSharding, PartitionSpec
        return NamedSharding(self.art.mesh, PartitionSpec())

    def step_count(self, state):
        return state["count"]

    def stats_shape(self):
        """``(n_leaves, N_FIELDS)`` when the mode emits per-leaf stats
        rows (adaptive), else None (no stats ring allocated)."""
        from repro.dist.modes import get_mode
        if not get_mode(self.art.config.mode).emits_stats:
            return None
        from repro.adapt import stats as astats
        n_leaves = len(jax.tree_util.tree_leaves(self.art.layout._leaves))
        return (n_leaves, astats.N_FIELDS)

    def step_token(self):
        """Hashable token the compiled-step cache keys on besides k: the
        TrainConfig, so swapping artifacts (a new adaptive bit plan)
        never reuses the previous plan's executable."""
        return self.art.config

    def aot_facts(self):
        """What the compiled step's machine code depends on beyond the
        argument signature: the mode/codec config and mesh geometry."""
        mesh = self.art.mesh
        return {"program": "dist", "config": self.art.config,
                "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
                "n_workers": self.art.n_workers,
                "worker_axes": self.art.worker_axes}


class _SingleProgram:
    """Single-machine path: a ``repro.core.qadam``-style Optimizer plus a
    ``loss_fn(forward_params, batch)``. State is
    ``{"params": ..., "opt": QAdamState}``."""

    def __init__(self, opt, loss_fn):
        self.opt, self.loss_fn = opt, loss_fn

    def init_state(self, params):
        # private copy: the session donates its state buffers into each
        # dispatch, which would delete the caller's params in place.
        # device_put commits the buffers so dispatch #2 (whose inputs are
        # committed jit outputs) reuses dispatch #1's executable.
        params = jax.device_put(jax.tree.map(jnp.copy, params))
        return {"params": params, "opt": jax.device_put(
            self.opt.init(params))}

    def step_fn(self):
        from repro.core.qadam import apply_updates
        opt, loss_fn = self.opt, self.loss_fn

        def step(state, batch):
            p, s = state["params"], state["opt"]
            fp = opt.forward_params(p, s)
            loss, g = jax.value_and_grad(loss_fn)(fp, batch)
            upd, s2 = opt.update(g, s, p)
            return {"params": apply_updates(p, upd), "opt": s2}, \
                {"loss": loss}
        return step

    def place(self, batch, stacked: bool):
        return jax.device_put(batch)

    def to_ckpt(self, state):
        return {"params": state["params"], "opt": state["opt"]._asdict()}

    def from_ckpt(self, tree):
        from repro.core.qadam import QAdamState
        return {"params": tree["params"], "opt": QAdamState(**tree["opt"])}

    def ckpt_shardings(self, state):
        return None

    def ring_sharding(self):
        return jax.local_devices()[0]

    def step_count(self, state):
        return state["opt"].count

    def stats_shape(self):
        return None

    def step_token(self):
        return None

    def aot_facts(self):
        return {"program": "single",
                "opt": type(self.opt).__name__,
                "opt_cfg": getattr(self.opt, "cfg", None),
                "loss_fn": getattr(self.loss_fn, "__qualname__",
                                   repr(self.loss_fn))}


# ---------------------------------------------------------------------------
# background batch prefetcher
# ---------------------------------------------------------------------------

class _Prefetcher:
    """Pulls host batches from the generator and stages them to device on
    a background thread, ``depth`` staged dispatches ahead. Work is
    demand-driven: the session enqueues the exact dispatch sizes it will
    run (so scan chunks group deterministically and the consumed-batch
    count stays exact for resume). ``depth == 0`` degrades to synchronous
    inline pulls."""

    def __init__(self, batches: Iterator, place: Callable, depth: int,
                 stacked: bool):
        self._batches, self._place, self.depth = batches, place, depth
        self._stacked = stacked   # chunked sessions scan a leading axis
        if depth > 0:
            self._plan: queue.Queue = queue.Queue()
            self._out: queue.Queue = queue.Queue(maxsize=depth)
            self._stop = threading.Event()
            self._thread = threading.Thread(
                target=self._fill, name="train-prefetch", daemon=True)
            self._thread.start()

    def _pull(self, k: int):
        if not self._stacked:
            b = next(self._batches)
            return self._place(b, stacked=False)
        # always stack under a scan program - a tail dispatch of k=1
        # still needs its leading scan axis
        b = _stack_host([next(self._batches) for _ in range(k)])
        return self._place(b, stacked=True)

    def _put(self, item) -> bool:
        while not self._stop.is_set():
            try:
                self._out.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _fill(self):
        while not self._stop.is_set():
            try:
                k = self._plan.get(timeout=0.1)
            except queue.Empty:
                continue
            try:
                item = self._pull(k)
            except BaseException as e:  # surfaced on the consumer side
                self._put(e)
                return
            if not self._put(item):
                return

    def request(self, sizes: List[int]):
        if self.depth > 0:
            for k in sizes:
                self._plan.put(k)

    def get(self, k: int):
        if self.depth <= 0:
            return self._pull(k)
        item = self._out.get()
        if isinstance(item, BaseException):
            raise item
        return item

    def close(self):
        if self.depth > 0:
            self._stop.set()
            while True:     # unblock a producer stuck on a full queue
                try:
                    self._out.get_nowait()
                except queue.Empty:
                    break
            self._thread.join(timeout=2.0)


# ---------------------------------------------------------------------------
# the session
# ---------------------------------------------------------------------------

class TrainSession:
    """Async training session over one program (dist or single-machine).

    Typical use::

        sess = TrainSession.from_artifacts(art, batches, cfg)
        sess.resume(cfg.ckpt_dir)      # no-op when no checkpoint exists
        sess.run(1000)                 # 1000 more steps
        sess.close()

    ``run(n)`` executes exactly ``n`` optimizer steps (``n`` batches).
    ``history`` collects ``{"step", "loss"}`` entries at log boundaries
    and ``{"step", "eval"}`` entries at eval boundaries (each eval gets
    its OWN entry pinned to its own step - the old loop misattached evals
    to the most recent log entry). ``stats`` mirrors ``ServeSession``:
    ``dispatches`` (compiled step calls), ``syncs`` (host device_gets on
    the critical path - zero in steady state), ``steps``, ``ckpts``.
    """

    def __init__(self, program, batches: Iterator,
                 cfg: Optional[SessionConfig] = None, *,
                 init_arg=None, state=None, log: Callable = print):
        self.cfg = cfg or SessionConfig()
        self._program = program
        self._batches = batches
        self._log = log
        self._state = state if state is not None \
            else program.init_state(init_arg)
        self._ckpt_shardings = program.ckpt_shardings(self._state)
        self.chunk = max(1, self.cfg.scan_chunk)
        for name, every in (("log_every", self.cfg.log_every),
                            ("eval_every", self.cfg.eval_every),
                            ("ckpt_every", self.cfg.ckpt_every)):
            if every and self.chunk > 1 and every % self.chunk:
                raise ValueError(
                    f"{name}={every} must be a multiple of "
                    f"scan_chunk={self.chunk}")
        # device loss ring: sized so every unharvested step since the
        # last log boundary stays resident (one extra chunk of slack for
        # boundary-misaligned tails). Stats-emitting modes share the
        # slot geometry, so the cover also spans the stats window.
        cover = max(self.cfg.log_every, self.cfg.stats_ring, 1)
        self._ring_len = self.chunk * (math.ceil(cover / self.chunk) + 1)
        # committed placement (replicated over the program's mesh): an
        # uncommitted jnp.zeros ring would differ from the (committed)
        # dispatch outputs in the jit cache key and force a silent
        # recompile of the whole step on the second dispatch
        self._ring = jax.device_put(jnp.zeros((self._ring_len,),
                                              jnp.float32),
                                    program.ring_sharding())
        # device stats ring (modes with ``emits_stats``): per-step
        # (n_leaves, N_FIELDS) rows written inside the compiled step,
        # harvested in one sync at replan/log boundaries
        sshape = program.stats_shape()
        self._sring = None if sshape is None else jax.device_put(
            jnp.zeros((self._ring_len,) + tuple(sshape), jnp.float32),
            program.ring_sharding())
        self._slot = 0
        self._segments: List[tuple] = []   # (first_step, slot, k) pending
        self._stat_segments: List[tuple] = []
        self._steps_by_k: Dict[Any, Callable] = {}
        self._step = 0                     # optimizer steps executed
        self._prefetch: Optional[_Prefetcher] = None
        # extra JSON-safe entries merged into every checkpoint manifest
        # next to "batches_consumed" - the adaptive controller keeps the
        # live bit plan + stats-EMA here so --adaptive --resume restores
        # the plan (see repro.adapt.controller.AdaptiveController.resume)
        self.ckpt_extra: Dict[str, Any] = {}
        self.history: List[Dict[str, Any]] = []
        # compilations / aot_loads account for every step executable this
        # session built vs loaded ready-made (tests assert a warm AOT dir
        # means a zero-compilation session)
        self.stats = {"dispatches": 0, "syncs": 0, "steps": 0, "ckpts": 0,
                      "compilations": 0, "aot_loads": 0}
        # opt-in persistent XLA cache (no-op unless REPRO_COMPILE_CACHE
        # is set; the launchers enable it unconditionally)
        perf_cache.ensure_persistent_cache()
        self._ckpt_q: Optional[queue.Queue] = None
        self._ckpt_thread: Optional[threading.Thread] = None
        self._ckpt_err: Optional[BaseException] = None
        self._closed = False

    # -- constructors ---------------------------------------------------

    @classmethod
    def from_artifacts(cls, art, batches: Iterator,
                       cfg: Optional[SessionConfig] = None, *, key=None,
                       state=None, log: Callable = print) -> "TrainSession":
        """Distributed session over ``dist.step.make_train_step``
        artifacts (any mode: qadam / dp_adam / terngrad / ef_sgd)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        return cls(_DistProgram(art), batches, cfg, init_arg=key,
                   state=state, log=log)

    @classmethod
    def from_optimizer(cls, opt, loss_fn: Callable, params,
                       batches: Iterator,
                       cfg: Optional[SessionConfig] = None, *,
                       log: Callable = print) -> "TrainSession":
        """Single-machine session over a ``repro.core.qadam``-style
        optimizer and ``loss_fn(forward_params, batch) -> scalar``."""
        return cls(_SingleProgram(opt, loss_fn), batches, cfg,
                   init_arg=params, log=log)

    # -- compiled step plumbing ----------------------------------------

    def _built_step(self, k: int, args: tuple) -> Callable:
        """Compiled ``(state, ring[, sring], slot, batch) -> (state,
        ring[, sring])`` for a k-step dispatch; state and ring buffers
        are donated, the loss (and, for stats-emitting modes, the
        per-leaf stats row) lands in its ring INSIDE the compiled
        program (no host sync).

        The cache key carries the program's ``step_token`` (the dist
        TrainConfig), so a ``swap_artifacts`` plan switch builds a new
        executable instead of reusing the old plan's. With
        ``cfg.aot_dir`` the executable is loaded from / exported to an
        AOT artifact keyed on the program facts + ``args`` signature
        (see ``repro.perf.aot``); ``stats["compilations"]`` vs
        ``stats["aot_loads"]`` records which path ran."""
        ckey = (k, self._program.step_token())
        fn = self._steps_by_k.get(ckey)
        if fn is not None:
            return fn
        step_fn = self._program.step_fn()
        with_stats = self._sring is not None
        if k == 1 and self.chunk == 1:
            def wrapped(state, ring, slot, batch):
                state, metrics = step_fn(state, batch)
                return state, ring.at[slot].set(metrics["loss"])

            def wrapped_s(state, ring, sring, slot, batch):
                state, metrics = step_fn(state, batch)
                sring = jax.lax.dynamic_update_slice(
                    sring, metrics["gstats"][None], (slot, 0, 0))
                return state, ring.at[slot].set(metrics["loss"]), sring
        else:
            def wrapped(state, ring, slot, batches):
                def body(s, b):
                    s2, m = step_fn(s, b)
                    return s2, m["loss"]
                state, losses = jax.lax.scan(body, state, batches)
                return state, jax.lax.dynamic_update_slice(
                    ring, losses, (slot,))

            def wrapped_s(state, ring, sring, slot, batches):
                def body(s, b):
                    s2, m = step_fn(s, b)
                    return s2, (m["loss"], m["gstats"])
                state, (losses, rows) = jax.lax.scan(body, state, batches)
                ring = jax.lax.dynamic_update_slice(ring, losses, (slot,))
                sring = jax.lax.dynamic_update_slice(
                    sring, rows, (slot, 0, 0))
                return state, ring, sring
        # pin the output shardings to the input state's: on small meshes
        # GSPMD canonicalizes size-1-axis specs to replicated on the way
        # out, and the sharding flip would silently recompile the whole
        # step on the SECOND dispatch
        state_sh = jax.tree.map(lambda x: x.sharding, self._state)
        if with_stats:
            out_sh = (state_sh, self._ring.sharding, self._sring.sharding)
            jitted = jax.jit(wrapped_s, donate_argnums=(0, 1, 2),
                             out_shardings=out_sh)
        else:
            out_sh = (state_sh, self._ring.sharding)
            jitted = jax.jit(wrapped, donate_argnums=(0, 1),
                             out_shardings=out_sh)
        facts = dict(self._program.aot_facts(), k=k, chunk=self.chunk,
                     ring_len=self._ring_len)
        fn = aot.load_or_compile(jitted, args, aot_dir=self.cfg.aot_dir,
                                 facts=facts, stats=self.stats)
        self._steps_by_k[ckey] = fn
        return fn

    def _sync(self, x):
        self.stats["syncs"] += 1
        return jax.device_get(x)

    # -- loss ring ------------------------------------------------------

    @staticmethod
    def _push_segment(segments: List[tuple], first_step: int, slot: int,
                      k: int) -> List[tuple]:
        lo, hi = slot, slot + k
        segments = [s for s in segments
                    if s[1] + s[2] <= lo or s[1] >= hi]
        segments.append((first_step, slot, k))
        return segments

    def _record_segment(self, first_step: int, slot: int, k: int):
        self._segments = self._push_segment(self._segments, first_step,
                                            slot, k)
        if self._sring is not None:
            self._stat_segments = self._push_segment(
                self._stat_segments, first_step, slot, k)

    def harvest_losses(self) -> List[tuple]:
        """Pull every still-resident per-step loss off the device in ONE
        host sync; returns ``[(step, loss), ...]`` and clears the pending
        ring segments."""
        if not self._segments:
            return []
        vals = self._sync(self._ring)
        out = []
        for first, slot, k in self._segments:
            for j in range(k):
                out.append((first + j, float(vals[slot + j])))
        self._segments.clear()
        out.sort()
        if self.cfg.check_finite:
            for s, v in out:
                if not np.isfinite(v):
                    raise FloatingPointError(f"loss diverged at step {s}")
        return out

    def harvest_stats(self) -> List[tuple]:
        """Pull every still-resident per-step gradient-stats row off the
        device in ONE host sync; returns ``[(step, (n_leaves, N_FIELDS)
        ndarray), ...]`` sorted by step and clears the pending stats
        segments. Empty for modes without ``emits_stats``."""
        if self._sring is None or not self._stat_segments:
            return []
        vals = self._sync(self._sring)
        out = []
        for first, slot, k in self._stat_segments:
            for j in range(k):
                out.append((first + j, np.asarray(vals[slot + j])))
        self._stat_segments.clear()
        out.sort(key=lambda t: t[0])
        return out

    # -- adaptive replans ----------------------------------------------

    def swap_artifacts(self, art):
        """Swap in new ``StepArtifacts`` (same model/mesh/state layout,
        different TrainConfig - the adaptive controller's new bit plan)
        at a dispatch boundary. The live state buffers carry over
        untouched - masters, moments and EF residuals continue bitwise
        from the previous plan - and the next dispatch compiles (or
        AOT-loads) the new plan's executable under its own cache key."""
        if not isinstance(self._program, _DistProgram):
            raise ValueError("swap_artifacts requires a dist session")
        old = self._program.art
        if (art.mesh is not old.mesh or art.n_workers != old.n_workers
                or art.worker_axes != old.worker_axes):
            raise ValueError("swap_artifacts cannot change mesh geometry")
        self._program.art = art

    # -- checkpointing --------------------------------------------------

    def _ensure_writer(self):
        if self._ckpt_thread is not None:
            return
        self._ckpt_q = queue.Queue()

        def writer():
            while True:
                item = self._ckpt_q.get()
                try:
                    if item is None:
                        return
                    tree, step, extra = item
                    store.save(self.cfg.ckpt_dir, tree, step=step,
                               keep=self.cfg.ckpt_keep, extra=extra,
                               codec=self.cfg.ckpt_codec)
                except BaseException as e:   # re-raised on the main thread
                    self._ckpt_err = e
                finally:
                    self._ckpt_q.task_done()

        self._ckpt_thread = threading.Thread(
            target=writer, name="train-ckpt-writer", daemon=True)
        self._ckpt_thread.start()

    def checkpoint(self, step: Optional[int] = None):
        """Snapshot the live state on device (async copy - the hot loop
        keeps going) and write it out. With ``cfg.ckpt_async`` the
        npz/manifest write (including the device->host transfer) happens
        on the writer thread, off the critical path."""
        if self._ckpt_err is not None:
            err, self._ckpt_err = self._ckpt_err, None
            raise err
        if not self.cfg.ckpt_dir:
            raise ValueError("SessionConfig.ckpt_dir is not set")
        step = self._step if step is None else step
        # device-side copy: the live buffers are donated into the next
        # dispatch, the snapshot stays valid for the writer
        snap = jax.tree.map(jnp.copy, self._state)
        tree = self._program.to_ckpt(snap)
        extra = {"batches_consumed": self._step, **self.ckpt_extra}
        self.stats["ckpts"] += 1
        if self.cfg.ckpt_async:
            self._ensure_writer()
            self._ckpt_q.put((tree, step, extra))
        else:
            store.save(self.cfg.ckpt_dir, tree, step=step,
                       keep=self.cfg.ckpt_keep, extra=extra,
                       codec=self.cfg.ckpt_codec)

    def wait_for_checkpoints(self):
        """Block until every queued async checkpoint hit disk."""
        if self._ckpt_q is not None:
            self._ckpt_q.join()
        if self._ckpt_err is not None:
            err, self._ckpt_err = self._ckpt_err, None
            raise err

    def resume(self, ckpt_dir: Optional[str] = None,
               step: Optional[int] = None) -> int:
        """Restore the latest (or given) checkpoint under ``ckpt_dir``
        (default ``cfg.ckpt_dir``): state, step counter, and data-stream
        position - the generator is fast-forwarded past every batch the
        checkpointed run consumed, so continuing is bit-identical to an
        uninterrupted run. Returns the restored step (0 when no
        checkpoint exists). Must be called before the first ``run()``."""
        if self._step:
            raise RuntimeError("resume() must precede run()")
        d = ckpt_dir or self.cfg.ckpt_dir
        if not d:
            raise ValueError("no checkpoint directory given")
        found = store.latest_step(d) if step is None else step
        if found is None:
            return 0
        like = self._program.to_ckpt(self._state)
        tree = store.restore(d, like, shardings=self._ckpt_shardings,
                             step=found)
        self._state = self._program.from_ckpt(tree)
        extra = store.read_extra(d, step=found)
        consumed = int(extra.get("batches_consumed", found))
        for _ in range(consumed):
            next(self._batches)
        self._step = consumed
        return found

    # -- the hot loop ---------------------------------------------------

    def _boundary_hits(self, i0: int, k: int, every: int) -> List[int]:
        if every <= 0:
            return []
        return [s for s in range(i0 + 1, i0 + k + 1) if s % every == 0]

    def run(self, steps: int) -> List[Dict[str, Any]]:
        """Run exactly ``steps`` more optimizer steps; returns (the tail
        of) ``history``. Steady-state dispatches perform zero host
        syncs; the host only reads the device at log/eval boundaries."""
        if self._closed:
            raise RuntimeError("session is closed")
        if steps <= 0:
            return []
        if self._prefetch is None:
            self._prefetch = _Prefetcher(self._batches,
                                         self._program.place,
                                         self.cfg.prefetch,
                                         stacked=self.chunk > 1)
        q, r = divmod(steps, self.chunk)
        plan = [self.chunk] * q + ([r] if r else [])
        self._prefetch.request(plan)
        hist_start = len(self.history)
        run_start = self._step
        t0 = time.perf_counter()
        for di, k in enumerate(plan):
            batch = self._prefetch.get(k)
            if self._slot + k > self._ring_len:
                self._slot = 0
            sl, i0 = self._slot, self._step
            if self._sring is None:
                args = (self._state, self._ring, sl, batch)
                self._state, self._ring = self._built_step(k, args)(*args)
            else:
                args = (self._state, self._ring, self._sring, sl, batch)
                self._state, self._ring, self._sring = \
                    self._built_step(k, args)(*args)
            self._record_segment(i0 + 1, sl, k)
            self._slot += k
            self._step += k
            self.stats["dispatches"] += 1
            self.stats["steps"] += k
            log_hits = self._boundary_hits(i0, k, self.cfg.log_every)
            last = di == len(plan) - 1
            if self.cfg.log_every > 0 and (log_hits or di == 0 or last):
                want = set(log_hits)
                if di == 0 or last:
                    want.add(i0 + k)
                dt = time.perf_counter() - t0
                rate = dt / max(1, self._step - run_start)
                for s, v in self.harvest_losses():
                    if s in want:
                        self.history.append({"step": s, "loss": v})
                        self._log(f"step {s:5d}  loss {v:.4f}  "
                                  f"({rate:.2f}s/step)")
            # eval/ckpt cadences fire per boundary crossed, but are
            # pinned to the TRUE post-dispatch step (self._step): with a
            # tail-misaligned run() a boundary can fall mid-dispatch, and
            # labeling post-dispatch state with the earlier boundary step
            # would break the bit-identical resume contract. Cadences are
            # validated as chunk multiples, so at most one hit each.
            if self.cfg.eval_fn is not None and \
                    self._boundary_hits(i0, k, self.cfg.eval_every):
                ev = self.cfg.eval_fn(self._state)
                self.history.append({"step": self._step, "eval": ev})
                self._log(f"  eval @{self._step}: {ev}")
            if self.cfg.ckpt_every and self.cfg.ckpt_dir and \
                    self._boundary_hits(i0, k, self.cfg.ckpt_every):
                self.checkpoint()
        return self.history[hist_start:]

    # -- accessors / lifecycle ------------------------------------------

    @property
    def state(self):
        """The live train-state pytree (valid between dispatches)."""
        return self._state

    @property
    def step(self) -> int:
        return self._step

    def close(self):
        """Stop the prefetch thread and flush pending checkpoints."""
        if self._closed:
            return
        self._closed = True
        if self._prefetch is not None:
            self._prefetch.close()
        self.wait_for_checkpoints()
        if self._ckpt_q is not None:
            self._ckpt_q.put(None)
            self._ckpt_thread.join(timeout=5.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


# ---------------------------------------------------------------------------
# single-machine chunked step builders (canonical home; ``opt.multistep``
# re-exports these as the compat surface)
# ---------------------------------------------------------------------------

def make_chunked_update(opt, donate: bool = True) -> Callable:
    """K pure optimizer updates per call: ``fn(params, state, gstack)``
    with ``gstack`` a gradient pytree stacked over a leading step axis.
    Returns (params, state)."""
    from repro.core.qadam import apply_updates

    def chunk(params, state, gstack):
        def body(carry, g):
            p, s = carry
            upd, s2 = opt.update(g, s, p)
            return (apply_updates(p, upd), s2), None
        (p2, s2), _ = jax.lax.scan(body, (params, state), gstack)
        return p2, s2
    return jax.jit(chunk, donate_argnums=(0, 1) if donate else ())


def make_chunked_train_step(opt, loss_fn: Callable,
                            donate: bool = True) -> Callable:
    """K full steps (Q_x forward params -> grad -> engine update -> apply)
    per call: ``fn(params, state, batches)`` with ``batches`` a batch
    pytree stacked over a leading step axis. Returns
    (params, state, per-step losses)."""
    from repro.core.qadam import apply_updates

    def chunk(params, state, batches):
        def body(carry, batch):
            p, s = carry
            fp = opt.forward_params(p, s)
            loss, g = jax.value_and_grad(loss_fn)(fp, batch)
            upd, s2 = opt.update(g, s, p)
            return (apply_updates(p, upd), s2), loss
        (p2, s2), losses = jax.lax.scan(body, (params, state), batches)
        return p2, s2, losses
    return jax.jit(chunk, donate_argnums=(0, 1) if donate else ())
