"""Training-loop compat surface + communication accounting.

The loop itself lives in ``repro.train.session.TrainSession`` (async
prefetch, device-resident metrics, async checkpoints, resume);
``train()`` here is a thin shim kept for existing callers. New code
should construct a ``TrainSession`` directly.

``comm_bytes_per_step`` (the paper's 'Comm' column) stays here - it is
loop-independent accounting over ``StepArtifacts``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.dist.modes import get_mode
from repro.dist.step import (StepArtifacts, TrainConfig, _leaf_meta,
                             weight_wire_codec)
from repro.train.session import SessionConfig, TrainSession


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 0            # 0 = never
    ckpt_dir: Optional[str] = None
    eval_every: int = 0
    eval_fn: Optional[Callable] = None
    # >1: lax.scan this many steps per compiled call (one Python dispatch
    # per chunk, state buffers donated). ckpt/eval/log cadences must be
    # multiples of the chunk.
    scan_chunk: int = 1
    prefetch: int = 2              # staged batches; 0 = synchronous pulls


def comm_bytes_per_step(art: StepArtifacts, tc: TrainConfig) -> Dict[str, float]:
    """Per-device *code* payload bytes of the two quantized worker
    channels (the paper's 'Comm' column), sourced entirely from the
    ``repro.comm`` codec registry: per leaf, the mode's declared
    update-exchange codec (``ModeSpec.wire_nbytes``) plus the
    weight-broadcast codec (``dist.step.weight_wire_codec``). Tests
    assert the figures agree byte-for-byte with the packed payload
    arrays the collectives actually move
    (``tests/test_comm_accounting.py``). The f32 scale side-channels
    (one scalar per leaf per worker; per-256-block for ef_sgd and the
    adaptive blockwise lanes, ~6% of their 2-bit payload) are excluded.

    Per-leaf wire plans (``tc.bit_plan``, the adaptive mode) are exact
    too: the sum goes through ``ModeSpec.leaf_wire_nbytes`` in
    metas_flat order, so the figure tracks every replan.

    Topologies (``repro.dist.topology``): the returned ``"tiers"`` dict
    splits every figure by link tier. Flat topologies report all bytes
    on ``inter`` (one tier is all there is); a hierarchical topology
    moves only ``n_inter`` payload rows per leaf across the slow tier
    (``update_exchange_bytes`` shrinks by exactly ``1/n_intra``) and
    adds the fast-tier fp gradient pre-reduce under
    ``tiers.intra.grad_reduce``. ``adapt.controller.measured_tier_bytes``
    asserts each figure against the actual payload ``.nbytes``."""
    mode = get_mode(tc.mode)
    metas = _leaf_meta(art.layout, art.n_workers)
    leaves = jax.tree.leaves(
        metas, is_leaf=lambda x: type(x).__name__ == "LeafMeta")
    shard_numel = sum(int(np.prod(m.shp)) for m in leaves)
    tiers = getattr(art, "tiers", None)
    hier = (mode.tiered and tiers is not None
            and getattr(tiers, "intra_axes", ()))
    ex_inter = ex_intra = 0
    for i, m in enumerate(leaves):
        d = mode.leaf_tier_nbytes(tc, i, m.c, m.numel, art.n_workers, tiers)
        ex_inter += d["inter"]
        ex_intra += d["intra"]
    bc_inter = bc_intra = 0
    for m in leaves:
        p = weight_wire_codec(tc, m.full_numel).payload_nbytes(m.c)
        if hier:
            # inter-first gather: each chunk's payload crosses the slow
            # tier once per node, then fans out within the node.
            bc_inter += tiers.n_inter * p
            bc_intra += tiers.n_intra * tiers.n_inter * p
        else:
            bc_inter += art.n_workers * p
    bcast = bc_inter + bc_intra
    return {"update_exchange_bytes": ex_inter,
            "weight_broadcast_bytes": bcast,
            "total_bytes": ex_inter + ex_intra + bcast,
            "shard_params": shard_numel,
            "tiers": {
                "inter": {"update_exchange": ex_inter,
                          "weight_broadcast": bc_inter,
                          "total": ex_inter + bc_inter},
                "intra": {"grad_reduce": ex_intra,
                          "weight_broadcast": bc_intra,
                          "total": ex_intra + bc_intra},
            }}


def train(art: StepArtifacts, tc: TrainConfig, batches: Iterator,
          lc: LoopConfig, key=None, state=None, log=print):
    """Compat shim: one-shot ``TrainSession`` run. Returns
    ``(state, history)`` like the old blocking loop; evals now get their
    own history entries (``{"step", "eval"}``) pinned to the eval step."""
    cfg = SessionConfig(log_every=lc.log_every, ckpt_every=lc.ckpt_every,
                        ckpt_dir=lc.ckpt_dir, eval_every=lc.eval_every,
                        eval_fn=lc.eval_fn, scan_chunk=lc.scan_chunk,
                        prefetch=lc.prefetch)
    sess = TrainSession.from_artifacts(art, batches, cfg, key=key,
                                       state=state, log=log)
    try:
        sess.run(lc.steps)
    finally:
        sess.close()
    return sess.state, sess.history
