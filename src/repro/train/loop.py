"""Training loop: wires data pipeline, distributed step, metrics,
checkpointing, and communication accounting together."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint import store
from repro.dist.step import StepArtifacts, TrainConfig
from repro.models.config import ModelConfig


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 0            # 0 = never
    ckpt_dir: Optional[str] = None
    eval_every: int = 0
    eval_fn: Optional[Callable] = None


def comm_bytes_per_step(art: StepArtifacts, tc: TrainConfig) -> Dict[str, float]:
    """Per-device *code* payload bytes of the two quantized worker
    channels (the paper's 'Comm' column). Sums, over parameter leaves,
    the packed uint8 payload each device touches per step - the same
    arithmetic the wire in ``repro.dist.collectives`` performs, so tests
    can assert the two agree byte-for-byte
    (``tests/test_comm_accounting.py``). The f32 scale side-channels
    (one scalar per leaf per worker; per-256-block for ef_sgd, ~6% of
    its 2-bit payload) are excluded."""
    from repro.dist import collectives as C
    from repro.dist.step import _leaf_meta
    metas = _leaf_meta(art.layout, art.n_workers)
    leaves = jax.tree.leaves(
        metas, is_leaf=lambda x: type(x).__name__ == "LeafMeta")
    shard_numel = sum(int(np.prod(m.shp)) for m in leaves)
    a2a = sum(C.update_exchange_nbytes(m.c, art.n_workers, tc.grad_k,
                                       getattr(tc, "mode", "qadam"))
              for m in leaves)
    bcast = sum(C.weight_broadcast_nbytes(
        m.c, art.n_workers, m.full_numel, tc.weight_k,
        tc.weight_q_min_numel) for m in leaves)
    return {"update_exchange_bytes": a2a, "weight_broadcast_bytes": bcast,
            "total_bytes": a2a + bcast, "shard_params": shard_numel}


def train(art: StepArtifacts, tc: TrainConfig, batches: Iterator,
          lc: LoopConfig, key=None, state=None, log=print):
    key = key if key is not None else jax.random.PRNGKey(0)
    if state is None:
        state = art.init_state(key)
    step = jax.jit(art.step_fn)
    history = []
    t0 = time.time()
    for i in range(lc.steps):
        batch = next(batches)
        state, metrics = step(state, batch)
        if (i + 1) % lc.log_every == 0 or i == 0:
            loss = float(metrics["loss"])
            dt = time.time() - t0
            log(f"step {i + 1:5d}  loss {loss:.4f}  "
                f"({dt / (i + 1):.2f}s/step)")
            history.append({"step": i + 1, "loss": loss})
            if not np.isfinite(loss):
                raise FloatingPointError(f"loss diverged at step {i + 1}")
        if lc.ckpt_every and (i + 1) % lc.ckpt_every == 0 and lc.ckpt_dir:
            store.save(lc.ckpt_dir, state, step=i + 1)
        if lc.eval_every and (i + 1) % lc.eval_every == 0 and lc.eval_fn:
            ev = lc.eval_fn(state)
            log(f"  eval @{i + 1}: {ev}")
            history[-1]["eval"] = ev
    return state, history
