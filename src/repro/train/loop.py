"""Training loop: wires data pipeline, distributed step, metrics,
checkpointing, and communication accounting together."""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint import store
from repro.dist import collectives as C
from repro.dist.modes import get_mode
from repro.dist.step import StepArtifacts, TrainConfig, _leaf_meta
from repro.models.config import ModelConfig


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 0            # 0 = never
    ckpt_dir: Optional[str] = None
    eval_every: int = 0
    eval_fn: Optional[Callable] = None
    # >1: lax.scan this many steps per compiled call (one Python dispatch
    # per chunk, state buffers donated). ckpt/eval/log cadences must be
    # multiples of the chunk.
    scan_chunk: int = 1


def comm_bytes_per_step(art: StepArtifacts, tc: TrainConfig) -> Dict[str, float]:
    """Per-device *code* payload bytes of the two quantized worker
    channels (the paper's 'Comm' column). Sums, over parameter leaves,
    the packed uint8 payload each device touches per step - the mode's
    own ``wire_nbytes`` plus the weight-broadcast arithmetic the wire in
    ``repro.dist.collectives`` performs, so tests can assert the figures
    agree byte-for-byte (``tests/test_comm_accounting.py``). The f32
    scale side-channels (one scalar per leaf per worker; per-256-block
    for ef_sgd, ~6% of its 2-bit payload) are excluded."""
    mode = get_mode(tc.mode)
    metas = _leaf_meta(art.layout, art.n_workers)
    leaves = jax.tree.leaves(
        metas, is_leaf=lambda x: type(x).__name__ == "LeafMeta")
    shard_numel = sum(int(np.prod(m.shp)) for m in leaves)
    a2a = sum(mode.wire_nbytes(m.c, art.n_workers, tc.grad_k)
              for m in leaves)
    bcast = sum(C.weight_broadcast_nbytes(
        m.c, art.n_workers, m.full_numel, tc.weight_k,
        tc.weight_q_min_numel) for m in leaves)
    return {"update_exchange_bytes": a2a, "weight_broadcast_bytes": bcast,
            "total_bytes": a2a + bcast, "shard_params": shard_numel}


def _make_chunk_step(step_fn):
    """One compiled program scanning the stacked batch pytree's leading
    axis, donating the state buffers (in-place double-buffer-free update
    on device)."""
    @functools.partial(jax.jit, donate_argnums=(0,))
    def chunk_step(state, batches):
        def body(s, b):
            s2, metrics = step_fn(s, b)
            return s2, metrics["loss"]
        return jax.lax.scan(body, state, batches)
    return chunk_step


def train(art: StepArtifacts, tc: TrainConfig, batches: Iterator,
          lc: LoopConfig, key=None, state=None, log=print):
    key = key if key is not None else jax.random.PRNGKey(0)
    if state is None:
        state = art.init_state(key)
    from repro.opt.multistep import stack_batches
    chunk = max(1, lc.scan_chunk)
    if chunk > 1:
        step = _make_chunk_step(art.step_fn)
    else:
        step = jax.jit(art.step_fn, donate_argnums=(0,))
    history = []
    t0 = time.time()
    for i0 in range(0, lc.steps, chunk):
        k = min(chunk, lc.steps - i0)  # tail chunk stays within budget
        if chunk > 1:
            stacked = stack_batches([next(batches) for _ in range(k)])
            state, losses = step(state, stacked)
            i, loss_now = i0 + k - 1, float(losses[-1])
        else:
            state, metrics = step(state, next(batches))
            i, loss_now = i0, float(metrics["loss"])
        if (i + 1) % lc.log_every < k or i0 == 0:
            dt = time.time() - t0
            log(f"step {i + 1:5d}  loss {loss_now:.4f}  "
                f"({dt / (i + 1):.2f}s/step)")
            history.append({"step": i + 1, "loss": loss_now})
            if not np.isfinite(loss_now):
                raise FloatingPointError(f"loss diverged at step {i + 1}")
        if lc.ckpt_every and (i + 1) % lc.ckpt_every == 0 and lc.ckpt_dir:
            store.save(lc.ckpt_dir, state, step=i + 1)
        if lc.eval_every and (i + 1) % lc.eval_every == 0 and lc.eval_fn:
            ev = lc.eval_fn(state)
            log(f"  eval @{i + 1}: {ev}")
            history[-1]["eval"] = ev
    return state, history
