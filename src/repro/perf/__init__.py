"""Performance infrastructure: profiling, compile caching, AOT steps.

The subsystem that catches fused-kernel regressions at authoring time
(the PR-5 log-decode 0.23x went unnoticed because the CI gate's blanket
1.5x grace tolerated it) and eliminates jit cold-start on fleet
restarts:

  * :mod:`repro.perf.profiling` - ``jax.profiler`` trace harness with
    per-bench annotations (``benchmarks/run.py --trace``);
  * :mod:`repro.perf.cache`     - persistent XLA compilation cache
    setup shared by the launchers and sessions;
  * :mod:`repro.perf.aot`       - ahead-of-time export/load of compiled
    train/decode steps keyed on (config digest, mesh, mode, codec);
  * :mod:`repro.perf.autotune`  - per-backend tile-width tuning for the
    fused kernels (installs ``comm.kernels.set_enc_rows`` and
    ``comm.matmul.set_mm_cols``).
"""
from repro.perf import aot, autotune, cache, profiling
from repro.perf.aot import load_or_compile, step_key
from repro.perf.cache import (cache_entries, disable_persistent_cache,
                              enable_persistent_cache,
                              ensure_persistent_cache)
from repro.perf.profiling import annotate, trace

__all__ = [
    "aot", "autotune", "cache", "profiling",
    "annotate", "trace",
    "cache_entries", "disable_persistent_cache", "enable_persistent_cache",
    "ensure_persistent_cache",
    "load_or_compile", "step_key",
]
