"""Persistent XLA compilation cache setup.

One switch for the whole repo: the launchers enable it by default
(opt out with ``--no-compile-cache``), the sessions enable it when the
``REPRO_COMPILE_CACHE`` env var names a directory (a library must not
silently redirect global jax config, so env-less session construction
leaves the config alone). Entries are content-addressed by XLA on the
(HLO, compile options, backend) fingerprint, so a restarted fleet
recompiles nothing that already compiled anywhere sharing the
directory.

Env knobs::

  REPRO_COMPILE_CACHE=<dir>   enable and place the cache (sessions too)
  REPRO_COMPILE_CACHE=0|off   force-disable, even in launchers

The jax config knobs this sets: ``jax_compilation_cache_dir``,
``jax_persistent_cache_min_entry_size_bytes``,
``jax_persistent_cache_min_compile_time_secs`` (both minimums default
to 0 here: the codec kernels are small and fast to compile, exactly the
entries the stock 1-second threshold would skip).
"""
from __future__ import annotations

import os
from typing import Optional

import jax

ENV_VAR = "REPRO_COMPILE_CACHE"
DEFAULT_CACHE_DIR = os.path.join(
    os.path.expanduser("~"), ".cache", "repro", "xla")

_OFF = ("0", "off", "false", "no")


def enable_persistent_cache(cache_dir: Optional[str] = None, *,
                            min_entry_size_bytes: int = 0,
                            min_compile_time_secs: float = 0.0,
                            ) -> Optional[str]:
    """Point jax's persistent compilation cache at ``cache_dir``.

    Resolution order: explicit ``cache_dir`` > ``$REPRO_COMPILE_CACHE``
    > :data:`DEFAULT_CACHE_DIR`; an env value of ``0``/``off`` disables
    and returns None. Returns the directory in use.
    """
    env = os.environ.get(ENV_VAR, "").strip()
    if cache_dir is None:
        if env.lower() in _OFF:
            return None
        cache_dir = env or DEFAULT_CACHE_DIR
    cache_dir = os.path.abspath(os.path.expanduser(cache_dir))
    os.makedirs(cache_dir, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes",
                      min_entry_size_bytes)
    jax.config.update("jax_persistent_cache_min_compile_time_secs",
                      min_compile_time_secs)
    _reset_cache_state()
    return cache_dir


def ensure_persistent_cache() -> Optional[str]:
    """Session-side hook: enable the cache iff ``$REPRO_COMPILE_CACHE``
    opts in (a library must not silently repoint global jax config)."""
    env = os.environ.get(ENV_VAR, "").strip()
    if not env or env.lower() in _OFF:
        return None
    if jax.config.jax_compilation_cache_dir:
        return jax.config.jax_compilation_cache_dir  # already configured
    return enable_persistent_cache(env)


def _reset_cache_state() -> None:
    """jax initializes its cache object once, at the first compile; a
    dir configured after that point is silently ignored. Resetting the
    cached state makes enable/disable effective mid-process (e.g. a
    session constructed after model init already compiled something)."""
    try:
        from jax.experimental.compilation_cache import compilation_cache
        compilation_cache.reset_cache()
    except Exception:
        pass  # private-ish API: a jax without it just loses mid-process


def disable_persistent_cache() -> None:
    jax.config.update("jax_compilation_cache_dir", None)
    _reset_cache_state()


def cache_entries(cache_dir: str) -> int:
    """Number of cache entries on disk (one content-addressed file per
    compiled executable; ``-atime`` sidecars excluded)."""
    if not os.path.isdir(cache_dir):
        return 0
    return sum(1 for f in os.listdir(cache_dir)
               if not f.endswith("-atime") and not f.startswith("."))
