"""Per-backend tile-width autotuning for the fused Pallas kernels
(codec encode/decode rows, dequant-matmul output columns).

The fused kernels step their grid in ``comm.kernels.enc_rows()`` rows.
The right value is backend-dependent (VMEM budget and VPU shape on TPU
generations differ; interpret mode on CPU prefers fewer, fatter grid
steps), so rather than bake one constant, :func:`tune_enc_rows` times a
codec round-trip at each candidate and installs the winner via
``comm.kernels.set_enc_rows`` for ``jax.default_backend()``.

Retuning changes padded tile shapes, which keys fresh jit entries - by
design the tuned value is installed once at startup (launchers /
benchmarks), not flipped mid-run.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.comm import codec as C
from repro.comm import kernels as K
from repro.comm import matmul as MM
from repro.opt import engine

CANDIDATE_ROWS = (8, 16, 32, 64)
CANDIDATE_COLS = (128, 256, 512)
# exchange-bucket sweep: whole-tree fence, 1 MiB, the 4 MiB default
# heritage, 16 MiB near-whole-tree. The config's current value always
# joins the sweep.
CANDIDATE_BUCKETS = (0, 1 << 20, 4 << 20, 16 << 20)


def _time_roundtrip(spec: str, numel: int, iters: int) -> float:
    cd = C.get_codec(spec)
    x = jax.random.normal(jax.random.PRNGKey(0), (numel,), jnp.float32)
    wb = cd.encode(x, backend="pallas")
    cd.decode(wb, backend="pallas").block_until_ready()  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        wb = cd.encode(x, backend="pallas")
        cd.decode(wb, backend="pallas").block_until_ready()
    return (time.perf_counter() - t0) / iters


def tune_enc_rows(spec: str = "log:6", *, numel: int = 1 << 18,
                  iters: int = 3,
                  candidates: Sequence[int] = CANDIDATE_ROWS,
                  backend: Optional[str] = None,
                  install: bool = True) -> dict:
    """Measure a fused encode+decode round-trip per candidate tile rows.

    Returns ``{"timings_s": {rows: seconds}, "best": rows,
    "installed": bool}``; with ``install=True`` the best value is left
    installed for the active backend (otherwise the previous override is
    restored).
    """
    key = backend or jax.default_backend()
    prev = K._ENC_ROWS_OVERRIDE.get(key)
    timings = {}
    try:
        for rows in candidates:
            K.set_enc_rows(rows, backend=key)
            timings[rows] = _time_roundtrip(spec, numel, iters)
    finally:
        K.set_enc_rows(prev, backend=key)
    best = min(timings, key=timings.get)
    if install:
        K.set_enc_rows(best, backend=key)
    return {"timings_s": timings, "best": best, "installed": install}


def _time_dequant_matmul(m: int, k: int, n: int, k_x: int,
                         iters: int) -> float:
    from repro import comm
    w = jax.random.normal(jax.random.PRNGKey(0), (k, n), jnp.float32)
    codes, scale = engine.quantize_uniform(w, k_x, absolute=False)
    pack_bits = comm.UniformCodec(k_x=k_x, absolute=False).bits
    if pack_bits < 8:
        codes = comm.pack_rows(codes, pack_bits)
    else:
        pack_bits = 0
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k), jnp.float32)
    fn = jax.jit(lambda x, c, s: MM.dequant_matmul(
        x, c, s, k_x=k_x, n=n, pack_bits=pack_bits, backend="pallas"))
    fn(x, codes, scale).block_until_ready()  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(x, codes, scale).block_until_ready()
    return (time.perf_counter() - t0) / iters


def tune_mm_cols(*, m: int = 8, k: int = 1 << 10, n: int = 1 << 10,
                 k_x: int = 6, iters: int = 3,
                 candidates: Sequence[int] = CANDIDATE_COLS,
                 backend: Optional[str] = None,
                 install: bool = True) -> dict:
    """Measure the fused dequant-matmul (``repro.comm.matmul``) per
    candidate output-tile width and install the winner via
    ``set_mm_cols`` - :func:`tune_enc_rows` for the serving matmul path.
    (m, k, n) defaults model a decode-step projection: a few activation
    rows against a square-ish weight.
    """
    key = backend or jax.default_backend()
    prev = MM._MM_COLS_OVERRIDE.get(key)
    timings = {}
    try:
        for cols in candidates:
            if n % cols != 0:
                continue  # tile must cover the output width exactly
            MM.set_mm_cols(cols, backend=key)
            timings[cols] = _time_dequant_matmul(m, k, n, k_x, iters)
    finally:
        MM.set_mm_cols(prev, backend=key)
    best = min(timings, key=timings.get)
    if install:
        MM.set_mm_cols(best, backend=key)
    return {"timings_s": timings, "best": best, "installed": install}


def tune_exchange_buckets(model, mesh, tc, batch, *,
                          candidates: Sequence[int] = CANDIDATE_BUCKETS,
                          steps: int = 3, warmup: int = 1) -> dict:
    """Sweep ``TrainConfig.exchange_bucket_bytes`` against measured
    train-step time for this (model, mesh, topology) - the
    backward/exchange overlap knob the per-bucket gradient fences in
    ``dist.step`` expose. How much overlap pays depends on the wire: a
    hierarchical topology ships ~1/devices_per_node the inter-tier
    payload per leaf, so its best bucket is usually smaller than flat's.

    Unlike the kernel tuners there is no process-global knob to
    install: the bucket size is part of ``TrainConfig`` (its own jit/AOT
    cache key), so the winner is returned as ``"config"`` for the
    caller to build artifacts from. ``tc.exchange_bucket_bytes`` always
    joins the sweep, so ``"speedup"`` (default time / best time) is
    >= 1.0 by construction.

    Returns ``{"timings_s": {bucket: seconds}, "best": bucket,
    "default": tc.exchange_bucket_bytes, "speedup": float,
    "config": TrainConfig}``.
    """
    from repro.dist.step import make_train_step

    cands = list(dict.fromkeys(
        tuple(int(b) for b in candidates) + (tc.exchange_bucket_bytes,)))
    timings = {}
    for b in cands:
        tcb = dataclasses.replace(tc, exchange_bucket_bytes=b)
        art = make_train_step(model, mesh, tcb)
        state = art.init_state(jax.random.PRNGKey(0))
        step = jax.jit(art.step_fn, donate_argnums=(0,))
        for _ in range(max(1, warmup)):
            state, metrics = step(state, batch)
        jax.block_until_ready(metrics["loss"])
        t0 = time.perf_counter()
        for _ in range(steps):
            state, metrics = step(state, batch)
        jax.block_until_ready(metrics["loss"])
        timings[b] = (time.perf_counter() - t0) / steps
        del state
    best = min(timings, key=timings.get)
    return {"timings_s": timings, "best": best,
            "default": tc.exchange_bucket_bytes,
            "speedup": timings[tc.exchange_bucket_bytes] / timings[best],
            "config": dataclasses.replace(tc, exchange_bucket_bytes=best)}
