"""Per-backend tile-width autotuning for the fused codec kernels.

The fused kernels step their grid in ``comm.kernels.enc_rows()`` rows.
The right value is backend-dependent (VMEM budget and VPU shape on TPU
generations differ; interpret mode on CPU prefers fewer, fatter grid
steps), so rather than bake one constant, :func:`tune_enc_rows` times a
codec round-trip at each candidate and installs the winner via
``comm.kernels.set_enc_rows`` for ``jax.default_backend()``.

Retuning changes padded tile shapes, which keys fresh jit entries - by
design the tuned value is installed once at startup (launchers /
benchmarks), not flipped mid-run.
"""
from __future__ import annotations

import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.comm import codec as C
from repro.comm import kernels as K

CANDIDATE_ROWS = (8, 16, 32, 64)


def _time_roundtrip(spec: str, numel: int, iters: int) -> float:
    cd = C.get_codec(spec)
    x = jax.random.normal(jax.random.PRNGKey(0), (numel,), jnp.float32)
    wb = cd.encode(x, backend="pallas")
    cd.decode(wb, backend="pallas").block_until_ready()  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        wb = cd.encode(x, backend="pallas")
        cd.decode(wb, backend="pallas").block_until_ready()
    return (time.perf_counter() - t0) / iters


def tune_enc_rows(spec: str = "log:6", *, numel: int = 1 << 18,
                  iters: int = 3,
                  candidates: Sequence[int] = CANDIDATE_ROWS,
                  backend: Optional[str] = None,
                  install: bool = True) -> dict:
    """Measure a fused encode+decode round-trip per candidate tile rows.

    Returns ``{"timings_s": {rows: seconds}, "best": rows,
    "installed": bool}``; with ``install=True`` the best value is left
    installed for the active backend (otherwise the previous override is
    restored).
    """
    key = backend or jax.default_backend()
    prev = K._ENC_ROWS_OVERRIDE.get(key)
    timings = {}
    try:
        for rows in candidates:
            K.set_enc_rows(rows, backend=key)
            timings[rows] = _time_roundtrip(spec, numel, iters)
    finally:
        K.set_enc_rows(prev, backend=key)
    best = min(timings, key=timings.get)
    if install:
        K.set_enc_rows(best, backend=key)
    return {"timings_s": timings, "best": best, "installed": install}
