"""Ahead-of-time export/load of compiled step executables.

The persistent XLA cache (:mod:`repro.perf.cache`) skips *compilation*
on restart but still pays tracing + lowering per process. This module
removes that too: a compiled step is serialized once
(``jax.experimental.serialize_executable``) under a key digesting
everything its machine code depends on - train/serve config, mesh
geometry, mode, codec specs, abstract argument shapes/dtypes/shardings,
device topology, jax version - and later restarts
``deserialize_and_load`` the executable directly.

Artifact layout: ``<aot_dir>/<sha256[:24]>.aotstep``, a pickle of
``{format, jax, key_facts, payload, in_tree, out_tree}``. Donation is
baked into the serialized executable, so a loaded step donates exactly
the argnums the original ``jax.jit`` did. Any load failure (missing,
corrupt, version-skewed) falls back to compiling - an AOT dir is a
cache, never a correctness dependency.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pickle
from typing import Any, Optional

import jax
from jax.experimental import serialize_executable as _se

FORMAT = 1
SUFFIX = ".aotstep"


def _canon(obj):
    """Canonicalize config-ish objects into JSON-able structure."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {"__dc__": type(obj).__name__,
                **{k: _canon(v) for k, v in
                   dataclasses.asdict(obj).items()}}
    if isinstance(obj, dict):
        return {str(k): _canon(v) for k, v in sorted(obj.items())}
    if isinstance(obj, (list, tuple)):
        return [_canon(v) for v in obj]
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return repr(obj)


def digest(facts: Any) -> str:
    blob = json.dumps(_canon(facts), sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:24]


def _abstract(tree) -> Any:
    """Shape/dtype/sharding signature of an argument pytree. Python
    scalars abstract to their TYPE only: jit traces them as weak-typed
    scalars, so the executable is value-independent (the train step's
    ring ``slot`` varies per dispatch and must not fork the key)."""
    def leaf(x):
        if hasattr(x, "shape") and hasattr(x, "dtype"):
            sh = getattr(x, "sharding", None)
            return (tuple(x.shape), str(x.dtype),
                    repr(sh) if sh is not None else None)
        if isinstance(x, (bool, int, float)):
            return ("py", type(x).__name__)
        return x if isinstance(x, (str, type(None))) else repr(x)
    return jax.tree_util.tree_map(leaf, tree)


def _device_facts() -> dict:
    devs = jax.devices()
    return {
        "backend": jax.default_backend(),
        "device_kind": devs[0].device_kind,
        "n_devices": len(devs),
        "jax": jax.__version__,
    }


def step_key(facts: Any, args: tuple = ()) -> str:
    """Digest of (caller facts, abstract args, device topology) - the
    name the step executable is stored under."""
    return digest({"facts": facts, "args": _abstract(args),
                   "device": _device_facts()})


def artifact_path(aot_dir: str, key: str) -> str:
    return os.path.join(aot_dir, key + SUFFIX)


def save(aot_dir: str, key: str, compiled) -> str:
    """Serialize a ``jax.stages.Compiled`` under ``key``. Atomic
    (tmp + rename) so a crashed writer never leaves a torn artifact."""
    os.makedirs(aot_dir, exist_ok=True)
    payload, in_tree, out_tree = _se.serialize(compiled)
    blob = pickle.dumps({
        "format": FORMAT,
        "jax": jax.__version__,
        "payload": payload,
        "in_tree": in_tree,
        "out_tree": out_tree,
    })
    path = artifact_path(aot_dir, key)
    tmp = path + f".tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(blob)
    os.replace(tmp, path)
    return path


def load(aot_dir: Optional[str], key: str):
    """Load the executable stored under ``key``, or None when absent /
    corrupt / built by a different jax (AOT dirs are caches: every
    failure mode is a miss, never an error)."""
    if not aot_dir:
        return None
    path = artifact_path(aot_dir, key)
    if not os.path.exists(path):
        return None
    try:
        with open(path, "rb") as f:
            art = pickle.load(f)
        if art.get("format") != FORMAT or art.get("jax") != jax.__version__:
            return None
        return _se.deserialize_and_load(art["payload"], art["in_tree"],
                                        art["out_tree"])
    except Exception:
        return None


def load_or_compile(jitted, args: tuple, *, aot_dir: Optional[str],
                    facts: Any, stats: Optional[dict] = None):
    """The session-side entry point: return a ready executable for
    ``jitted(*args)``, loading from ``aot_dir`` when a matching artifact
    exists and compiling + exporting otherwise.

    Without an ``aot_dir`` the jitted callable is returned as-is (its
    first call compiles, possibly hitting the persistent XLA cache).
    ``stats`` counters incremented: ``aot_loads`` on a hit,
    ``compilations`` otherwise (and ``aot_saves`` after an export).
    """
    def bump(name):
        if stats is not None:
            stats[name] = stats.get(name, 0) + 1

    if not aot_dir:
        bump("compilations")
        return jitted
    key = step_key(facts, args)
    compiled = load(aot_dir, key)
    if compiled is not None:
        bump("aot_loads")
        return compiled
    compiled = jitted.lower(*args).compile()
    bump("compilations")
    save(aot_dir, key, compiled)
    bump("aot_saves")
    return compiled
