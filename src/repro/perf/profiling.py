"""``jax.profiler`` trace harness for the benchmark suites.

``benchmarks/run.py --trace`` wraps a whole suite in one
:func:`trace` context and each bench in an :func:`annotate` scope, so
the resulting TensorBoard/Perfetto timeline carries ``bench:<name>``
markers around every kernel dispatch. This is the tool that makes a
fused kernel spending its time in a per-element transcendental (the
log-decode 0.23x regression) visible at authoring time instead of five
PRs later.

View a trace with ``tensorboard --logdir <dir>`` (Profile tab) or feed
the ``*.xplane.pb`` / ``*.trace.json.gz`` under
``<dir>/plugins/profile/<run>/`` to ui.perfetto.dev.
"""
from __future__ import annotations

import contextlib
import glob
import os
from typing import Iterator, Optional

import jax

DEFAULT_TRACE_DIR = os.path.join("results", "traces")


@contextlib.contextmanager
def trace(log_dir: Optional[str] = None, *,
          enabled: bool = True) -> Iterator[Optional[str]]:
    """Profile everything inside the context into ``log_dir``.

    Yields the log dir (or None when ``enabled=False``, so callers can
    wrap unconditionally: ``with trace(d, enabled=args.trace):``).
    """
    if not enabled:
        yield None
        return
    log_dir = log_dir or DEFAULT_TRACE_DIR
    os.makedirs(log_dir, exist_ok=True)
    with jax.profiler.trace(log_dir):
        yield log_dir


def annotate(name: str):
    """Named scope on the profiler timeline (``TraceAnnotation``)."""
    return jax.profiler.TraceAnnotation(name)


def trace_runs(log_dir: str) -> list:
    """Profile run directories written under ``log_dir``, newest last."""
    runs = glob.glob(os.path.join(log_dir, "plugins", "profile", "*"))
    return sorted(runs, key=os.path.getmtime)
