"""Sharding-aware checkpointing: npz payloads + json manifest.

No orbax offline; this stores any pytree of arrays (train state, serve
params) with dtype/shape manifest and restores onto a mesh by device_put
with the original NamedShardings (or host arrays when mesh is None).

Layout: each ``save(path, tree, step=N)`` writes a *step-versioned*
subdirectory ``path/step_00000N/`` via a temp dir + atomic ``os.replace``
- a crash mid-save leaves at most a stale ``.tmp-*`` dir and never
corrupts an existing checkpoint. ``keep`` prunes to the last N steps.
``latest_step``/``restore`` scan the subdirs (and still understand the
pre-PR4 flat single-manifest layout). ``extra`` rides in the manifest for
host-side resume metadata (step counters, data-stream position).

Optional codec compression (``save(..., codec="uniform_amax:7")``):
leaves under the ``codec_keys`` top-level keys (default: the optimizer
moments m/v/e) are stored as ``repro.comm`` wire buffers - packed codes
+ scales - instead of raw f32, cutting moment snapshots ~4x at k_x=7.
The manifest records the codec spec per leaf; ``restore`` decodes
transparently. (Lossy by construction - exactly the quantizer's grid
error; master weights and counters always stay exact.)
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

_STEP_PREFIX = "step_"
_TMP_PREFIX = ".tmp-"

MOMENT_KEYS = ("m", "v", "e", "es")


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "name", k)))
                     for k in path) for path, _ in flat]
    vals = [v for _, v in flat]
    return keys, vals, treedef


def _step_dirname(step: int) -> str:
    return f"{_STEP_PREFIX}{step:08d}"


def _list_steps(path: str) -> List[int]:
    """Step numbers of the complete (manifest-bearing) versioned subdirs."""
    try:
        names = os.listdir(path)
    except FileNotFoundError:
        return []
    steps = []
    for n in names:
        if not n.startswith(_STEP_PREFIX):
            continue
        if not os.path.exists(os.path.join(path, n, "manifest.json")):
            continue  # partial dir (crash before the atomic rename)
        try:
            steps.append(int(n[len(_STEP_PREFIX):]))
        except ValueError:
            continue
    return sorted(steps)


def _resolve_dir(path: str, step: Optional[int] = None) -> str:
    """Directory holding the requested (default: latest) checkpoint.
    Falls back to ``path`` itself for the legacy flat layout."""
    if step is not None:
        return os.path.join(path, _step_dirname(step))
    steps = _list_steps(path)
    if steps:
        return os.path.join(path, _step_dirname(steps[-1]))
    return path


def _codec_eligible(key: str, arr: np.ndarray,
                    codec_keys: Sequence[str]) -> bool:
    return (key.split("/", 1)[0] in codec_keys
            and arr.dtype.kind == "f" and arr.size > 1)


def _write_payload(d: str, tree: Any, step: Optional[int],
                   extra: Optional[Dict], codec: Optional[str] = None,
                   codec_keys: Sequence[str] = MOMENT_KEYS) -> None:
    os.makedirs(d, exist_ok=True)
    if codec is not None:
        from repro import comm
        cd = comm.get_codec(codec)
    keys, vals, _ = _flatten(tree)
    arrays = {}
    manifest = {"step": step, "leaves": []}
    if extra:
        manifest["extra"] = extra
    for i, (k, v) in enumerate(zip(keys, vals)):
        arr = np.asarray(jax.device_get(v))
        shape = list(arr.shape)  # before ascontiguousarray 0d->1d promotion
        arr = np.ascontiguousarray(arr)
        name = f"leaf_{i}"
        if codec is not None and _codec_eligible(k, arr, codec_keys):
            wb = cd.encode(jnp.asarray(arr))
            arrays[name] = np.asarray(jax.device_get(wb.payload))
            arrays[f"{name}_scale"] = np.asarray(jax.device_get(wb.scale))
            manifest["leaves"].append(
                {"key": k, "name": name, "dtype": str(arr.dtype),
                 "shape": shape, "codec": cd.spec})
            continue
        # store raw bytes: npz mangles non-native dtypes (bfloat16 -> |V2)
        arrays[name] = arr.view(np.uint8).reshape(-1)
        manifest["leaves"].append(
            {"key": k, "name": name, "dtype": str(arr.dtype),
             "shape": shape})
    np.savez(os.path.join(d, "arrays.npz"), **arrays)
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def save(path: str, tree: Any, step: Optional[int] = None,
         keep: Optional[int] = None, extra: Optional[Dict] = None,
         codec: Optional[str] = None,
         codec_keys: Sequence[str] = MOMENT_KEYS) -> str:
    """Write one checkpoint; returns the directory written.

    With ``step``, writes ``path/step_XXXXXXXX/`` atomically (temp dir +
    ``os.replace``) and, with ``keep``, prunes to the newest ``keep``
    versioned checkpoints. Without ``step``, writes the flat legacy
    layout directly into ``path`` (serve params snapshots). ``codec``
    turns on codec-compressed snapshots for the ``codec_keys`` subtrees
    (see the module docstring).
    """
    if step is None:
        _write_payload(path, tree, None, extra, codec, codec_keys)
        return path
    os.makedirs(path, exist_ok=True)
    final = os.path.join(path, _step_dirname(step))
    tmp = os.path.join(path, f"{_TMP_PREFIX}{_step_dirname(step)}.{os.getpid()}")
    shutil.rmtree(tmp, ignore_errors=True)
    try:
        _write_payload(tmp, tree, step, extra, codec, codec_keys)
        if os.path.isdir(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    if keep is not None and keep > 0:
        for s in _list_steps(path)[:-keep]:
            shutil.rmtree(os.path.join(path, _step_dirname(s)),
                          ignore_errors=True)
    return final


def restore(path: str, like: Any, shardings: Any = None,
            step: Optional[int] = None) -> Any:
    """`like`: pytree with the target structure. `shardings`: optional
    matching pytree of jax.sharding.Sharding to place leaves. `step`:
    which versioned checkpoint to read (default: the latest; legacy flat
    layouts restore transparently)."""
    d = _resolve_dir(path, step)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    keys, vals, treedef = _flatten(like)
    by_key = {l["key"]: l for l in manifest["leaves"]}
    out = []
    import ml_dtypes  # registers bfloat16 etc. with numpy  # noqa: F401
    for k, v in zip(keys, vals):
        ent = by_key[k]
        raw = data[ent["name"]]
        dt = np.dtype(ent["dtype"])
        if ent.get("codec"):
            from repro import comm
            wb = comm.WireBuffer(
                payload=jnp.asarray(raw),
                scale=jnp.asarray(data[f"{ent['name']}_scale"]),
                spec=ent["codec"], shape=tuple(ent["shape"]))
            arr = np.asarray(jax.device_get(wb.decode())).astype(dt)
        else:
            arr = raw.view(dt).reshape(ent["shape"])
        assert list(arr.shape) == list(v.shape), (k, arr.shape, v.shape)
        out.append(jnp.asarray(arr))
    tree = jax.tree.unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree


def latest_step(path: str) -> Optional[int]:
    steps = _list_steps(path)
    if steps:
        return steps[-1]
    try:  # legacy flat layout
        with open(os.path.join(path, "manifest.json")) as f:
            return json.load(f).get("step")
    except FileNotFoundError:
        return None


def read_extra(path: str, step: Optional[int] = None) -> Dict:
    """Host-side resume metadata stored alongside a checkpoint."""
    d = _resolve_dir(path, step)
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            return json.load(f).get("extra") or {}
    except FileNotFoundError:
        return {}
