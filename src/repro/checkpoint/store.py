"""Sharding-aware checkpointing: npz payloads + json manifest.

No orbax offline; this stores any pytree of arrays (train state, serve
params) with dtype/shape manifest and restores onto a mesh by device_put
with the original NamedShardings (or host arrays when mesh is None).
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "name", k)))
                     for k in path) for path, _ in flat]
    vals = [v for _, v in flat]
    return keys, vals, treedef


def save(path: str, tree: Any, step: Optional[int] = None) -> None:
    os.makedirs(path, exist_ok=True)
    keys, vals, _ = _flatten(tree)
    arrays = {}
    manifest = {"step": step, "leaves": []}
    for i, (k, v) in enumerate(zip(keys, vals)):
        arr = np.asarray(jax.device_get(v))
        shape = list(arr.shape)  # before ascontiguousarray 0d->1d promotion
        arr = np.ascontiguousarray(arr)
        name = f"leaf_{i}"
        # store raw bytes: npz mangles non-native dtypes (bfloat16 -> |V2)
        arrays[name] = arr.view(np.uint8).reshape(-1)
        manifest["leaves"].append(
            {"key": k, "name": name, "dtype": str(arr.dtype),
             "shape": shape})
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def restore(path: str, like: Any, shardings: Any = None) -> Any:
    """`like`: pytree with the target structure. `shardings`: optional
    matching pytree of jax.sharding.Sharding to place leaves."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    keys, vals, treedef = _flatten(like)
    by_key = {l["key"]: l for l in manifest["leaves"]}
    out = []
    import ml_dtypes  # registers bfloat16 etc. with numpy  # noqa: F401
    for k, v in zip(keys, vals):
        ent = by_key[k]
        raw = data[ent["name"]]
        dt = np.dtype(ent["dtype"])
        arr = raw.view(dt).reshape(ent["shape"])
        assert list(arr.shape) == list(v.shape), (k, arr.shape, v.shape)
        out.append(jnp.asarray(arr))
    tree = jax.tree.unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree


def latest_step(path: str) -> Optional[int]:
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            return json.load(f).get("step")
    except FileNotFoundError:
        return None
