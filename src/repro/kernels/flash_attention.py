"""Pallas TPU flash attention (forward) for the serving/prefill hot path.

Online-softmax tiling: queries blocked (BLOCK_Q, head_dim) in VMEM, K/V
streamed in (BLOCK_K, head_dim) tiles; running (max, sum, acc) carried in
VREGs so the S x S score matrix never materializes in HBM. Heads ride the
grid; GQA handled by mapping each q-head block to its kv-head tile via the
BlockSpec index map.

Forward-only by design: training attention goes through the jnp path
(layers.attention) where XLA's remat handles the backward; this kernel is
the inference prefill hot spot (no bwd needed). Supports causal masking,
sliding windows, and gemma-style logit softcap. Validated against
ref.flash_attention_ref in interpret mode (tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, *, seq_kv: int,
                      causal: bool, window: int, softcap, sm_scale: float,
                      q_offset: int):
    # q_ref: (BLOCK_Q, hd); k_ref/v_ref: (seq_kv, hd) - streamed via fori
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * sm_scale
    hd = q.shape[-1]

    m0 = jnp.full((BLOCK_Q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((BLOCK_Q,), jnp.float32)
    acc0 = jnp.zeros((BLOCK_Q, hd), jnp.float32)
    q_pos = q_offset + qi * BLOCK_Q + jnp.arange(BLOCK_Q)

    def body(kb, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice(k_ref[0], (kb * BLOCK_K, 0),
                                  (BLOCK_K, hd)).astype(jnp.float32)
        v = jax.lax.dynamic_slice(v_ref[0], (kb * BLOCK_K, 0),
                                  (BLOCK_K, hd)).astype(jnp.float32)
        s = q @ k.T                                    # (BQ, BK)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        kv_pos = kb * BLOCK_K + jnp.arange(BLOCK_K)
        mask = jnp.ones((BLOCK_Q, BLOCK_K), bool)
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if window:
            mask &= kv_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    nkb = seq_kv // BLOCK_K
    if causal:
        # only stream kv blocks that can be visible to this q block
        last = (q_offset + (qi + 1) * BLOCK_Q + BLOCK_K - 1) // BLOCK_K
        nkb_eff = jnp.minimum(nkb, last)
    else:
        nkb_eff = nkb
    m, l, acc = jax.lax.fori_loop(0, nkb_eff, body, (m0, l0, acc0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap=None, q_offset: int = 0,
                    interpret=None) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Skv, K, hd) with H % K == 0.

    Sq % BLOCK_Q == 0 and Skv % BLOCK_K == 0 (pad upstream).
    Returns (B, Sq, H, hd) in q.dtype.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Sq, H, hd = q.shape
    _, Skv, K, _ = k.shape
    rep = H // K
    sm_scale = 1.0 / np.sqrt(hd)

    qt = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(B * K, Skv, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(B * K, Skv, hd)

    kern = functools.partial(
        _flash_fwd_kernel, seq_kv=Skv, causal=causal, window=int(window),
        softcap=softcap, sm_scale=sm_scale, q_offset=q_offset)
    out = pl.pallas_call(
        kern,
        grid=(B * H, Sq // BLOCK_Q),
        in_specs=[
            pl.BlockSpec((1, BLOCK_Q, hd), lambda h, i: (h, i, 0)),
            # GQA: q head h reads kv head h // rep of batch h // H
            pl.BlockSpec((1, Skv, hd),
                         lambda h, i: ((h // (H)) * K + (h % H) // rep,
                                       0, 0)),
            pl.BlockSpec((1, Skv, hd),
                         lambda h, i: ((h // (H)) * K + (h % H) // rep,
                                       0, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_Q, hd), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
