"""Thin re-exports of the canonical grid/update math.

Historically this module held the pure-jnp oracles the Pallas kernels were
tested against. That math now lives once in ``repro.opt.grids`` (and the
kernel bodies call it directly), so this module is just the old import
surface.
"""
from __future__ import annotations

from repro.opt.grids import (  # noqa: F401
    adam_ef_moments,
    adam_ef_quantize,
    block_amax,
    log_dequantize,
    log_quantize,
    uniform_dequantize,
    uniform_quantize,
)
