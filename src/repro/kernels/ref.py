"""Pure-jnp oracles for the Pallas kernels.

These are *the* semantics; kernels must match them to within float tolerance.
They mirror repro.core.quantizers but operate on the flat 2D-tiled layout the
kernels use and expose the scale as an explicit argument (the kernels are the
second pass of a two-pass scheme: pass 1 block-amax, pass 2 quantize).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def block_amax(x: jax.Array) -> jax.Array:
    """Per-call global amax (oracle for the amax pass)."""
    return jnp.max(jnp.abs(x.astype(jnp.float32)))


def log_quantize(x: jax.Array, scale: jax.Array, k_g: int) -> jax.Array:
    """Log-grid codes given a scale. Matches quantizers.log_encode."""
    x = x.astype(jnp.float32)
    s = jnp.maximum(scale, 1e-30)
    y = jnp.abs(x) / s
    safe_y = jnp.where(y > 0, y, 1.0)
    e_float = -jnp.log2(safe_y)
    e_lo = jnp.floor(e_float)
    mid = 1.5 * jnp.exp2(-(e_lo + 1.0))
    e_near = jnp.where(y >= mid, e_lo, e_lo + 1.0)
    e_near = jnp.clip(e_near, 0.0, float(k_g))
    is_zero = (y < jnp.exp2(-float(k_g)) * 0.5) | (x == 0.0)
    mag = jnp.where(is_zero, 0.0, float(k_g) + 1.0 - e_near)
    return jnp.where(x < 0, -mag, mag).astype(jnp.int8)


def log_dequantize(codes: jax.Array, scale: jax.Array, k_g: int) -> jax.Array:
    c = codes.astype(jnp.float32)
    mag = jnp.abs(c)
    val = jnp.exp2(mag - (float(k_g) + 1.0))
    val = jnp.where(mag == 0, 0.0, val)
    return jnp.sign(c) * val * scale


def uniform_quantize(x: jax.Array, scale: jax.Array, k_x: int) -> jax.Array:
    n = float(2 ** k_x)
    y = jnp.clip(x.astype(jnp.float32) / jnp.maximum(scale, 1e-30), -1.0, 1.0)
    # codes live in [-2^k, 2^k]: int8 only holds k_x <= 6
    dt = jnp.int8 if k_x <= 6 else jnp.int16
    return jnp.round(y * n).astype(dt)


def uniform_dequantize(codes: jax.Array, scale: jax.Array, k_x: int) -> jax.Array:
    n = float(2 ** k_x)
    return codes.astype(jnp.float32) / n * scale


def adam_ef_moments(g, m, v, e, *, alpha_t, beta, theta_t, eps):
    """Pass-1 oracle: moment updates + the full-precision Delta_t + e_t.

    Returns (m_new, v_new, delta_plus_e). Algorithm 1 lines 3-5 pre-quantize.
    """
    g = g.astype(jnp.float32)
    v_new = theta_t * v + (1.0 - theta_t) * g * g
    m_new = beta * m + (1.0 - beta) * g
    delta_plus_e = alpha_t * m_new / jnp.sqrt(v_new + eps) + e
    return m_new, v_new, delta_plus_e


def adam_ef_quantize(delta_plus_e, scale, k_g):
    """Pass-2 oracle: codes + residual (Algorithm 1 lines 5-6)."""
    codes = log_quantize(delta_plus_e, scale, k_g)
    deq = log_dequantize(codes, scale, k_g)
    e_new = delta_plus_e - deq
    return codes, e_new
