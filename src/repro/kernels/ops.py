"""Jit'd public wrappers around the Pallas kernels.

Handles layout: arbitrary-shape tensors are flattened and zero-padded to the
kernels' (R, 128) tile layout (R a multiple of BLOCK_ROWS), then restored.
On non-TPU backends the kernels run in interpret mode (correctness path);
`use_pallas=False` falls back to the pure-jnp oracle in ref.py.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels import quantize as qk
from repro.kernels import adam_ef as ak

_TILE = qk.BLOCK_ROWS * qk.LANES


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _to_tiles(x: jax.Array) -> Tuple[jax.Array, int]:
    flat = x.reshape(-1)
    numel = flat.shape[0]
    pad = (-numel) % _TILE
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, qk.LANES), numel


def _from_tiles(x2d: jax.Array, numel: int, shape) -> jax.Array:
    return x2d.reshape(-1)[:numel].reshape(shape)


@functools.partial(jax.jit, static_argnames=("k_g", "use_pallas"))
def quantize_log(x: jax.Array, k_g: int = 6, use_pallas: bool = True):
    """Paper's Q_g encode: per-tensor amax scale + log-grid int8 codes."""
    if not use_pallas:
        scale = jnp.maximum(ref.block_amax(x), 1e-30)
        return ref.log_quantize(x, scale, k_g), scale
    x2d, numel = _to_tiles(x.astype(jnp.float32))
    scale = jnp.maximum(qk.amax_pallas(x2d, interpret=_interpret()), 1e-30)
    codes2d = qk.log_quantize_pallas(x2d, scale, k_g, interpret=_interpret())
    return _from_tiles(codes2d, numel, x.shape), scale


@functools.partial(jax.jit, static_argnames=("k_g", "use_pallas", "out_dtype"))
def dequantize_log(codes: jax.Array, scale: jax.Array, k_g: int = 6,
                   use_pallas: bool = True, out_dtype=jnp.float32):
    if not use_pallas:
        return ref.log_dequantize(codes, scale, k_g).astype(out_dtype)
    c2d, numel = _to_tiles(codes)
    out = qk.log_dequantize_pallas(c2d, scale, k_g, out_dtype=out_dtype,
                                   interpret=_interpret())
    return _from_tiles(out, numel, codes.shape)


@functools.partial(jax.jit, static_argnames=("k_x", "absolute", "use_pallas"))
def quantize_uniform(x: jax.Array, k_x: int = 7, absolute: bool = True,
                     use_pallas: bool = True):
    """Paper's Q_x encode (absolute grid over [-0.5, 0.5] by default)."""
    if absolute:
        scale = jnp.float32(0.5)
    else:
        x2d0, _ = _to_tiles(x.astype(jnp.float32))
        scale = jnp.maximum(
            qk.amax_pallas(x2d0, interpret=_interpret()) if use_pallas
            else ref.block_amax(x), 1e-30)
    if not use_pallas:
        return ref.uniform_quantize(x, scale, k_x), scale
    x2d, numel = _to_tiles(x.astype(jnp.float32))
    codes2d = qk.uniform_quantize_pallas(x2d, scale, k_x,
                                         interpret=_interpret())
    return _from_tiles(codes2d, numel, x.shape), scale


@functools.partial(jax.jit, static_argnames=("k_x", "use_pallas", "out_dtype"))
def dequantize_uniform(codes: jax.Array, scale: jax.Array, k_x: int = 7,
                       use_pallas: bool = True, out_dtype=jnp.float32):
    if not use_pallas:
        return ref.uniform_dequantize(codes, scale, k_x).astype(out_dtype)
    c2d, numel = _to_tiles(codes)
    out = qk.uniform_dequantize_pallas(c2d, scale, k_x, out_dtype=out_dtype,
                                       interpret=_interpret())
    return _from_tiles(out, numel, codes.shape)


@functools.partial(jax.jit, static_argnames=("k_g", "use_pallas"))
def adam_ef_step(g, m, v, e, alpha_t, beta, theta_t, eps,
                 k_g: int = 6, use_pallas: bool = True):
    """Fused worker inner loop of Algorithm 3: returns
    (m', v', codes, scale, e')."""
    if not use_pallas:
        m_n, v_n, de = ref.adam_ef_moments(
            g, m, v, e, alpha_t=alpha_t, beta=beta, theta_t=theta_t, eps=eps)
        scale = jnp.maximum(ref.block_amax(de), 1e-30)
        codes, e_n = ref.adam_ef_quantize(de, scale, k_g)
        return m_n, v_n, codes, scale, e_n
    shape = g.shape
    g2d, numel = _to_tiles(g.astype(jnp.float32))
    m2d, _ = _to_tiles(m)
    v2d, _ = _to_tiles(v)
    e2d, _ = _to_tiles(e)
    hp = jnp.stack([jnp.float32(alpha_t), jnp.float32(beta),
                    jnp.float32(theta_t), jnp.float32(eps)])
    m_n2, v_n2, de2, amax = ak.adam_moments_pallas(
        g2d, m2d, v2d, e2d, hp, interpret=_interpret())
    scale = jnp.maximum(amax, 1e-30)
    codes2, e_n2 = ak.ef_quantize_pallas(de2, scale, k_g,
                                         interpret=_interpret())
    return (_from_tiles(m_n2, numel, shape), _from_tiles(v_n2, numel, shape),
            _from_tiles(codes2, numel, shape), scale,
            _from_tiles(e_n2, numel, shape))
