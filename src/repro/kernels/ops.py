"""Backward-compatible wrappers around the optimizer engine.

The real implementation lives in ``repro.opt.engine`` (backend-dispatched:
``backend="jnp" | "pallas" | None`` for auto). These adapters keep the
historical ``use_pallas: bool`` surface that the kernel tests and
benchmarks drive; new code should import ``repro.opt.engine`` directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.opt import engine


def _bk(use_pallas: bool) -> str:
    return "pallas" if use_pallas else "jnp"


def quantize_log(x: jax.Array, k_g: int = 6, use_pallas: bool = True):
    return engine.quantize_log(x, k_g, backend=_bk(use_pallas))


def dequantize_log(codes: jax.Array, scale: jax.Array, k_g: int = 6,
                   use_pallas: bool = True, out_dtype=jnp.float32):
    return engine.dequantize_log(codes, scale, k_g, backend=_bk(use_pallas),
                                 out_dtype=out_dtype)


def quantize_uniform(x: jax.Array, k_x: int = 7, absolute: bool = True,
                     use_pallas: bool = True):
    return engine.quantize_uniform(x, k_x, absolute=absolute,
                                   backend=_bk(use_pallas))


def dequantize_uniform(codes: jax.Array, scale: jax.Array, k_x: int = 7,
                       use_pallas: bool = True, out_dtype=jnp.float32):
    return engine.dequantize_uniform(codes, scale, k_x,
                                     backend=_bk(use_pallas),
                                     out_dtype=out_dtype)


def adam_ef_step(g, m, v, e, alpha_t, beta, theta_t, eps,
                 k_g: int = 6, use_pallas: bool = True):
    """Fused worker inner loop of Algorithm 3: returns
    (m', v', codes, scale, e')."""
    return engine.adam_ef_step(g, m, v, e, alpha_t, beta, theta_t, eps,
                               k_g=k_g, backend=_bk(use_pallas))
