"""Pallas kernel: fused 4-bit pack/unpack of log-grid codes.

The channel-1 wire carries two signed nibbles per byte (repro.core.packing
semantics). On TPU this is a VPU shuffle over (rows,128) tiles: the packed
layout interleaves along the last dim so each lane reads its pair locally.
Validated against core.packing in interpret mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256
LANES = 128


def _pack4_kernel(codes_ref, packed_ref):
    c = codes_ref[...].astype(jnp.int32) + 8          # (R, 2*LANES) biased
    lo = c[:, 0::2]
    hi = c[:, 1::2]
    packed_ref[...] = (lo | (hi << 4)).astype(jnp.uint8)


def pack4_pallas(codes2d: jax.Array, *, interpret: bool) -> jax.Array:
    """codes2d: int8 (R, 256) with values in [-8, 7] -> uint8 (R, 128)."""
    rows = codes2d.shape[0]
    grid = rows // BLOCK_ROWS
    return pl.pallas_call(
        _pack4_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((BLOCK_ROWS, 2 * LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.uint8),
        interpret=interpret,
    )(codes2d)


def _unpack4_kernel(packed_ref, codes_ref):
    u = packed_ref[...].astype(jnp.int32)             # (R, LANES)
    lo = (u & 0xF) - 8
    hi = ((u >> 4) & 0xF) - 8
    out = jnp.zeros(codes_ref.shape, jnp.int32)
    out = out.at[:, 0::2].set(lo)
    out = out.at[:, 1::2].set(hi)
    codes_ref[...] = out.astype(jnp.int8)


def unpack4_pallas(packed2d: jax.Array, *, interpret: bool) -> jax.Array:
    rows = packed2d.shape[0]
    grid = rows // BLOCK_ROWS
    return pl.pallas_call(
        _unpack4_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_ROWS, 2 * LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, 2 * LANES), jnp.int8),
        interpret=interpret,
    )(packed2d)
