"""Pallas pack/unpack kernels - thin shim over ``repro.comm.kernels``.

The generic lane packer there covers 2/3/4/6/8/16-bit widths in the same
byte layout; ``pack4_pallas``/``unpack4_pallas`` keep the historical
4-bit surface (two signed nibbles per byte, ``repro.core.packing``
semantics) used by the kernel tests.
"""
from __future__ import annotations

import jax

from repro.comm.kernels import pack_pallas, unpack_pallas  # noqa: F401

BLOCK_ROWS = 256
LANES = 128


def pack4_pallas(codes2d: jax.Array, *, interpret: bool) -> jax.Array:
    """codes2d: int8 (R, 256) with values in [-8, 7] -> uint8 (R, 128)."""
    return pack_pallas(codes2d, 4, interpret=interpret)


def unpack4_pallas(packed2d: jax.Array, *, interpret: bool) -> jax.Array:
    return unpack_pallas(packed2d, 4, interpret=interpret)
