"""Fused Adam+EF worker-step kernel (Algorithm 3 lines 4-7, minus comm).

Two Pallas passes over the parameter shard:

  pass A (`adam_moments`): one streamed read of (g, m, v, e), one write of
      (m', v', Delta+e) plus per-block amax partials -> the scale for Q_g.
      Naively this is 6 separate elementwise XLA ops with ~10 HBM
      round-trips; the fusion does 4 reads + 3 writes.
  pass B (`ef_quantize`): reads Delta+e, writes int8 codes and the new
      error-feedback residual e' = (Delta+e) - deq(codes).

Scalars (alpha_t, beta, theta_t, eps) arrive as a (4,) f32 operand broadcast
to every grid step (index_map pins block 0), which keeps them in SMEM on TPU.

Both kernel bodies call the canonical math in ``repro.opt.grids`` on their
VMEM tiles, so the fused path is bit-identical to the jnp backend by
construction (asserted by ``tests/test_opt_engine.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.quantize import BLOCK_ROWS, LANES
from repro.opt import grids


def _moments_kernel(g_ref, m_ref, v_ref, e_ref, hp_ref,
                    m_out, v_out, de_out, amax_out):
    m_new, v_new, de = grids.adam_ef_moments(
        g_ref[...], m_ref[...], v_ref[...], e_ref[...],
        alpha_t=hp_ref[0], beta=hp_ref[1], theta_t=hp_ref[2], eps=hp_ref[3])
    m_out[...] = m_new
    v_out[...] = v_new
    de_out[...] = de
    amax_out[0] = grids.block_amax(de)


def adam_moments_pallas(g2d, m2d, v2d, e2d, hp, *, interpret: bool):
    """hp: (4,) f32 = [alpha_t, beta, theta_t, eps]."""
    rows = g2d.shape[0]
    grid = rows // BLOCK_ROWS
    blk = lambda: pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    m_new, v_new, de, partials = pl.pallas_call(
        _moments_kernel,
        grid=(grid,),
        in_specs=[blk(), blk(), blk(), blk(),
                  pl.BlockSpec((4,), lambda i: (0,))],
        out_specs=[blk(), blk(), blk(), pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
            jax.ShapeDtypeStruct((grid,), jnp.float32),
        ],
        interpret=interpret,
    )(g2d, m2d, v2d, e2d, hp)
    return m_new, v_new, de, jnp.max(partials)


def _ef_quantize_kernel(de_ref, scale_ref, codes_ref, e_out, *, k_g: int):
    codes, e_new = grids.adam_ef_quantize(de_ref[...], scale_ref[0], k_g)
    codes_ref[...] = codes
    e_out[...] = e_new


def ef_quantize_pallas(de2d, scale, k_g: int, *, interpret: bool):
    rows = de2d.shape[0]
    grid = rows // BLOCK_ROWS
    blk = lambda: pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_ef_quantize_kernel, k_g=k_g),
        grid=(grid,),
        in_specs=[blk(), pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=[blk(), blk()],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), jnp.int8),
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(de2d, scale.reshape(1))
