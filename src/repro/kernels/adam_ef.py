"""Fused Adam+EF worker-step kernel (Algorithm 3 lines 4-7, minus comm).

Two Pallas passes over the parameter shard:

  pass A (`adam_moments`): one streamed read of (g, m, v, e), one write of
      (m', v', Delta+e) plus per-block amax partials -> the scale for Q_g.
      Naively this is 6 separate elementwise XLA ops with ~10 HBM
      round-trips; the fusion does 4 reads + 3 writes.
  pass B (`ef_quantize`): reads Delta+e, writes int8 codes and the new
      error-feedback residual e' = (Delta+e) - deq(codes).

Scalars (alpha_t, beta, theta_t, eps) arrive as a (4,) f32 operand broadcast
to every grid step (index_map pins block 0), which keeps them in SMEM on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.quantize import BLOCK_ROWS, LANES


def _moments_kernel(g_ref, m_ref, v_ref, e_ref, hp_ref,
                    m_out, v_out, de_out, amax_out):
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...]
    v = v_ref[...]
    e = e_ref[...]
    alpha_t, beta, theta_t, eps = hp_ref[0], hp_ref[1], hp_ref[2], hp_ref[3]
    v_new = theta_t * v + (1.0 - theta_t) * g * g
    m_new = beta * m + (1.0 - beta) * g
    de = alpha_t * m_new * jax.lax.rsqrt(v_new + eps) + e
    m_out[...] = m_new
    v_out[...] = v_new
    de_out[...] = de
    amax_out[0] = jnp.max(jnp.abs(de))


def adam_moments_pallas(g2d, m2d, v2d, e2d, hp, *, interpret: bool):
    """hp: (4,) f32 = [alpha_t, beta, theta_t, eps]."""
    rows = g2d.shape[0]
    grid = rows // BLOCK_ROWS
    blk = lambda: pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    m_new, v_new, de, partials = pl.pallas_call(
        _moments_kernel,
        grid=(grid,),
        in_specs=[blk(), blk(), blk(), blk(),
                  pl.BlockSpec((4,), lambda i: (0,))],
        out_specs=[blk(), blk(), blk(), pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
            jax.ShapeDtypeStruct((grid,), jnp.float32),
        ],
        interpret=interpret,
    )(g2d, m2d, v2d, e2d, hp)
    return m_new, v_new, de, jnp.max(partials)


def _ef_quantize_kernel(de_ref, scale_ref, codes_ref, e_out, *, k_g: int):
    de = de_ref[...]
    s = jnp.maximum(scale_ref[0], 1e-30)
    y = jnp.abs(de) / s
    safe_y = jnp.where(y > 0, y, 1.0)
    e_lo = jnp.floor(-jnp.log2(safe_y))
    mid = 1.5 * jnp.exp2(-(e_lo + 1.0))
    e_near = jnp.where(y >= mid, e_lo, e_lo + 1.0)
    e_near = jnp.clip(e_near, 0.0, float(k_g))
    is_zero = (y < jnp.exp2(-float(k_g)) * 0.5) | (de == 0.0)
    mag = jnp.where(is_zero, 0.0, float(k_g) + 1.0 - e_near)
    codes = jnp.where(de < 0, -mag, mag)
    # dequantize in-register for the EF residual
    deq_mag = jnp.where(mag == 0, 0.0, jnp.exp2(mag - (float(k_g) + 1.0)))
    deq = jnp.sign(codes) * deq_mag * scale_ref[0]
    codes_ref[...] = codes.astype(jnp.int8)
    e_out[...] = de - deq


def ef_quantize_pallas(de2d, scale, k_g: int, *, interpret: bool):
    rows = de2d.shape[0]
    grid = rows // BLOCK_ROWS
    blk = lambda: pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_ef_quantize_kernel, k_g=k_g),
        grid=(grid,),
        in_specs=[blk(), pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=[blk(), blk()],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), jnp.int8),
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(de2d, scale.reshape(1))
