"""Pallas TPU kernels: amax reduction + log/uniform grid quantization.

TPU adaptation notes (vs the paper's CUDA-free formulation):
  * These are VPU (vector unit) kernels - no MXU involvement. Blocks are
    (BLOCK_ROWS, 128): the last dim matches the 128-lane VREG layout, rows
    a multiple of 8 (f32 sublane) so every load is a full tile.
  * Two-pass scheme: pass 1 tiles the tensor and emits one partial amax per
    grid step into SMEM-resident (grid,) vector; the tiny final max happens
    in XLA. Pass 2 re-streams the tensor and writes int8 codes. This is the
    standard TPU pattern for data-dependent scales (one HBM round-trip per
    pass; fusing the passes would require keeping the whole tensor in VMEM).
  * exp2/log2 are VPU-native (transcendental unit), so the log-grid math
    runs at full vector throughput.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256
LANES = 128


def _amax_kernel(x_ref, o_ref):
    o_ref[0] = jnp.max(jnp.abs(x_ref[...].astype(jnp.float32)))


def amax_pallas(x2d: jax.Array, *, interpret: bool) -> jax.Array:
    """Per-block amax -> (grid,) partials. x2d: (R, 128), R % BLOCK_ROWS == 0."""
    rows = x2d.shape[0]
    grid = rows // BLOCK_ROWS
    partials = pl.pallas_call(
        _amax_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((grid,), jnp.float32),
        interpret=interpret,
    )(x2d)
    return jnp.max(partials)


def _log_quantize_kernel(x_ref, scale_ref, codes_ref, *, k_g: int):
    x = x_ref[...].astype(jnp.float32)
    s = jnp.maximum(scale_ref[0], 1e-30)
    y = jnp.abs(x) / s
    safe_y = jnp.where(y > 0, y, 1.0)
    e_lo = jnp.floor(-jnp.log2(safe_y))
    mid = 1.5 * jnp.exp2(-(e_lo + 1.0))
    e_near = jnp.where(y >= mid, e_lo, e_lo + 1.0)
    e_near = jnp.clip(e_near, 0.0, float(k_g))
    is_zero = (y < jnp.exp2(-float(k_g)) * 0.5) | (x == 0.0)
    mag = jnp.where(is_zero, 0.0, float(k_g) + 1.0 - e_near)
    codes_ref[...] = jnp.where(x < 0, -mag, mag).astype(jnp.int8)


def log_quantize_pallas(x2d: jax.Array, scale: jax.Array, k_g: int,
                        *, interpret: bool) -> jax.Array:
    rows = x2d.shape[0]
    grid = rows // BLOCK_ROWS
    return pl.pallas_call(
        functools.partial(_log_quantize_kernel, k_g=k_g),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int8),
        interpret=interpret,
    )(x2d, scale.reshape(1))


def _log_dequantize_kernel(codes_ref, scale_ref, o_ref, *, k_g: int,
                           out_dtype):
    c = codes_ref[...].astype(jnp.float32)
    mag = jnp.abs(c)
    val = jnp.exp2(mag - (float(k_g) + 1.0))
    val = jnp.where(mag == 0, 0.0, val)
    o_ref[...] = (jnp.sign(c) * val * scale_ref[0]).astype(out_dtype)


def log_dequantize_pallas(codes2d: jax.Array, scale: jax.Array, k_g: int,
                          *, out_dtype=jnp.float32, interpret: bool) -> jax.Array:
    rows = codes2d.shape[0]
    grid = rows // BLOCK_ROWS
    return pl.pallas_call(
        functools.partial(_log_dequantize_kernel, k_g=k_g, out_dtype=out_dtype),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), out_dtype),
        interpret=interpret,
    )(codes2d, scale.reshape(1))


def _uniform_quantize_kernel(x_ref, scale_ref, codes_ref, *, k_x: int):
    n = float(2 ** k_x)
    y = jnp.clip(x_ref[...].astype(jnp.float32)
                 / jnp.maximum(scale_ref[0], 1e-30), -1.0, 1.0)
    codes_ref[...] = jnp.round(y * n).astype(jnp.int8)


def uniform_quantize_pallas(x2d: jax.Array, scale: jax.Array, k_x: int,
                            *, interpret: bool) -> jax.Array:
    rows = x2d.shape[0]
    grid = rows // BLOCK_ROWS
    return pl.pallas_call(
        functools.partial(_uniform_quantize_kernel, k_x=k_x),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int8),
        interpret=interpret,
    )(x2d, scale.reshape(1))


def _uniform_dequantize_kernel(codes_ref, scale_ref, o_ref, *, k_x: int,
                               out_dtype):
    n = float(2 ** k_x)
    o_ref[...] = (codes_ref[...].astype(jnp.float32) / n
                  * scale_ref[0]).astype(out_dtype)


def uniform_dequantize_pallas(codes2d: jax.Array, scale: jax.Array, k_x: int,
                              *, out_dtype=jnp.float32,
                              interpret: bool) -> jax.Array:
    rows = codes2d.shape[0]
    grid = rows // BLOCK_ROWS
    return pl.pallas_call(
        functools.partial(_uniform_dequantize_kernel, k_x=k_x,
                          out_dtype=out_dtype),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), out_dtype),
        interpret=interpret,
    )(codes2d, scale.reshape(1))
