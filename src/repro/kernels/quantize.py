"""Pallas TPU kernels: amax reduction + grid quantization (log / uniform /
ternary / blockwise sign).

TPU adaptation notes (vs the paper's CUDA-free formulation):
  * These are VPU (vector unit) kernels - no MXU involvement. Blocks are
    (BLOCK_ROWS, 128): the last dim matches the 128-lane VREG layout, rows
    a multiple of 8 (f32 sublane) so every load is a full tile.
  * Two-pass scheme: pass 1 tiles the tensor and emits one partial amax per
    grid step into SMEM-resident (grid,) vector; the tiny final max happens
    in XLA. Pass 2 re-streams the tensor and writes integer codes. This is
    the standard TPU pattern for data-dependent scales (one HBM round-trip
    per pass; fusing the passes would require keeping the whole tensor in
    VMEM).
  * exp2/log2 are VPU-native (transcendental unit), so the log-grid math
    runs at full vector throughput.

Every kernel body calls the canonical grid math in ``repro.opt.grids`` on
its VMEM-resident tile - the kernels *cannot* drift from the jnp backend,
which is what makes the engine's exact-parity contract
(``tests/test_opt_engine.py``) hold by construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.opt import grids

BLOCK_ROWS = 256
LANES = 128


def _amax_kernel(x_ref, o_ref):
    o_ref[0] = grids.block_amax(x_ref[...])


def amax_pallas(x2d: jax.Array, *, interpret: bool) -> jax.Array:
    """Per-block amax -> (grid,) partials. x2d: (R, 128), R % BLOCK_ROWS == 0."""
    rows = x2d.shape[0]
    grid = rows // BLOCK_ROWS
    partials = pl.pallas_call(
        _amax_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((grid,), jnp.float32),
        interpret=interpret,
    )(x2d)
    return jnp.max(partials)


def _log_quantize_kernel(x_ref, scale_ref, codes_ref, *, k_g: int):
    codes_ref[...] = grids.log_quantize(x_ref[...], scale_ref[0], k_g)


def log_quantize_pallas(x2d: jax.Array, scale: jax.Array, k_g: int,
                        *, interpret: bool) -> jax.Array:
    rows = x2d.shape[0]
    grid = rows // BLOCK_ROWS
    return pl.pallas_call(
        functools.partial(_log_quantize_kernel, k_g=k_g),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int8),
        interpret=interpret,
    )(x2d, scale.reshape(1))


def _log_dequantize_kernel(codes_ref, scale_ref, o_ref, *, k_g: int,
                           out_dtype):
    o_ref[...] = grids.log_dequantize(
        codes_ref[...], scale_ref[0], k_g).astype(out_dtype)


def log_dequantize_pallas(codes2d: jax.Array, scale: jax.Array, k_g: int,
                          *, out_dtype=jnp.float32, interpret: bool) -> jax.Array:
    rows = codes2d.shape[0]
    grid = rows // BLOCK_ROWS
    return pl.pallas_call(
        functools.partial(_log_dequantize_kernel, k_g=k_g, out_dtype=out_dtype),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), out_dtype),
        interpret=interpret,
    )(codes2d, scale.reshape(1))


def _uniform_quantize_kernel(x_ref, scale_ref, codes_ref, *, k_x: int):
    codes_ref[...] = grids.uniform_quantize(x_ref[...], scale_ref[0], k_x)


def uniform_quantize_pallas(x2d: jax.Array, scale: jax.Array, k_x: int,
                            *, interpret: bool) -> jax.Array:
    """Codes dtype follows the grid width: int8 for k_x <= 6, int16 above
    (codes reach +/- 2^k_x, which overflows int8 at k_x = 7)."""
    rows = x2d.shape[0]
    grid = rows // BLOCK_ROWS
    return pl.pallas_call(
        functools.partial(_uniform_quantize_kernel, k_x=k_x),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES),
                                       grids.uniform_code_dtype(k_x)),
        interpret=interpret,
    )(x2d, scale.reshape(1))


def _uniform_dequantize_kernel(codes_ref, scale_ref, o_ref, *, k_x: int,
                               out_dtype):
    o_ref[...] = grids.uniform_dequantize(
        codes_ref[...], scale_ref[0], k_x).astype(out_dtype)


def uniform_dequantize_pallas(codes2d: jax.Array, scale: jax.Array, k_x: int,
                              *, out_dtype=jnp.float32,
                              interpret: bool) -> jax.Array:
    rows = codes2d.shape[0]
    grid = rows // BLOCK_ROWS
    return pl.pallas_call(
        functools.partial(_uniform_dequantize_kernel, k_x=k_x,
                          out_dtype=out_dtype),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), out_dtype),
        interpret=interpret,
    )(codes2d, scale.reshape(1))


def _ternary_quantize_kernel(x_ref, u_ref, scale_ref, codes_ref):
    codes_ref[...] = grids.ternary_quantize(x_ref[...], u_ref[...],
                                            scale_ref[0])


def ternary_quantize_pallas(x2d: jax.Array, u2d: jax.Array,
                            scale: jax.Array, *, interpret: bool) -> jax.Array:
    """TernGrad codes from pre-drawn uniforms (stochastic rounding bits are
    generated outside so the jnp backend sees identical draws)."""
    rows = x2d.shape[0]
    grid = rows // BLOCK_ROWS
    blk = lambda: pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _ternary_quantize_kernel,
        grid=(grid,),
        in_specs=[blk(), blk(), pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=blk(),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int8),
        interpret=interpret,
    )(x2d, u2d, scale.reshape(1))


# Blockwise rows processed per grid step (f32 sublane multiple).
BLOCKWISE_ROWS = 8


def _blockwise_quantize_kernel(x_ref, codes_ref, scale_ref):
    codes, scale = grids.blockwise_quantize(x_ref[...])
    codes_ref[...] = codes
    scale_ref[...] = scale


def blockwise_quantize_pallas(x2d: jax.Array, *, interpret: bool):
    """(nb, block) -> (sign codes, per-block scales). The block dim rides
    the lane axis whole (one EF block per sublane row); nb must be a
    multiple of BLOCKWISE_ROWS (the engine pads with zero rows)."""
    nb, block = x2d.shape
    grid = nb // BLOCKWISE_ROWS
    codes, scales = pl.pallas_call(
        _blockwise_quantize_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((BLOCKWISE_ROWS, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((BLOCKWISE_ROWS, block), lambda i: (i, 0)),
                   pl.BlockSpec((BLOCKWISE_ROWS,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((nb, block), jnp.int8),
                   jax.ShapeDtypeStruct((nb,), jnp.float32)],
        interpret=interpret,
    )(x2d)
    return codes, scales
