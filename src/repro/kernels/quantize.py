"""Pallas quantization kernels - thin shim.

The kernels live in ``repro.comm.kernels`` (the codec stack owns every
quantize/pack pass, fused and unfused); this module re-exports the
historical per-op surface the engine and kernel tests drive. See
``repro.comm`` for the fused single-launch encode/decode paths.
"""
from __future__ import annotations

from repro.comm.kernels import (  # noqa: F401
    BLOCK_ROWS,
    BLOCKWISE_ROWS,
    LANES,
    amax_pallas,
    blockwise_quantize_pallas,
    log_dequantize_pallas,
    log_quantize_pallas,
    ternary_quantize_pallas,
    uniform_dequantize_pallas,
    uniform_quantize_pallas,
)
