"""repro.adapt - runtime-adaptive, accuracy-aware quantization.

Three layers, host-driven, zero steady-state host syncs:

  * :mod:`repro.adapt.stats`     - device-resident per-leaf gradient
    statistics (amax / mean-square EMAs) accumulated inside the jitted
    train step into a TrainSession stats ring.
  * :mod:`repro.adapt.allocate`  - bit-allocation policy: per-leaf lane
    widths from the 2/3/4/6/8/16 set under a total wire-byte budget,
    minimizing expected quantization distortion.
  * :mod:`repro.adapt.controller`- host replan loop: harvest stats,
    re-solve the plan, swap codecs at replan boundaries with each plan
    keyed into the AOT/compile cache and EF residuals carried bitwise
    across the switch.

``controller`` pulls in the dist/train stack, which itself imports the
``adaptive`` mode plugin (-> this package), so it is loaded lazily via
``__getattr__`` to keep the import graph acyclic.
"""
from repro.adapt import allocate, stats  # noqa: F401
from repro.adapt.allocate import (  # noqa: F401
    Group,
    WIDTH_SPECS,
    WIDTHS,
    allocate_specs,
    baseline_cost,
    expected_distortion,
    plan_cost,
)
from repro.adapt.stats import N_FIELDS, STAT_FIELDS, StatsEMA  # noqa: F401

_CONTROLLER_NAMES = ("AdaptConfig", "AdaptiveController", "plan_for_model",
                     "leaf_groups_for", "measured_exchange_bytes",
                     "measured_tier_bytes", "verify_accounting")


def __getattr__(name):
    if name in _CONTROLLER_NAMES or name == "controller":
        # importlib, not ``from repro.adapt import controller``: the
        # from-import form probes this attribute again via hasattr()
        # before the submodule lands on the package and recurses.
        import importlib
        controller = importlib.import_module("repro.adapt.controller")
        return controller if name == "controller" else getattr(controller,
                                                               name)
    raise AttributeError(f"module 'repro.adapt' has no attribute {name!r}")
