"""Host-side replan loop for adaptive quantization.

The controller owns a ``TrainSession`` running the ``adaptive`` mode
and, every ``replan_every`` steps:

  1. harvests the device stats ring (ONE host sync per window - the
     same sync discipline as the loss ring, zero added steady-state
     syncs),
  2. folds the rows into a :class:`repro.adapt.stats.StatsEMA`,
  3. re-solves the bit plan (:mod:`repro.adapt.allocate`) under the
     byte budget from the observed amax/meansq history,
  4. on a plan change, rebuilds the step artifacts with the new
     ``TrainConfig.bit_plan`` and ``swap_artifacts``-s them in. The
     state buffers (masters, Adam moments, EF residuals) carry over
     bitwise - a replan changes only the wire - and the new plan's
     executable is keyed separately into the jit/AOT cache (TrainConfig
     rides in the AOT facts), so a revisited plan never recompiles.

``measured_exchange_bytes`` re-derives the a2a figure from real encoded
payload ``.nbytes`` per leaf - the verification hook behind
``--adapt-verify`` and the accounting tests: at every replan the
registry-sourced ``comm_bytes_per_step`` must equal it exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import comm
from repro.adapt import allocate as A
from repro.adapt import stats as S


@dataclasses.dataclass
class AdaptConfig:
    budget_ratio: float = 0.6   # a2a byte budget vs fixed log:6 (k_g=6)
    replan_every: int = 25      # steps between replan boundaries
    ema_decay: float = 0.8      # StatsEMA decay per harvested step
    baseline_width: int = 4     # the fixed lane the budget is quoted vs


def _leaf_names(layout) -> List[str]:
    flat = jax.tree_util.tree_flatten_with_path(layout._leaves)[0]
    return [jax.tree_util.keystr(path) for path, _ in flat]


def leaf_groups_for(art, ema: Optional[S.StatsEMA] = None,
                    ) -> List[A.Group]:
    """Allocation groups for the artifacts' state leaves (metas_flat
    order). Without an EMA (pre-run planning, dryrun) a uniform prior
    is used: every leaf amax=1, meansq=1 - allocation then splits on
    wire geometry alone."""
    from repro.dist.step import _leaf_meta
    metas = _leaf_meta(art.layout, art.n_workers)
    leaves = jax.tree_util.tree_leaves(
        metas, is_leaf=lambda x: type(x).__name__ == "LeafMeta")
    names = _leaf_names(art.layout)
    snap = ema.snapshot() if ema is not None else None
    groups = []
    for i, m in enumerate(leaves):
        amax, meansq = (1.0, 1.0) if snap is None \
            else (float(snap[i, 0]), float(snap[i, 1]))
        groups.append(A.Group(name=names[i], numel=m.numel, c=m.c,
                              amax=amax, meansq=meansq))
    return groups


def solve_plan(groups: List[A.Group], n_workers: int,
               acfg: AdaptConfig) -> Tuple[Tuple[str, ...], int, int]:
    """(specs, budget_bytes, baseline_bytes) for one replan."""
    baseline = A.baseline_cost(groups, n_workers, acfg.baseline_width)
    budget = int(acfg.budget_ratio * baseline)
    return A.allocate_specs(groups, budget, n_workers), budget, baseline


def plan_report(groups: List[A.Group], specs: Tuple[str, ...],
                n_workers: int) -> List[Dict[str, Any]]:
    """Per-leaf rows for logs/dryrun: spec, width, exact a2a bytes."""
    rows = []
    for g, spec in zip(groups, specs):
        codec = comm.get_codec(spec)
        rows.append({"leaf": g.name, "numel": g.numel, "c": g.c,
                     "spec": spec, "bits": codec.bits,
                     "a2a_bytes": n_workers * codec.payload_nbytes(g.c)})
    return rows


def plan_for_model(model, mesh, tc, *, budget_ratio: float = 0.6,
                   ema: Optional[S.StatsEMA] = None):
    """One-shot (pre-run) plan: build adaptive artifacts, solve under
    the uniform prior (or a supplied EMA), return ``(tc2, art2,
    report)`` with ``tc2.bit_plan`` set and ``art2`` compiled-ready
    artifacts for it. Dryrun's ``--adaptive`` path."""
    from repro.dist.step import make_train_step
    acfg = AdaptConfig(budget_ratio=budget_ratio)
    tc1 = dataclasses.replace(tc, mode="adaptive", bit_plan=None)
    art1 = make_train_step(model, mesh, tc1)
    groups = leaf_groups_for(art1, ema)
    specs, budget, baseline = solve_plan(groups, art1.n_workers, acfg)
    tc2 = dataclasses.replace(tc1, bit_plan=specs)
    art2 = make_train_step(model, mesh, tc2)
    report = plan_report(groups, specs, art2.n_workers)
    return tc2, art2, {"rows": report, "budget_bytes": budget,
                       "baseline_bytes": baseline,
                       "plan_bytes": sum(r["a2a_bytes"] for r in report)}


def _hier_tiers(art, mode):
    """The artifacts' tiers when the mode actually exchanges over them
    (None for flat topologies and non-tiered modes like dp_adam)."""
    tiers = getattr(art, "tiers", None)
    if mode.tiered and tiers is not None and tiers.intra_axes:
        return tiers
    return None


def _leaf_payload_nbytes(art, tc, mode, m, i, n_src: int) -> int:
    """Measured exchange payload bytes for one leaf: encode a real
    tensor with its plan codec and slice to the ``n_src`` rows that
    actually cross the exchange tier (all ``n_workers`` rows flat,
    ``tiers.n_inter`` hierarchical - rows are byte-aligned so the slice
    is exactly the wire array)."""
    codec = mode.leaf_codec(tc, i)
    x = jnp.linspace(-1.0, 1.0, m.numel, dtype=jnp.float32)
    if isinstance(codec, comm.IdentityCodec):
        return n_src * m.c * 4
    if isinstance(codec, comm.BlockwiseCodec):
        from repro.opt import engine
        codes2d, _ = engine.quantize_blockwise(x, codec.block)
        rows = comm.pad_rows(codes2d.reshape(-1)[:m.numel],
                             art.n_workers)
        return comm.pack_rows(rows, codec.bits)[:n_src].nbytes
    key = jax.random.PRNGKey(0)
    payload, _ = comm.encode_rows(x, codec, art.n_workers, key=key)
    return payload[:n_src].nbytes


def measured_exchange_bytes(art, tc) -> int:
    """Measured per-device a2a payload bytes on the *exchange tier*:
    encode a real tensor per leaf with its plan codec and sum the wire
    array ``.nbytes`` - the ground truth
    ``comm_bytes_per_step(...)["update_exchange_bytes"]`` must match
    exactly. On a hierarchical topology only ``tiers.n_inter`` rows per
    leaf cross the slow tier, and so only those are counted."""
    from repro.dist.modes import get_mode
    from repro.dist.step import _leaf_meta
    mode = get_mode(tc.mode)
    tiers = _hier_tiers(art, mode)
    n_src = tiers.n_inter if tiers is not None else art.n_workers
    metas = _leaf_meta(art.layout, art.n_workers)
    leaves = jax.tree_util.tree_leaves(
        metas, is_leaf=lambda x: type(x).__name__ == "LeafMeta")
    return sum(_leaf_payload_nbytes(art, tc, mode, m, i, n_src)
               for i, m in enumerate(leaves))


def measured_tier_bytes(art, tc) -> Dict[str, Dict[str, int]]:
    """Measured per-tier wire bytes from real buffer ``.nbytes`` - the
    ground-truth counterpart of ``comm_bytes_per_step(...)["tiers"]``.

    inter.update_exchange re-encodes every leaf (see
    :func:`measured_exchange_bytes`); intra.grad_reduce materializes the
    fast-tier fp32 gather buffer (``n_intra`` per-worker gradient rows);
    the broadcast figures encode one real chunk per leaf with the
    weight-wire codec and scale by the per-tier fan-out of the
    inter-first gather."""
    from repro.dist.modes import get_mode
    from repro.dist.step import _leaf_meta, weight_wire_codec
    mode = get_mode(tc.mode)
    tiers = _hier_tiers(art, mode)
    n_src = tiers.n_inter if tiers is not None else art.n_workers
    metas = _leaf_meta(art.layout, art.n_workers)
    leaves = jax.tree_util.tree_leaves(
        metas, is_leaf=lambda x: type(x).__name__ == "LeafMeta")
    ex_inter = ex_intra = bc_inter = bc_intra = 0
    for i, m in enumerate(leaves):
        ex_inter += _leaf_payload_nbytes(art, tc, mode, m, i, n_src)
        if tiers is not None:
            ex_intra += np.zeros((tiers.n_intra, m.numel),
                                 np.float32).nbytes
        wc = weight_wire_codec(tc, m.full_numel)
        if isinstance(wc, comm.IdentityCodec):
            p = m.c * 4
        else:
            payload, _ = comm.encode_rows(
                jnp.linspace(-1.0, 1.0, m.c, dtype=jnp.float32), wc, 1,
                key=jax.random.PRNGKey(0))
            p = payload.nbytes
        if tiers is not None:
            bc_inter += tiers.n_inter * p
            bc_intra += tiers.n_intra * tiers.n_inter * p
        else:
            bc_inter += art.n_workers * p
    return {"inter": {"update_exchange": ex_inter,
                      "weight_broadcast": bc_inter,
                      "total": ex_inter + bc_inter},
            "intra": {"grad_reduce": ex_intra,
                      "weight_broadcast": bc_intra,
                      "total": ex_intra + bc_intra}}


def verify_accounting(art, tc) -> Dict[str, Any]:
    """Assert registry accounting == measured payload bytes - the a2a
    headline figure and every per-tier entry; returns both figure sets
    (raises AssertionError on mismatch)."""
    from repro.train.loop import comm_bytes_per_step
    booked = comm_bytes_per_step(art, tc)
    accounted = booked["update_exchange_bytes"]
    measured = measured_exchange_bytes(art, tc)
    assert accounted == measured, \
        f"accounted {accounted} != measured {measured} a2a bytes"
    mtiers = measured_tier_bytes(art, tc)
    assert booked["tiers"] == mtiers, \
        f"accounted tiers {booked['tiers']} != measured {mtiers}"
    return {"accounted": accounted, "measured": measured,
            "tiers": mtiers}


class AdaptiveController:
    """Drives an adaptive ``TrainSession``: windowed run / harvest /
    replan. Use exactly like a session::

        ctl = AdaptiveController(model, mesh, tc, batches, acfg, scfg)
        ctl.run(steps)
        ctl.close()

    ``plan_log`` records one entry per plan segment: the step it took
    effect, the specs, and the registry accounting at that plan.
    """

    def __init__(self, model, mesh, tc, batches, acfg: AdaptConfig,
                 scfg=None, *, key=None, log=print, verify: bool = False):
        from repro.dist.step import make_train_step
        from repro.train.loop import comm_bytes_per_step
        from repro.train.session import SessionConfig, TrainSession
        self._comm_bytes = comm_bytes_per_step
        self._make_step = make_train_step
        self.model, self.mesh = model, mesh
        self.acfg = acfg
        self.verify = verify
        self._log = log
        self.tc = dataclasses.replace(tc, mode="adaptive")
        self.art = make_train_step(model, mesh, self.tc)
        scfg = scfg or SessionConfig(log_every=0)
        scfg = dataclasses.replace(
            scfg, stats_ring=max(scfg.stats_ring, acfg.replan_every))
        self.session = TrainSession.from_artifacts(self.art, batches,
                                                   scfg, key=key, log=log)
        n_leaves = len(jax.tree_util.tree_leaves(self.art.layout._leaves))
        self.ema = S.StatsEMA(n_leaves, acfg.ema_decay)
        self.plan_log: List[Dict[str, Any]] = []
        self.replans = 0
        self._record_plan(0)
        self._sync_ckpt_extra()

    def _sync_ckpt_extra(self):
        """Mirror the live plan + EMA into ``session.ckpt_extra`` so
        every checkpoint (sync or async) carries them; ``resume`` reads
        them back and replans from the same history an uninterrupted
        run would have had."""
        self.session.ckpt_extra["bit_plan"] = (
            list(self.tc.bit_plan) if self.tc.bit_plan else None)
        self.session.ckpt_extra["adapt_ema"] = (
            self.ema.state_dict() if self.ema.count > 0.0 else None)

    def resume(self, ckpt_dir: Optional[str] = None) -> int:
        """Restore an adaptive run: read the checkpointed bit plan +
        stats EMA from the manifest extra, rebuild artifacts for the
        restored plan (a plan the run compiled before warm-loads from
        the AOT cache - ``bit_plan`` rides in ``TrainConfig``, the
        cache key), swap them in, then restore state/stream position
        via ``TrainSession.resume``. Returns the restored step (0 when
        no checkpoint exists). Must precede ``run()``."""
        from repro.checkpoint import store
        d = ckpt_dir or self.session.cfg.ckpt_dir
        if not d:
            raise ValueError("no checkpoint directory given")
        found = store.latest_step(d)
        if found is None:
            return 0
        extra = store.read_extra(d, step=found)
        plan = extra.get("bit_plan")
        plan = tuple(plan) if plan else None
        if plan != self.tc.bit_plan:
            self.tc = dataclasses.replace(self.tc, bit_plan=plan)
            self.art = self._make_step(self.model, self.mesh, self.tc)
            self.session.swap_artifacts(self.art)
            self._record_plan(found)
        if extra.get("adapt_ema"):
            self.ema = S.StatsEMA.from_state(extra["adapt_ema"])
        out = self.session.resume(d, step=found)
        # Re-solve at the resume boundary: when the checkpoint sits on a
        # replan boundary, an uninterrupted run replans right after the
        # window harvest the checkpoint carries - the restored plan is
        # the segment BEFORE that boundary. Mid-window checkpoints
        # re-solve from the same EMA and land on the same plan (no-op),
        # so this keeps boundary-aligned resumes bit-identical.
        self.replan()
        self._sync_ckpt_extra()
        return out

    def _record_plan(self, step: int):
        entry = {"step": step, "bit_plan": self.tc.bit_plan,
                 "comm": self._comm_bytes(self.art, self.tc)}
        if self.verify:
            entry["verify"] = verify_accounting(self.art, self.tc)
        self.plan_log.append(entry)

    def replan(self) -> bool:
        """Re-solve from the EMA; swap artifacts when the plan moved.
        Returns True when a swap happened."""
        if self.ema.count <= 0.0:
            return False
        groups = leaf_groups_for(self.art, self.ema)
        specs, _, _ = solve_plan(groups, self.art.n_workers, self.acfg)
        if specs == self.tc.bit_plan:
            return False
        self.tc = dataclasses.replace(self.tc, bit_plan=specs)
        self.art = self._make_step(self.model, self.mesh, self.tc)
        self.session.swap_artifacts(self.art)
        self.replans += 1
        self._record_plan(self.session.step)
        self._sync_ckpt_extra()
        self._log(f"  replan @{self.session.step}: "
                  f"{self.plan_log[-1]['comm']['update_exchange_bytes']} "
                  f"a2a B/step")
        return True

    def run(self, steps: int):
        """Run ``steps`` optimizer steps with a replan boundary every
        ``acfg.replan_every`` steps."""
        done = 0
        while done < steps:
            k = min(self.acfg.replan_every, steps - done)
            self.session.run(k)
            done += k
            for _, rows in self.session.harvest_stats():
                self.ema.update(rows)
            self._sync_ckpt_extra()
            if done < steps:
                self.replan()
        return self.session.history

    @property
    def state(self):
        return self.session.state

    @property
    def stats(self):
        return self.session.stats

    def close(self):
        self.session.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
