"""Host-side replan loop for adaptive quantization.

The controller owns a ``TrainSession`` running the ``adaptive`` mode
and, every ``replan_every`` steps:

  1. harvests the device stats ring (ONE host sync per window - the
     same sync discipline as the loss ring, zero added steady-state
     syncs),
  2. folds the rows into a :class:`repro.adapt.stats.StatsEMA`,
  3. re-solves the bit plan (:mod:`repro.adapt.allocate`) under the
     byte budget from the observed amax/meansq history,
  4. on a plan change, rebuilds the step artifacts with the new
     ``TrainConfig.bit_plan`` and ``swap_artifacts``-s them in. The
     state buffers (masters, Adam moments, EF residuals) carry over
     bitwise - a replan changes only the wire - and the new plan's
     executable is keyed separately into the jit/AOT cache (TrainConfig
     rides in the AOT facts), so a revisited plan never recompiles.

``measured_exchange_bytes`` re-derives the a2a figure from real encoded
payload ``.nbytes`` per leaf - the verification hook behind
``--adapt-verify`` and the accounting tests: at every replan the
registry-sourced ``comm_bytes_per_step`` must equal it exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import comm
from repro.adapt import allocate as A
from repro.adapt import stats as S


@dataclasses.dataclass
class AdaptConfig:
    budget_ratio: float = 0.6   # a2a byte budget vs fixed log:6 (k_g=6)
    replan_every: int = 25      # steps between replan boundaries
    ema_decay: float = 0.8      # StatsEMA decay per harvested step
    baseline_width: int = 4     # the fixed lane the budget is quoted vs


def _leaf_names(layout) -> List[str]:
    flat = jax.tree_util.tree_flatten_with_path(layout._leaves)[0]
    return [jax.tree_util.keystr(path) for path, _ in flat]


def leaf_groups_for(art, ema: Optional[S.StatsEMA] = None,
                    ) -> List[A.Group]:
    """Allocation groups for the artifacts' state leaves (metas_flat
    order). Without an EMA (pre-run planning, dryrun) a uniform prior
    is used: every leaf amax=1, meansq=1 - allocation then splits on
    wire geometry alone."""
    from repro.dist.step import _leaf_meta
    metas = _leaf_meta(art.layout, art.n_workers)
    leaves = jax.tree_util.tree_leaves(
        metas, is_leaf=lambda x: type(x).__name__ == "LeafMeta")
    names = _leaf_names(art.layout)
    snap = ema.snapshot() if ema is not None else None
    groups = []
    for i, m in enumerate(leaves):
        amax, meansq = (1.0, 1.0) if snap is None \
            else (float(snap[i, 0]), float(snap[i, 1]))
        groups.append(A.Group(name=names[i], numel=m.numel, c=m.c,
                              amax=amax, meansq=meansq))
    return groups


def solve_plan(groups: List[A.Group], n_workers: int,
               acfg: AdaptConfig) -> Tuple[Tuple[str, ...], int, int]:
    """(specs, budget_bytes, baseline_bytes) for one replan."""
    baseline = A.baseline_cost(groups, n_workers, acfg.baseline_width)
    budget = int(acfg.budget_ratio * baseline)
    return A.allocate_specs(groups, budget, n_workers), budget, baseline


def plan_report(groups: List[A.Group], specs: Tuple[str, ...],
                n_workers: int) -> List[Dict[str, Any]]:
    """Per-leaf rows for logs/dryrun: spec, width, exact a2a bytes."""
    rows = []
    for g, spec in zip(groups, specs):
        codec = comm.get_codec(spec)
        rows.append({"leaf": g.name, "numel": g.numel, "c": g.c,
                     "spec": spec, "bits": codec.bits,
                     "a2a_bytes": n_workers * codec.payload_nbytes(g.c)})
    return rows


def plan_for_model(model, mesh, tc, *, budget_ratio: float = 0.6,
                   ema: Optional[S.StatsEMA] = None):
    """One-shot (pre-run) plan: build adaptive artifacts, solve under
    the uniform prior (or a supplied EMA), return ``(tc2, art2,
    report)`` with ``tc2.bit_plan`` set and ``art2`` compiled-ready
    artifacts for it. Dryrun's ``--adaptive`` path."""
    from repro.dist.step import make_train_step
    acfg = AdaptConfig(budget_ratio=budget_ratio)
    tc1 = dataclasses.replace(tc, mode="adaptive", bit_plan=None)
    art1 = make_train_step(model, mesh, tc1)
    groups = leaf_groups_for(art1, ema)
    specs, budget, baseline = solve_plan(groups, art1.n_workers, acfg)
    tc2 = dataclasses.replace(tc1, bit_plan=specs)
    art2 = make_train_step(model, mesh, tc2)
    report = plan_report(groups, specs, art2.n_workers)
    return tc2, art2, {"rows": report, "budget_bytes": budget,
                       "baseline_bytes": baseline,
                       "plan_bytes": sum(r["a2a_bytes"] for r in report)}


def measured_exchange_bytes(art, tc) -> int:
    """Measured per-device a2a payload bytes: encode a real tensor per
    leaf with its plan codec and sum the payload ``.nbytes`` - the
    ground truth ``comm_bytes_per_step`` must match exactly."""
    from repro.dist.modes import get_mode
    from repro.dist.step import _leaf_meta
    mode = get_mode(tc.mode)
    metas = _leaf_meta(art.layout, art.n_workers)
    leaves = jax.tree_util.tree_leaves(
        metas, is_leaf=lambda x: type(x).__name__ == "LeafMeta")
    total = 0
    for i, m in enumerate(leaves):
        codec = mode.leaf_codec(tc, i)
        x = jnp.linspace(-1.0, 1.0, m.numel, dtype=jnp.float32)
        if isinstance(codec, comm.IdentityCodec):
            total += art.n_workers * m.c * 4
        elif isinstance(codec, comm.BlockwiseCodec):
            from repro.opt import engine
            codes2d, _ = engine.quantize_blockwise(x, codec.block)
            rows = comm.pad_rows(codes2d.reshape(-1)[:m.numel],
                                 art.n_workers)
            total += comm.pack_rows(rows, codec.bits).nbytes
        else:
            key = jax.random.PRNGKey(0)
            payload, _ = comm.encode_rows(x, codec, art.n_workers,
                                          key=key)
            total += payload.nbytes
    return total


def verify_accounting(art, tc) -> Dict[str, int]:
    """Assert registry accounting == measured payload bytes; returns
    both figures (raises AssertionError on mismatch)."""
    from repro.train.loop import comm_bytes_per_step
    accounted = comm_bytes_per_step(art, tc)["update_exchange_bytes"]
    measured = measured_exchange_bytes(art, tc)
    assert accounted == measured, \
        f"accounted {accounted} != measured {measured} a2a bytes"
    return {"accounted": accounted, "measured": measured}


class AdaptiveController:
    """Drives an adaptive ``TrainSession``: windowed run / harvest /
    replan. Use exactly like a session::

        ctl = AdaptiveController(model, mesh, tc, batches, acfg, scfg)
        ctl.run(steps)
        ctl.close()

    ``plan_log`` records one entry per plan segment: the step it took
    effect, the specs, and the registry accounting at that plan.
    """

    def __init__(self, model, mesh, tc, batches, acfg: AdaptConfig,
                 scfg=None, *, key=None, log=print, verify: bool = False):
        from repro.dist.step import make_train_step
        from repro.train.loop import comm_bytes_per_step
        from repro.train.session import SessionConfig, TrainSession
        self._comm_bytes = comm_bytes_per_step
        self._make_step = make_train_step
        self.model, self.mesh = model, mesh
        self.acfg = acfg
        self.verify = verify
        self._log = log
        self.tc = dataclasses.replace(tc, mode="adaptive")
        self.art = make_train_step(model, mesh, self.tc)
        scfg = scfg or SessionConfig(log_every=0)
        scfg = dataclasses.replace(
            scfg, stats_ring=max(scfg.stats_ring, acfg.replan_every))
        self.session = TrainSession.from_artifacts(self.art, batches,
                                                   scfg, key=key, log=log)
        n_leaves = len(jax.tree_util.tree_leaves(self.art.layout._leaves))
        self.ema = S.StatsEMA(n_leaves, acfg.ema_decay)
        self.plan_log: List[Dict[str, Any]] = []
        self.replans = 0
        self._record_plan(0)

    def _record_plan(self, step: int):
        entry = {"step": step, "bit_plan": self.tc.bit_plan,
                 "comm": self._comm_bytes(self.art, self.tc)}
        if self.verify:
            entry["verify"] = verify_accounting(self.art, self.tc)
        self.plan_log.append(entry)

    def replan(self) -> bool:
        """Re-solve from the EMA; swap artifacts when the plan moved.
        Returns True when a swap happened."""
        if self.ema.count <= 0.0:
            return False
        groups = leaf_groups_for(self.art, self.ema)
        specs, _, _ = solve_plan(groups, self.art.n_workers, self.acfg)
        if specs == self.tc.bit_plan:
            return False
        self.tc = dataclasses.replace(self.tc, bit_plan=specs)
        self.art = self._make_step(self.model, self.mesh, self.tc)
        self.session.swap_artifacts(self.art)
        self.replans += 1
        self._record_plan(self.session.step)
        self._log(f"  replan @{self.session.step}: "
                  f"{self.plan_log[-1]['comm']['update_exchange_bytes']} "
                  f"a2a B/step")
        return True

    def run(self, steps: int):
        """Run ``steps`` optimizer steps with a replan boundary every
        ``acfg.replan_every`` steps."""
        done = 0
        while done < steps:
            k = min(self.acfg.replan_every, steps - done)
            self.session.run(k)
            done += k
            for _, rows in self.session.harvest_stats():
                self.ema.update(rows)
            if done < steps:
                self.replan()
        return self.session.history

    @property
    def state(self):
        return self.session.state

    @property
    def stats(self):
        return self.session.stats

    def close(self):
        self.session.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
