"""Bit-allocation policy: per-leaf lane widths under a wire-byte budget.

Given per-leaf gradient statistics (amax + mean-square EMAs harvested
from the device stats ring, :mod:`repro.adapt.stats`) this module
solves for a per-leaf quantization width from the supported lane set
(2/3/4/6/8/16-bit, :data:`repro.comm.bits.SUPPORTED_BITS`) minimizing
total expected quantization distortion subject to a total all-to-all
byte budget.

Width -> codec mapping (``WIDTH_SPECS``): every lane is an existing
registry codec, so byte accounting stays registry-sourced:

  ====  =======================  ========================================
  bits  spec                     grid
  ====  =======================  ========================================
  2     ``blockwise:256``        per-block sign codes (Zheng et al.)
  3     ``log:2``                log grid, 2 magnitude levels
  4     ``log:6``                the paper's fixed default (k_g = 6)
  6     ``log:30``               log grid, 30 magnitude levels
  8     ``log:126``              log grid, 126 magnitude levels
  16    ``uniform_amax:14:w16``  14-bit uniform + sign on a 16-bit lane
  ====  =======================  ========================================

The solver is the classic rate-distortion ladder: per group, take the
lower convex hull of (wire bytes, expected distortion) over the lane
set; hull-to-hull steps have decreasing distortion-per-byte by
convexity. Merge all groups' steps into one ratio-sorted sequence -
generated *budget-independently* - and a given budget applies the
longest affordable prefix. A larger budget therefore always yields a
plan pointwise at least as wide (monotone in budget, a property the
fuzz tests pin down).

``payload_nbytes`` packs whole lane groups, so for tiny leaves a wider
lane can genuinely cost fewer bytes (1 element at 3-bit = 3 bytes, at
4-bit = 1 byte); the hull handles this naturally - dominated points
(costlier and no more accurate) never enter a chain.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

from repro.comm import bits as B

# Ascending lane widths and the registry codec spec realizing each one.
WIDTHS: Tuple[int, ...] = tuple(sorted(B.SUPPORTED_BITS))
WIDTH_SPECS: Dict[int, str] = {
    2: "blockwise:256",
    3: "log:2",
    4: "log:6",
    6: "log:30",
    8: "log:126",
    16: "uniform_amax:14:w16",
}
# log-grid k_g realizing each log lane (lane_bits_for(k_g + 1)).
_LOG_K = {3: 2, 4: 6, 6: 30, 8: 126}

# Mean-square relative error of round-to-nearest on the power-of-two
# log grid for in-range magnitudes: representable points amax * 2^-j,
# worst-case relative error 1/3, E[rel^2] ~ 0.037 for log-uniform
# magnitudes.
LOG_REL2 = 0.037


def _halfnormal_below(t: float, meansq: float) -> float:
    """E[x^2 ; |x| < t] for x half-normal with E[x^2] = meansq."""
    if meansq <= 0.0 or t <= 0.0:
        return 0.0
    u = t / math.sqrt(meansq)
    return meansq * (math.erf(u / math.sqrt(2.0))
                     - math.sqrt(2.0 / math.pi) * u * math.exp(-0.5 * u * u))


def expected_distortion(width: int, amax: float, meansq: float) -> float:
    """Expected per-element squared quantization error at ``width`` bits.

    Distortion models (closed-form, driven only by the harvested
    ``amax`` / ``meansq`` stats):

    * 2-bit blockwise sign codes: x -> sign(x) * E|x| keeps the
      mean-|.| direction; under a half-normal magnitude model the
      residual energy is ``(1 - 2/pi) * meansq``.
    * log:k: magnitudes below ``amax * 2^-k / 2`` snap to zero (that
      energy is lost outright); in-range magnitudes pay LOG_REL2
      relative error.
    * 16-bit uniform: step ``amax / 2^14``, variance step^2 / 12.
    """
    amax = max(float(amax), 0.0)
    meansq = max(float(meansq), 0.0)
    if width == 2:
        return (1.0 - 2.0 / math.pi) * meansq
    if width in _LOG_K:
        k = _LOG_K[width]
        t = amax * (2.0 ** -k) * 0.5
        tail2 = _halfnormal_below(t, meansq)
        return LOG_REL2 * (meansq - tail2) + tail2
    if width == 16:
        step = amax / float(2 ** 14)
        return step * step / 12.0
    raise ValueError(f"unsupported width {width}: pick from {WIDTHS}")


@dataclasses.dataclass(frozen=True)
class Group:
    """One allocation unit: a leaf (or bucket of leaves) on the wire.

    ``c`` is the padded per-worker chunk length (the wire row width
    the all-to-all actually moves); ``numel`` the true element count
    used to weight distortion.
    """
    name: str
    numel: int
    c: int
    amax: float
    meansq: float


def group_cost(g: Group, width: int, n_workers: int) -> int:
    """Exact a2a bytes for this group at ``width`` (registry math)."""
    return n_workers * B.payload_nbytes(g.c, width)


def plan_cost(groups: Sequence[Group], widths: Sequence[int],
              n_workers: int) -> int:
    return sum(group_cost(g, w, n_workers) for g, w in zip(groups, widths))


def group_distortion(g: Group, width: int) -> float:
    return g.numel * expected_distortion(width, g.amax, g.meansq)


def _hull_chain(g: Group, n_workers: int) -> List[Tuple[int, float, int]]:
    """Lower convex hull of (cost, distortion, width), cost ascending.

    The first vertex is the cheapest achievable point (ties broken by
    lower distortion, then narrower width); subsequent vertices strictly
    improve distortion at strictly higher cost, with step ratios
    (distortion drop per byte) decreasing along the chain.
    """
    pts = sorted((group_cost(g, w, n_workers), group_distortion(g, w), w)
                 for w in WIDTHS)
    stair: List[Tuple[int, float, int]] = []
    for c, d, w in pts:
        if not stair or d < stair[-1][1]:
            stair.append((c, d, w))
    hull: List[Tuple[int, float, int]] = []
    for p in stair:
        while len(hull) >= 2:
            (c1, d1, _), (c2, d2, _) = hull[-2], hull[-1]
            c3, d3, _ = p
            # middle vertex is on/above the chord from hull[-2] to p
            if (d2 - d1) * (c3 - c1) >= (d3 - d1) * (c2 - c1):
                hull.pop()
            else:
                break
        hull.append(p)
    return hull


def upgrade_sequence(groups: Sequence[Group], n_workers: int
                     ) -> List[Tuple[int, int, int]]:
    """Budget-independent ordered upgrades ``(group_idx, width, dcost)``.

    Steps descend by distortion reduction per extra wire byte; ties
    break on (group index, width) so the sequence - and therefore
    every budget's plan - is deterministic.
    """
    steps = []
    for gi, g in enumerate(groups):
        chain = _hull_chain(g, n_workers)
        for (c1, d1, _), (c2, d2, w2) in zip(chain[:-1], chain[1:]):
            steps.append(((d1 - d2) / (c2 - c1), gi, w2, c2 - c1))
    steps.sort(key=lambda s: (-s[0], s[1], s[2]))
    return [(gi, w, dcost) for _, gi, w, dcost in steps]


def allocate(groups: Sequence[Group], budget_bytes: int,
             n_workers: int) -> Tuple[int, ...]:
    """Per-group lane widths: longest affordable prefix of the ladder.

    Every group starts at its hull's cheapest vertex. The fixed upgrade
    sequence is walked in order; each upgrade applies while the running
    plan cost stays within ``budget_bytes``. Walking a *prefix* - never
    skipping an unaffordable step to take a cheaper later one - is what
    buys monotonicity in the budget.
    """
    if not groups:
        return ()
    widths = []
    cost = 0
    for g in groups:
        c0, _, w0 = _hull_chain(g, n_workers)[0]
        widths.append(w0)
        cost += c0
    for gi, w, dcost in upgrade_sequence(groups, n_workers):
        if cost + dcost > budget_bytes:
            break   # prefix semantics: stop at the first miss
        widths[gi] = w
        cost += dcost
    return tuple(widths)


def allocate_specs(groups: Sequence[Group], budget_bytes: int,
                   n_workers: int) -> Tuple[str, ...]:
    """Codec specs (``get_codec``-parsable) for the allocated widths."""
    return tuple(WIDTH_SPECS[w]
                 for w in allocate(groups, budget_bytes, n_workers))


def baseline_cost(groups: Sequence[Group], n_workers: int,
                  width: int = 4) -> int:
    """A2A bytes if every group used one fixed width (default log:6)."""
    return plan_cost(groups, [width] * len(groups), n_workers)
