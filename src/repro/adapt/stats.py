"""Device-resident gradient statistics for adaptive quantization.

Per-leaf statistics are computed **inside** the jitted train step (the
``adaptive`` mode's updater emits one row per leaf) and written into a
device stats ring managed by ``TrainSession`` exactly like the loss
ring: rows accumulate on device and are harvested in one transfer at
log/replan boundaries, so steady state adds zero host syncs.

Row layout (``STAT_FIELDS`` order, float32):

  ====  ==========  ==================================================
  col   field       reduction across mesh
  ====  ==========  ==================================================
  0     ``amax``    pmax  - max |delta + e| over workers/shards
  1     ``meansq``  pmean - mean (delta + e)^2 (quantizer input power)
  2     ``gsq``     pmean - mean g^2 (raw gradient power)
  ====  ==========  ==================================================

``local_stats`` / ``reduce_stats`` are traced jnp code; ``StatsEMA``
is the host-side history the controller feeds to the allocator.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

STAT_FIELDS: Tuple[str, ...] = ("amax", "meansq", "gsq")
N_FIELDS = len(STAT_FIELDS)


def local_stats(de: jax.Array, g: jax.Array) -> jax.Array:
    """One ``(N_FIELDS,)`` float32 row for this worker's leaf chunk.

    ``de`` is the quantizer input (delta + EF residual) - the tensor
    whose amax/power actually drive grid selection; ``g`` the raw
    gradient chunk.
    """
    de32 = de.astype(jnp.float32)
    g32 = g.astype(jnp.float32)
    return jnp.stack([jnp.max(jnp.abs(de32)),
                      jnp.mean(de32 * de32),
                      jnp.mean(g32 * g32)])


def reduce_stats(rows: jax.Array, axes: Sequence[str]) -> jax.Array:
    """Reduce stacked ``(n_leaves, N_FIELDS)`` local rows over mesh axes.

    amax reduces with pmax; the power columns with pmean (chunks are
    equal-size padded slices, so the mean of chunk means is the mean).
    """
    axes = tuple(axes)
    amax = jax.lax.pmax(rows[:, :1], axes)
    power = jax.lax.pmean(rows[:, 1:], axes)
    return jnp.concatenate([amax, power], axis=1)


class StatsEMA:
    """Host-side debiased EMA over harvested stats rows.

    amax tracks a peak-hold EMA (max of decayed history and the new
    observation) so transient spikes do not immediately shrink the
    grid range; the power columns use plain debiased EMAs.
    """

    def __init__(self, n_leaves: int, decay: float = 0.8):
        if not 0.0 <= decay < 1.0:
            raise ValueError(f"decay must be in [0, 1), got {decay}")
        self.decay = float(decay)
        self._ema = np.zeros((n_leaves, N_FIELDS), np.float64)
        self._amax_peak = np.zeros(n_leaves, np.float64)
        self._weight = 0.0

    @property
    def count(self) -> float:
        return self._weight

    def update(self, rows: np.ndarray) -> None:
        rows = np.asarray(rows, np.float64)
        if rows.shape != self._ema.shape:
            raise ValueError(
                f"stats row shape {rows.shape} != {self._ema.shape}")
        d = self.decay
        self._ema = d * self._ema + (1.0 - d) * rows
        self._weight = d * self._weight + (1.0 - d)
        self._amax_peak = np.maximum(d * self._amax_peak, rows[:, 0])

    def _debiased(self) -> np.ndarray:
        if self._weight <= 0.0:
            raise RuntimeError("StatsEMA.update never called")
        return self._ema / self._weight

    @property
    def amax(self) -> np.ndarray:
        """Peak-held amax per leaf (never below the debiased EMA)."""
        return np.maximum(self._debiased()[:, 0], self._amax_peak)

    @property
    def meansq(self) -> np.ndarray:
        return self._debiased()[:, 1]

    @property
    def gsq(self) -> np.ndarray:
        return self._debiased()[:, 2]

    def snapshot(self) -> Optional[np.ndarray]:
        """Debiased ``(n_leaves, N_FIELDS)`` view, or None before data."""
        if self._weight <= 0.0:
            return None
        out = self._debiased().copy()
        out[:, 0] = np.maximum(out[:, 0], self._amax_peak)
        return out

    def state_dict(self) -> dict:
        """JSON-serializable full state - rides in the checkpoint
        manifest ``extra`` so an adaptive resume replans from the same
        history it would have had uninterrupted."""
        return {"decay": self.decay,
                "ema": self._ema.tolist(),
                "amax_peak": self._amax_peak.tolist(),
                "weight": self._weight}

    @classmethod
    def from_state(cls, state: dict) -> "StatsEMA":
        ema = np.asarray(state["ema"], np.float64)
        if ema.ndim != 2 or ema.shape[1] != N_FIELDS:
            raise ValueError(f"bad EMA state shape {ema.shape}")
        obj = cls(ema.shape[0], float(state["decay"]))
        obj._ema = ema
        obj._amax_peak = np.asarray(state["amax_peak"], np.float64)
        if obj._amax_peak.shape != (ema.shape[0],):
            raise ValueError(
                f"bad amax_peak shape {obj._amax_peak.shape}")
        obj._weight = float(state["weight"])
        return obj
