"""Code-resident quantized weights for serving.

The paper motivates Q_x by "limited storage in edge devices" (Tables 2-3,
'Size'). The old ``quantize_resident_weights`` stored ``Q_x(x)`` *values*
back in fp32 - zero actual memory saved. This module keeps the integer
codes themselves resident:

  * ``quantize_params(params, k_x)`` replaces every large float leaf with a
    :class:`QuantizedLeaf` - integer codes (int16 above k_x=6; packed to
    the registry codec's 3/4/6-bit lanes with ``pack=True``) plus f32
    scales. Scan-stacked
    ``blocks`` leaves get one amax scale *per layer* (shape ``(L,)``), so
    ``lax.scan`` slices codes and scale together and each layer dequantizes
    independently.
  * ``make_dequant_gather()`` is a ``ShardCtx.param_gather`` hook: matmul-
    shaped leaves (projections, embeddings) stay as CODES end to end -
    their contractions run the fused dequant-matmul in
    :mod:`repro.comm.matmul` via ``QuantizedLeaf.__rmatmul__``/``take``,
    never materializing the fp tensor - and the remaining leaves
    dequantize *inside* the layer scan, at use. The resident footprint is
    the codes (``params_nbytes`` measures it: ~fp32/4 at k_x<=6).

Quantization itself goes through ``repro.opt.engine`` (Pallas kernels on
TPU, the same ``repro.opt.grids`` math everywhere else), and the packed
layout + lane width come from the ``repro.comm`` codec registry - so
resident payloads match the training/wire codecs bit-for-bit, and every
lane the registry packs (3/4/6-bit) is a residency option for free.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import comm
from repro.opt import engine, grids

_STACKED_KEYS = ("blocks", "enc_blocks")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedLeaf:
    """One parameter tensor held as integer codes + scales.

    codes: integer codes with the leaf's logical shape; when ``pack_bits``
        is set, uint8 with the last dim holding ``pack_bits``-bit lanes
        (``repro.comm.bits`` layout, per leading row - the same bytes
        the dist wire ships).
    scale: f32 scalar (per-tensor) or (L,) per-layer for stacked leaves.
        ``lax.scan`` slices it alongside the codes.
    """

    codes: jax.Array
    scale: jax.Array
    k_x: int = dataclasses.field(metadata=dict(static=True))
    shape: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True))
    dtype: str = dataclasses.field(metadata=dict(static=True))
    pack_bits: int = dataclasses.field(default=0, metadata=dict(static=True))
    # pending ``astype`` target: leaves routed through the fused matmul
    # record the activation-dtype cast here instead of materializing it,
    # and the kernel replicates the dequant->dtype->cast chain exactly
    cast: Optional[str] = dataclasses.field(
        default=None, metadata=dict(static=True))

    def tree_flatten(self):
        return ((self.codes, self.scale),
                (self.k_x, self.shape, self.dtype, self.pack_bits,
                 self.cast))

    @classmethod
    def tree_unflatten(cls, aux, children):
        codes, scale = children
        k_x, shape, dtype, pack_bits, cast = aux
        return cls(codes=codes, scale=scale, k_x=k_x, shape=shape,
                   dtype=dtype, pack_bits=pack_bits, cast=cast)

    @property
    def nbytes(self) -> int:
        """Actual resident bytes (codes + scales)."""
        return int(self.codes.nbytes) + int(self.scale.nbytes)

    def astype(self, dt) -> "QuantizedLeaf":
        """Defer a dtype cast (models call ``w.astype(x.dtype)`` on every
        projection); applied after dequant by every consuming path."""
        return dataclasses.replace(self, cast=jnp.dtype(dt).name)

    def dequantize(self) -> jax.Array:
        """Codes -> float tensor (called per-layer inside the model scan,
        where a stacked leaf's codes/scale arrive sliced to one layer)."""
        codes = self.codes
        if self.pack_bits:
            lead = codes.shape[:-1]
            flat = codes.reshape((-1, codes.shape[-1]))
            numel = self.shape[-1]  # logical last-dim length
            rows = comm.unpack_rows(flat, self.pack_bits, numel)
            codes = rows.reshape(lead + (numel,))
        scale = self.scale
        if scale.ndim:
            scale = scale.reshape(scale.shape + (1,) * (codes.ndim - scale.ndim))
        out = grids.uniform_dequantize(codes, scale, self.k_x).astype(
            jnp.dtype(self.dtype))
        return out.astype(jnp.dtype(self.cast)) if self.cast else out

    # -- fused contraction surface (repro.comm.matmul) ------------------
    # ``x @ leaf`` reflects to __rmatmul__ (jax arrays return
    # NotImplemented for unknown rhs types), so models' existing
    # ``x @ w.astype(x.dtype)`` projections dispatch here unchanged.

    def _mm(self, x, *, transpose: bool = False,
            backend: Optional[str] = None) -> jax.Array:
        kw = dict(k_x=self.k_x, n=self.shape[-1], pack_bits=self.pack_bits,
                  w_dtype=self.dtype, cast_dtype=self.cast,
                  transpose=transpose, backend=backend)
        if self.codes.ndim == 3:
            # stacked (L, ...) leaf used outside the scan: one fused call
            # per layer (each layer has its own scalar scale)
            return jnp.stack([
                comm.dequant_matmul(x[l], self.codes[l], self.scale[l], **kw)
                for l in range(self.codes.shape[0])])
        return comm.dequant_matmul(x, self.codes, self.scale, **kw)

    def matmul(self, x, backend: Optional[str] = None) -> jax.Array:
        """``x @ W`` without materializing W (fused dequant-matmul)."""
        return self._mm(x, backend=backend)

    def matmul_t(self, x, backend: Optional[str] = None) -> jax.Array:
        """``x @ W.T`` (tied-embedding logit heads) from codes."""
        return self._mm(x, transpose=True, backend=backend)

    def __rmatmul__(self, x) -> jax.Array:
        return self._mm(x)

    def take(self, idx) -> jax.Array:
        """Row lookup (embedding tables): gather only the requested code
        rows and dequantize those - bitwise identical to indexing the
        full ``dequantize()`` (elementwise dequant commutes with gather),
        without ever decoding the whole table."""
        codes = self.codes[idx]
        if self.pack_bits:
            lead = codes.shape[:-1]
            flat = codes.reshape((-1, codes.shape[-1]))
            numel = self.shape[-1]
            codes = comm.unpack_rows(flat, self.pack_bits, numel).reshape(
                lead + (numel,))
        out = grids.uniform_dequantize(codes, self.scale, self.k_x).astype(
            jnp.dtype(self.dtype))
        return out.astype(jnp.dtype(self.cast)) if self.cast else out


def _is_qleaf(x) -> bool:
    return isinstance(x, QuantizedLeaf)


def _path_head(path) -> Optional[str]:
    if not path:
        return None
    k = path[0]
    return getattr(k, "key", getattr(k, "name", None))


def _quantize_leaf(p: jax.Array, k_x: int, absolute: bool, per_layer: bool,
                   pack: bool) -> QuantizedLeaf:
    x = p.astype(jnp.float32)
    # engine dispatch: fused Pallas amax+quantize tiles on TPU; vmapped
    # over the layer dim for stacked leaves (one scale per layer)
    if per_layer:
        codes, scale = jax.vmap(
            lambda xl: engine.quantize_uniform(xl, k_x, absolute=absolute))(x)
    else:
        codes, scale = engine.quantize_uniform(x, k_x, absolute=absolute)
    # the registry's exact (unclipped) lane for this grid: 3/4/6-bit
    # lanes below int8 are worth packing, 8/16-bit codes stay as-is
    codec = comm.UniformCodec(k_x=k_x, absolute=absolute)
    pack_bits = 0
    if pack and codec.bits < 8:
        pack_bits = codec.bits
        lead = codes.shape[:-1]
        rows = comm.pack_rows(codes.reshape((-1, codes.shape[-1])),
                              pack_bits)
        codes = rows.reshape(lead + (rows.shape[-1],))
    return QuantizedLeaf(codes=codes, scale=scale, k_x=k_x,
                         shape=tuple(p.shape), dtype=jnp.dtype(p.dtype).name,
                         pack_bits=pack_bits)


def quantize_params(params, k_x: int = 6, *, absolute: bool = False,
                    min_numel: int = 2 ** 14, pack: bool = False):
    """Replace large float leaves with code-resident :class:`QuantizedLeaf`.

    Stacked ``blocks``/``enc_blocks`` leaves get per-layer scales (finer
    than a whole-stack amax, and what the per-layer dequant-at-use needs).
    Leaves smaller than ``min_numel`` (biases, norms) stay float.
    """
    def one(path, p):
        if (not hasattr(p, "dtype")
                or not jnp.issubdtype(p.dtype, jnp.floating)
                or p.ndim == 0 or p.size < min_numel):
            return p
        per_layer = _path_head(path) in _STACKED_KEYS and p.ndim > 1
        return _quantize_leaf(p, k_x, absolute, per_layer, pack)

    return jax.tree_util.tree_map_with_path(one, params)


def is_quantized(params) -> bool:
    return any(_is_qleaf(l) for l in
               jax.tree.leaves(params, is_leaf=_is_qleaf))


# Leaf names whose contraction the model expresses as ``x @ w`` (or an
# embed lookup / tied ``x @ w.T``): these stay code-resident through the
# gather and dispatch to repro.comm.matmul. Everything else (conv taps,
# MoE expert stacks, meta-token banks, norms) is consumed elementwise or
# via einsum and still dequantizes whole.
_MATMUL_KEYS = frozenset({
    "q", "k", "v", "o", "w_gate", "w_up", "w_down", "router",
    "in_proj", "out_proj", "embed", "unembed",
})


def _path_name(path) -> Optional[str]:
    if not path:
        return None
    k = path[-1]
    return getattr(k, "key", getattr(k, "name", None))


def _fused_ok(path, leaf, kind: str) -> bool:
    """True when this quantized leaf can stay as codes for the fused
    matmul: a known projection name AND 2-D logical weight. Inside the
    scan ("blocks"/"enc_blocks") codes arrive sliced but the aux shape is
    still the stacked (L, K, N), so 2-D-when-sliced means len(shape) == 3;
    higher-rank stacks (MoE experts, meta banks) fall through to
    ``dequantize()``."""
    if _path_name(path) not in _MATMUL_KEYS:
        return False
    want = 2 if kind == "static" else 3
    return len(leaf.shape) == want


def make_dequant_gather(inner=None, fused: bool = True):
    """A ``ShardCtx.param_gather`` hook for code-resident params. The
    "static" pass leaves scan-stacked subtrees quantized so ``lax.scan``
    carries the codes and each layer decodes only its own slice.

    With ``fused`` (the default since the fused dequant-matmul landed),
    matmul-shaped leaves - attention/MLP/SSM projections, routers,
    embed/unembed - are ALSO left as codes and their ``x @ w`` sites
    dispatch to ``repro.comm.matmul.dequant_matmul``; only conv taps,
    expert stacks, and other non-matmul leaves are materialized. Pass
    ``fused=False`` for the pre-PR-7 dequantize-everything semantics.
    ``inner``: optional downstream gather to compose with (mesh serving).
    """
    def deq(leaf):
        return leaf.dequantize() if _is_qleaf(leaf) else leaf

    def gather(subtree, kind: str):
        def one(path, leaf):
            if kind == "static" and _path_head(path) in _STACKED_KEYS:
                return leaf  # decoded per-layer inside the scan
            if fused and _is_qleaf(leaf) and _fused_ok(path, leaf, kind):
                return leaf  # codes feed the fused matmul directly
            return deq(leaf)
        out = jax.tree_util.tree_map_with_path(one, subtree,
                                               is_leaf=_is_qleaf)
        return inner(out, kind) if inner is not None else out

    return gather


def params_nbytes(params) -> int:
    """Actual resident bytes of a parameter tree (codes + scales for
    quantized leaves, array bytes otherwise) - what the example and tests
    assert against, instead of printing a theoretical "~/4"."""
    total = 0
    for leaf in jax.tree.leaves(params, is_leaf=_is_qleaf):
        total += leaf.nbytes if _is_qleaf(leaf) else int(leaf.nbytes)
    return total


def cache_nbytes(cache) -> int:
    """Resident bytes of a decode cache (fixed lanes or paged pool +
    tables alike) - the number the fleet benchmark equalizes when it
    compares paged vs fixed-lane serving at equal cache memory."""
    return sum(int(leaf.nbytes) for leaf in jax.tree.leaves(cache))
