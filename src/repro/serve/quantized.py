"""Code-resident quantized weights for serving.

The paper motivates Q_x by "limited storage in edge devices" (Tables 2-3,
'Size'). The old ``quantize_resident_weights`` stored ``Q_x(x)`` *values*
back in fp32 - zero actual memory saved. This module keeps the integer
codes themselves resident:

  * ``quantize_params(params, k_x)`` replaces every large float leaf with a
    :class:`QuantizedLeaf` - integer codes (int16 above k_x=6; packed to
    the registry codec's 3/4/6-bit lanes with ``pack=True``) plus f32
    scales. Scan-stacked
    ``blocks`` leaves get one amax scale *per layer* (shape ``(L,)``), so
    ``lax.scan`` slices codes and scale together and each layer dequantizes
    independently.
  * ``make_dequant_gather()`` is a ``ShardCtx.param_gather`` hook: the model
    dequantizes each block's leaves *inside* the layer scan, at use - only
    one layer's fp weights are ever live, the resident footprint is the
    codes (``params_nbytes`` measures it: ~fp32/4 at k_x<=6).

Quantization itself goes through ``repro.opt.engine`` (Pallas kernels on
TPU, the same ``repro.opt.grids`` math everywhere else), and the packed
layout + lane width come from the ``repro.comm`` codec registry - so
resident payloads match the training/wire codecs bit-for-bit, and every
lane the registry packs (3/4/6-bit) is a residency option for free.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import comm
from repro.opt import engine, grids

_STACKED_KEYS = ("blocks", "enc_blocks")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedLeaf:
    """One parameter tensor held as integer codes + scales.

    codes: integer codes with the leaf's logical shape; when ``pack_bits``
        is set, uint8 with the last dim holding ``pack_bits``-bit lanes
        (``repro.comm.bits`` layout, per leading row - the same bytes
        the dist wire ships).
    scale: f32 scalar (per-tensor) or (L,) per-layer for stacked leaves.
        ``lax.scan`` slices it alongside the codes.
    """

    codes: jax.Array
    scale: jax.Array
    k_x: int = dataclasses.field(metadata=dict(static=True))
    shape: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True))
    dtype: str = dataclasses.field(metadata=dict(static=True))
    pack_bits: int = dataclasses.field(default=0, metadata=dict(static=True))

    def tree_flatten(self):
        return ((self.codes, self.scale),
                (self.k_x, self.shape, self.dtype, self.pack_bits))

    @classmethod
    def tree_unflatten(cls, aux, children):
        codes, scale = children
        k_x, shape, dtype, pack_bits = aux
        return cls(codes=codes, scale=scale, k_x=k_x, shape=shape,
                   dtype=dtype, pack_bits=pack_bits)

    @property
    def nbytes(self) -> int:
        """Actual resident bytes (codes + scales)."""
        return int(self.codes.nbytes) + int(self.scale.nbytes)

    def dequantize(self) -> jax.Array:
        """Codes -> float tensor (called per-layer inside the model scan,
        where a stacked leaf's codes/scale arrive sliced to one layer)."""
        codes = self.codes
        if self.pack_bits:
            lead = codes.shape[:-1]
            flat = codes.reshape((-1, codes.shape[-1]))
            numel = self.shape[-1]  # logical last-dim length
            rows = comm.unpack_rows(flat, self.pack_bits, numel)
            codes = rows.reshape(lead + (numel,))
        scale = self.scale
        if scale.ndim:
            scale = scale.reshape(scale.shape + (1,) * (codes.ndim - scale.ndim))
        return grids.uniform_dequantize(codes, scale, self.k_x).astype(
            jnp.dtype(self.dtype))


def _is_qleaf(x) -> bool:
    return isinstance(x, QuantizedLeaf)


def _path_head(path) -> Optional[str]:
    if not path:
        return None
    k = path[0]
    return getattr(k, "key", getattr(k, "name", None))


def _quantize_leaf(p: jax.Array, k_x: int, absolute: bool, per_layer: bool,
                   pack: bool) -> QuantizedLeaf:
    x = p.astype(jnp.float32)
    # engine dispatch: fused Pallas amax+quantize tiles on TPU; vmapped
    # over the layer dim for stacked leaves (one scale per layer)
    if per_layer:
        codes, scale = jax.vmap(
            lambda xl: engine.quantize_uniform(xl, k_x, absolute=absolute))(x)
    else:
        codes, scale = engine.quantize_uniform(x, k_x, absolute=absolute)
    # the registry's exact (unclipped) lane for this grid: 3/4/6-bit
    # lanes below int8 are worth packing, 8/16-bit codes stay as-is
    codec = comm.UniformCodec(k_x=k_x, absolute=absolute)
    pack_bits = 0
    if pack and codec.bits < 8:
        pack_bits = codec.bits
        lead = codes.shape[:-1]
        rows = comm.pack_rows(codes.reshape((-1, codes.shape[-1])),
                              pack_bits)
        codes = rows.reshape(lead + (rows.shape[-1],))
    return QuantizedLeaf(codes=codes, scale=scale, k_x=k_x,
                         shape=tuple(p.shape), dtype=jnp.dtype(p.dtype).name,
                         pack_bits=pack_bits)


def quantize_params(params, k_x: int = 6, *, absolute: bool = False,
                    min_numel: int = 2 ** 14, pack: bool = False):
    """Replace large float leaves with code-resident :class:`QuantizedLeaf`.

    Stacked ``blocks``/``enc_blocks`` leaves get per-layer scales (finer
    than a whole-stack amax, and what the per-layer dequant-at-use needs).
    Leaves smaller than ``min_numel`` (biases, norms) stay float.
    """
    def one(path, p):
        if (not hasattr(p, "dtype")
                or not jnp.issubdtype(p.dtype, jnp.floating)
                or p.ndim == 0 or p.size < min_numel):
            return p
        per_layer = _path_head(path) in _STACKED_KEYS and p.ndim > 1
        return _quantize_leaf(p, k_x, absolute, per_layer, pack)

    return jax.tree_util.tree_map_with_path(one, params)


def is_quantized(params) -> bool:
    return any(_is_qleaf(l) for l in
               jax.tree.leaves(params, is_leaf=_is_qleaf))


def make_dequant_gather(inner=None):
    """A ``ShardCtx.param_gather`` hook that dequantizes ``QuantizedLeaf``
    leaves at use. The "static" pass leaves scan-stacked subtrees quantized
    so ``lax.scan`` carries the codes and each layer dequantizes only its
    own slice; every other kind dequantizes the (sliced) subtree whole.
    ``inner``: optional downstream gather to compose with (mesh serving).
    """
    def deq(leaf):
        return leaf.dequantize() if _is_qleaf(leaf) else leaf

    def gather(subtree, kind: str):
        if kind == "static":
            def one(path, leaf):
                if _path_head(path) in _STACKED_KEYS:
                    return leaf  # dequantized per-layer inside the scan
                return deq(leaf)
            out = jax.tree_util.tree_map_with_path(one, subtree,
                                                   is_leaf=_is_qleaf)
        else:
            out = jax.tree.map(deq, subtree, is_leaf=_is_qleaf)
        return inner(out, kind) if inner is not None else out

    return gather


def params_nbytes(params) -> int:
    """Actual resident bytes of a parameter tree (codes + scales for
    quantized leaves, array bytes otherwise) - what the example and tests
    assert against, instead of printing a theoretical "~/4"."""
    total = 0
    for leaf in jax.tree.leaves(params, is_leaf=_is_qleaf):
        total += leaf.nbytes if _is_qleaf(leaf) else int(leaf.nbytes)
    return total
