"""Back-compat batch API: ``Engine.generate`` as a thin shim over
:class:`repro.serve.session.ServeSession`.

The old Engine padded a fixed batch, ran prefill once, then round-tripped
every token through the host (one ``int(jnp.argmax(...))`` per request per
step) - and its "quantized-resident" mode stored fp32 values. New code
should use ``ServeSession`` directly (continuous batching, jitted
sampling) with ``quantize_params`` for genuinely code-resident weights;
``Engine`` just maps one request list onto one session.
"""
from __future__ import annotations

from typing import List, Optional

import jax

from repro.serve.quantized import quantize_params
from repro.serve.session import Request, Result, ServeSession

__all__ = ["Engine", "Request", "Result"]


class Engine:
    """One-shot batch generation (compat shim; see module docstring)."""

    def __init__(self, model, params, max_seq: int = 256,
                 quantized: bool = False, k_x: int = 6):
        self.model = model
        self.cfg = model.cfg
        self.max_seq = max_seq
        self.params = (quantize_params(params, k_x=k_x) if quantized
                       else params)
        self._session: Optional[ServeSession] = None

    def generate(self, requests: List[Request], key=None) -> List[Result]:
        # one session, grown (and recompiled) only when a larger batch
        # arrives; smaller batches ride idle slots
        if self._session is None or self._session.slots < len(requests):
            self._session = ServeSession(self.model, self.params,
                                         slots=len(requests),
                                         max_seq=self.max_seq, seed=0)
        session = self._session
        # old-Engine semantics: identical (requests, key) -> identical draws
        session.reseed(key if key is not None else jax.random.PRNGKey(0))
        handles = [session.submit(r) for r in requests]
        results = session.drain()
        return [results[h] for h in handles]
