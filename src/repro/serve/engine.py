"""Batched serving engine: prefill + decode with (optionally) int8-resident
quantized weights - the paper's weight-quantization motivation ("limited
storage in edge devices") applied to a serving fleet.

The engine pads a list of prompts into a batch, runs a single prefill to
build the KV/SSM cache, then steps the decode loop greedily (or with
temperature sampling). Works single-device or on a mesh via
repro.dist.serve.make_serve_step.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.models.layers import ShardCtx
from repro.core.quantizers import get_quantizer


@dataclasses.dataclass
class Request:
    prompt: Sequence[int]
    max_new_tokens: int = 16
    temperature: float = 0.0


@dataclasses.dataclass
class Result:
    tokens: List[int]
    prompt_len: int


def quantize_resident_weights(params, k_x: int = 6):
    """Store weights as Q_x(x) - model size /4 vs f32 (Table 2 'Size')."""
    q = get_quantizer(f"uniform_amax:{k_x}")

    def leaf(p):
        if p.size < 2 ** 14:
            return p
        return q(p).astype(p.dtype)
    return jax.tree.map(leaf, params)


class Engine:
    def __init__(self, model: Model, params, max_seq: int = 256,
                 quantized: bool = False, k_x: int = 6):
        self.model = model
        self.cfg = model.cfg
        self.max_seq = max_seq
        self.params = (quantize_resident_weights(params, k_x)
                       if quantized else params)
        self._decode = jax.jit(
            lambda p, i, c, pos: model.decode_step(p, i, c, pos))
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, max_seq_local=max_seq))

    def generate(self, requests: List[Request], key=None) -> List[Result]:
        cfg = self.cfg
        B = len(requests)
        plens = [len(r.prompt) for r in requests]
        pmax = max(plens)
        toks = np.zeros((B, pmax), np.int32)
        mask = np.zeros((B, pmax), np.float32)
        for i, r in enumerate(requests):
            toks[i, :plens[i]] = np.asarray(r.prompt, np.int32)
            mask[i, :plens[i]] = 1.0
        batch = {"tokens": jnp.asarray(toks),
                 "targets": jnp.asarray(toks),
                 "mask": jnp.asarray(mask)}

        logits, cache = self._prefill(self.params, batch)
        # last valid logit per row
        last = jnp.asarray([p - 1 for p in plens])
        cur = jnp.argmax(logits[jnp.arange(B), last], axis=-1)

        outs = [[int(cur[i])] for i in range(B)]
        key = key if key is not None else jax.random.PRNGKey(0)
        max_new = max(r.max_new_tokens for r in requests)
        pos = pmax  # decode appends after the padded prompt region
        for t in range(max_new - 1):
            lg, cache = self._decode(self.params, {"token": cur[:, None]},
                                     cache, jnp.int32(pos + t))
            nxt = []
            for i, r in enumerate(requests):
                if r.temperature > 0:
                    key, sub = jax.random.split(key)
                    tok = int(jax.random.categorical(
                        sub, lg[i] / r.temperature))
                else:
                    tok = int(jnp.argmax(lg[i]))
                nxt.append(tok)
            cur = jnp.asarray(nxt, jnp.int32)
            for i in range(B):
                if len(outs[i]) < requests[i].max_new_tokens:
                    outs[i].append(int(cur[i]))
        return [Result(tokens=outs[i], prompt_len=plens[i])
                for i in range(B)]
