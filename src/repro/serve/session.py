"""Continuous-batching serve sessions over a fixed pool of decode slots.

``ServeSession`` replaces the old batch-synchronous ``Engine.generate``:

  * ``submit(Request) -> handle`` claims a free slot (or queues); new
    requests join mid-flight as others finish - the batch never drains to
    restart. Requests carry an SLO class (``interactive`` > ``standard``
    > ``batch``): the pending queue is priority-ordered and, under pool
    pressure, a higher-class arrival preempts the lowest-class occupant
    (requeue-and-recompute by default, or ``preempt_mode="kill"`` which
    surfaces ``finish_reason="preempted"``).
  * ``step()`` runs ONE jitted decode step over all slots: token embedding,
    attention against each slot's own cache prefix (per-slot positions -
    slot i attends exactly its ``pos_i`` written entries, never padding or
    a previous occupant's rows), and sampling (greedy + per-slot
    temperature via a temperature vector and per-slot PRNG keys) all inside
    the compiled step. The host dispatches and moves on: zero per-token
    device->host transfers.
  * ``drain()`` runs until every submitted request finished and returns
    ``{handle: Result}``.

Decode state keeps a fixed shape - (slots,) control vectors + the cache -
so exactly one compiled decode step is reused for the whole session, with
the state buffers donated through it. The cache is either fixed-lane
(``(layers, slots, max_seq, ...)``) or, with ``paged=True``, a physical
page pool + per-slot page table (``repro.serve.paged``): slots then pin
only the pages their tokens occupy, so concurrency is bounded by tokens
in flight rather than ``slots * max_seq``, and admission validates page
availability up front - ``finish_reason="cache_full"`` cannot happen
while the pool has free pages.

Admission (local sessions) runs **chunked prefill** by default: the
prompt advances through ``model.decode_chunk`` in fixed-size chunks, one
chunk interleaved before each decode dispatch, so a long prompt never
stalls the decode batch and the per-prompt-length jit cache collapses to
exactly two chunk shapes (mid/final). ``prefill="whole"`` restores the
legacy one-shot batched prefill (fixed lanes only, compiled per prompt
length); mesh ``decode_fn`` sessions and SSD chunk-misaligned prompts
fall back to injecting the prompt through the decode step one token per
dispatch.

The decode callable is pluggable: the default wraps
``model.decode_step`` locally (dequantizing ``QuantizedParams`` per layer
at use); pass ``decode_fn=`` from ``repro.dist.serve.make_serve_step`` to
run the same session over a mesh - single-device and sharded serving are
one API (paged state is local-only for now; the mesh decode over a
sharded page pool lives in ``repro.dist.serve``'s cache specs).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import ShardCtx
from repro.perf import aot
from repro.perf import cache as perf_cache
from repro.serve.paged import PagePool
from repro.serve.quantized import is_quantized, make_dequant_gather

# SLO classes, higher = more urgent. The queue is ordered by (class,
# arrival); preemption only ever evicts a strictly lower class.
SLO_PRIORITY = {"batch": 0, "standard": 1, "interactive": 2}

_PAGED_LEAVES = ("pk", "pv", "ptab")


def _raw_key(key: jax.Array) -> jax.Array:
    """Normalize legacy (2,) uint32 / new-style typed PRNG keys to the raw
    uint32 data the per-slot key buffer stores."""
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        key = jax.random.key_data(key)
    key = jnp.asarray(key, jnp.uint32)
    if key.shape != (2,):
        raise ValueError("ServeSession needs a threefry PRNG key "
                         f"(2 uint32 words); got key data {key.shape}")
    return key


@dataclasses.dataclass
class Request:
    prompt: Sequence[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    slo: str = "standard"           # "interactive" | "standard" | "batch"


@dataclasses.dataclass
class Result:
    tokens: List[int]
    prompt_len: int
    handle: int = -1
    # "length" | "eos" | "cache_full" | "preempted"
    finish_reason: str = "length"


class ServeSession:
    """Slot-scheduled continuous-batching session.

    model: repro.models.model.Model (token-input decoder LM).
    params: the model's parameter tree; may contain ``QuantizedLeaf``
        leaves from ``quantize_params`` (local decode path only).
    slots: number of concurrent decode lanes (the fixed batch width).
    max_seq: per-slot cache length; a request needs
        ``len(prompt) + max_new_tokens - 1 <= max_seq``.
    eos_id: optional token id that finishes a request early.
    decode_fn: optional ``(params, inputs, cache, pos) -> (logits, cache)``
        override, e.g. from ``dist.serve.make_serve_step(..., "decode")``.
    paged: replace the fixed cache lanes with a page pool + page tables
        (``page_size`` tokens per page, ``num_pages`` physical pages -
        default ``slots * max_seq / page_size``, i.e. fixed-lane-equal
        memory). Local decode path only; requires
        ``max_seq % page_size == 0``. Decode over the paged view is
        bitwise identical to fixed-lane decode.
    prefill: admission mode - "auto" (chunked locally, injection on a
        mesh), "chunked", "whole" (legacy batched prefill, fixed lanes
        only), or "inject". Chunked admission advances ``prefill_chunk``
        prompt tokens per session step, interleaved with decode.
    preempt_mode: "requeue" re-admits a preempted request from its prompt
        with its original sampling key (identical tokens to an
        unpreempted run); "kill" returns the partial generation with
        ``finish_reason="preempted"``.
    sync_interval: while requests are queued AND a slot may have finished
        early (EOS configured), harvest every N steps. Without an EOS the
        scheduler knows each slot's earliest possible finish step
        host-side and harvests only then - O(requests) syncs, never
        O(tokens); with an empty queue the steady-state loop never syncs.
    aot_dir: AOT artifact directory (``repro.perf.aot``) for the compiled
        decode step, keyed on (model config digest, slots, max_seq, paged
        geometry, sample mode, quantization, arg signature). A warm dir
        makes the first dispatch skip trace+lower+compile; local decode
        path only. ``stats`` records ``compilations`` vs ``aot_loads``.
    """

    def __init__(self, model, params, *, slots: int = 8, max_seq: int = 256,
                 eos_id: Optional[int] = None,
                 decode_fn: Optional[Callable] = None,
                 base_key: Optional[jax.Array] = None, seed: int = 0,
                 sync_interval: int = 8, aot_dir: Optional[str] = None,
                 fused_matmul: bool = True,
                 paged: bool = False, page_size: int = 16,
                 num_pages: Optional[int] = None,
                 prefill: str = "auto", prefill_chunk: int = 32,
                 preempt_mode: str = "requeue"):
        cfg = model.cfg
        if cfg.input_mode != "tokens" or cfg.arch_type == "encdec":
            raise ValueError("ServeSession serves token-input decoder LMs")
        self.model, self.cfg = model, cfg
        self.slots, self.max_seq, self.eos_id = slots, max_seq, eos_id
        self.sync_interval = max(1, sync_interval)
        self.params = params
        self._local = decode_fn is None
        self.paged = bool(paged)
        if self.paged:
            if not self._local:
                raise ValueError("paged sessions use the local decode path; "
                                 "mesh paged decode runs through "
                                 "dist.serve cache specs directly")
            if cfg.arch_type == "ssm":
                raise ValueError("pure-SSM models hold no KV cache to page")
            if max_seq % page_size:
                raise ValueError(f"max_seq={max_seq} must be a multiple of "
                                 f"page_size={page_size}")
            self.page_size = int(page_size)
            self.num_pages = int(num_pages if num_pages is not None
                                 else slots * (max_seq // page_size))
            self._pool = PagePool(self.num_pages, self.page_size)
        else:
            self.page_size = self.num_pages = 0
            self._pool = None
        if prefill not in ("auto", "chunked", "whole", "inject"):
            raise ValueError(f"unknown prefill mode {prefill!r}")
        if prefill == "whole" and self.paged:
            raise ValueError("whole-prompt prefill fills a dense lane; "
                             "paged sessions admit chunked (or inject)")
        self._prefill_mode = prefill
        self.prefill_chunk = max(1, int(prefill_chunk))
        if preempt_mode not in ("requeue", "kill"):
            raise ValueError(f"unknown preempt_mode {preempt_mode!r}")
        self.preempt_mode = preempt_mode
        # fused_matmul: quantized projections contract straight from codes
        # (repro.comm.matmul); False restores dequantize-then-matmul.
        # Bitwise-identical tokens either way - this is a perf knob.
        self.fused_matmul = bool(fused_matmul) and is_quantized(params)
        self._ctx = (ShardCtx(param_gather=make_dequant_gather(
                         fused=fused_matmul))
                     if is_quantized(params) else ShardCtx())
        if decode_fn is None:
            ctx = self._ctx
            decode_fn = lambda p, i, c, pos: model.decode_step(p, i, c, pos,
                                                               ctx)
        elif is_quantized(params):
            raise ValueError("QuantizedParams require the local decode path;"
                             " a mesh decode_fn brings its own weight wire")
        self._decode = decode_fn
        self._prefill_fns: Dict[int, Callable] = {}  # keyed by prompt len
        # two step variants: sessions whose admitted requests are all
        # greedy never pay (or compile) the categorical sampling pass
        self._step_greedy = jax.jit(self._build_step(sample=False),
                                    donate_argnums=(1,))
        self._step_sample = jax.jit(self._build_step(sample=True),
                                    donate_argnums=(1,))
        self._admit_fn = jax.jit(self._build_admit(), donate_argnums=(0,))
        self._stage_fn = jax.jit(self._build_stage(), donate_argnums=(0,))
        self._release_fn = jax.jit(self._build_release(), donate_argnums=(0,))
        self._chunk_fns: Dict[bool, Callable] = {}   # is_last -> jitted
        self._aot_dir = aot_dir if self._local else None
        self._step_ready: Dict[bool, Callable] = {}  # sample -> executable
        perf_cache.ensure_persistent_cache()  # opt-in via env, see cache.py
        self._state = self._init_state()
        self._base_key = _raw_key(base_key if base_key is not None
                                  else jax.random.PRNGKey(seed))
        self._hot: set = set()          # handles in slots with temp > 0
        self._slot_handle: List[Optional[int]] = [None] * slots
        self._slot_done_step = [0] * slots   # earliest possible finish
        self._slot_pages: List[Optional[List[int]]] = [None] * slots
        self._prefill_q: "collections.OrderedDict[int, dict]" = \
            collections.OrderedDict()   # slot -> chunked-admission progress
        self._pending: List[int] = []   # handles, (priority, arrival) order
        self._requests: Dict[int, Request] = {}
        self._req_key: Dict[int, jax.Array] = {}   # stable across preemption
        self._results: Dict[int, Result] = {}
        self._submit_t: Dict[int, float] = {}
        self.ttft_s: Dict[int, float] = {}  # submit -> first-token dispatch
        self._next_handle = 0
        self._admit_seq = 0             # submissions since the last reseed
        self._steps = 0
        self.stats = {"dispatches": 0, "syncs": 0, "admitted": 0,
                      "compilations": 0, "aot_loads": 0,
                      "preemptions": 0, "chunk_dispatches": 0,
                      "max_inflight": 0}

    # ------------------------------------------------------------------
    # device-side state + compiled programs
    # ------------------------------------------------------------------

    def _init_state(self):
        B, S = self.slots, self.max_seq
        pool = (self.num_pages, self.page_size) if self.paged else None
        cache = self.model.init_cache(B, max_seq_local=S, page_pool=pool)
        z = lambda dt: jnp.zeros((B,), dt)
        return dict(cache=cache, cur=z(jnp.int32), pos=z(jnp.int32),
                    plen=z(jnp.int32), gen=z(jnp.int32),
                    max_new=z(jnp.int32), active=z(bool),
                    temp=z(jnp.float32),
                    rng=jnp.zeros((B, 2), jnp.uint32),
                    prompt=jnp.zeros((B, S), jnp.int32),
                    out=jnp.zeros((B, S), jnp.int32))

    def _claim_cache(self, cache, slot, ptab_row):
        """Slot-reuse reclaim, in-jit: zero only the recurrent lanes (SSM
        state, conv tail) - per-slot attention masking already makes a
        previous occupant's K/V rows unreachable, so the old whole-lane
        zeroing was pure wasted bandwidth - and install the slot's page
        table row when paged."""
        cache = dict(cache)
        for name in cache:
            if name in ("ssm", "conv"):
                cache[name] = cache[name].at[:, slot].set(0)
        if self.paged:
            cache["ptab"] = cache["ptab"].at[slot].set(ptab_row)
        return cache

    def _build_admit(self):
        S, paged = self.max_seq, self.paged

        def admit(st, slot, prompt, plen, max_new, temp, key, ptab_row):
            st = dict(st)
            st["prompt"] = st["prompt"].at[slot].set(prompt)
            st["cur"] = st["cur"].at[slot].set(prompt[0])
            st["pos"] = st["pos"].at[slot].set(0)
            st["plen"] = st["plen"].at[slot].set(plen)
            st["gen"] = st["gen"].at[slot].set(0)
            st["max_new"] = st["max_new"].at[slot].set(max_new)
            st["active"] = st["active"].at[slot].set(True)
            st["temp"] = st["temp"].at[slot].set(temp)
            st["rng"] = st["rng"].at[slot].set(key)
            st["cache"] = self._claim_cache(st["cache"], slot, ptab_row)
            return st
        return admit

    def _build_stage(self):
        """Claim a slot for chunked admission: recurrent lanes zeroed and
        the page-table row installed, but the slot stays inactive with
        ``pos = max_seq`` so interleaved decode steps neither advance it
        nor write into its (paged) cache while chunks are in flight."""
        S = self.max_seq

        def stage(st, slot, ptab_row):
            st = dict(st)
            st["active"] = st["active"].at[slot].set(False)
            st["pos"] = st["pos"].at[slot].set(S)
            st["gen"] = st["gen"].at[slot].set(0)
            st["cache"] = self._claim_cache(st["cache"], slot, ptab_row)
            return st
        return stage

    def _build_release(self):
        """Free a slot in-jit (harvest page reclaim / preemption): decode
        writes for the row are suppressed (paged: RELEASED-sentinel page
        table + out-of-view position drop the scatters, so recycled pages
        can never be corrupted by the previous owner)."""
        S, paged, P = self.max_seq, self.paged, self.num_pages

        def release(st, slot):
            st = dict(st)
            st["active"] = st["active"].at[slot].set(False)
            st["pos"] = st["pos"].at[slot].set(S)
            if paged:
                cache = dict(st["cache"])
                npag = cache["ptab"].shape[1]
                cache["ptab"] = cache["ptab"].at[slot].set(
                    jnp.full((npag,), P, jnp.int32))
                st["cache"] = cache
            return st
        return release

    def _build_prefill(self, plen: int):
        """Legacy admission via one batched prefill over the whole prompt:
        fills the slot's cache lane and emits the first generated token.
        Compiled once per distinct prompt length (``prefill="whole"``);
        chunked admission replaces this with two chunk-shaped programs."""
        model, S, eos, ctx = self.model, self.max_seq, self.eos_id, self._ctx

        def prefill(params, st, slot, prompt, max_new, temp, key):
            batch = {"tokens": prompt[None], "targets": prompt[None],
                     "mask": jnp.ones((1, plen), jnp.float32)}
            logits, lane = model.prefill(params, batch, max_seq_local=S,
                                         ctx=ctx)
            lg = logits[0, plen - 1].astype(jnp.float32)
            greedy = jnp.argmax(lg).astype(jnp.int32)
            k_next, k_draw = jax.random.split(key)
            sampled = jax.random.categorical(
                k_draw, lg / jnp.maximum(temp, 1e-6)).astype(jnp.int32)
            hot = temp > 0.0
            t0 = jnp.where(hot, sampled, greedy)
            st = dict(st)
            st["cache"] = {
                k: st["cache"][k].at[:, slot].set(
                    lane[k][:, 0].astype(st["cache"][k].dtype))
                for k in st["cache"]}
            st["prompt"] = st["prompt"].at[slot].set(
                jnp.zeros((S,), jnp.int32).at[:plen].set(prompt))
            st["cur"] = st["cur"].at[slot].set(t0)
            st["pos"] = st["pos"].at[slot].set(plen)
            st["plen"] = st["plen"].at[slot].set(plen)
            st["gen"] = st["gen"].at[slot].set(1)
            st["out"] = st["out"].at[slot, 0].set(t0)
            st["max_new"] = st["max_new"].at[slot].set(max_new)
            done = max_new <= 1
            if eos is not None:
                done |= t0 == jnp.int32(eos)
            st["active"] = st["active"].at[slot].set(~done)
            st["temp"] = st["temp"].at[slot].set(temp)
            st["rng"] = st["rng"].at[slot].set(
                jnp.where(hot, k_next, key))
            return st
        return prefill

    def _build_chunk(self, is_last: bool):
        """One chunked-prefill dispatch for one slot: advance the slot's
        cache by ``prefill_chunk`` prompt tokens via ``model.decode_chunk``.
        The final chunk additionally samples the first generated token
        with exactly the whole-prefill key discipline (one split, draw on
        one half, store the other), so chunked admissions reproduce the
        same per-request sampling streams on fixed-lane and paged
        sessions alike."""
        model, S, eos, ctx = self.model, self.max_seq, self.eos_id, self._ctx

        def chunk(params, st, slot, tokens, start, nvalid, max_new, temp,
                  key):
            st = dict(st)
            cache = st["cache"]
            lane = {}
            for name in cache:
                if name in ("pk", "pv"):
                    lane[name] = cache[name]
                elif name == "ptab":
                    lane[name] = jax.lax.dynamic_slice_in_dim(
                        cache[name], slot, 1, axis=0)
                else:
                    lane[name] = jax.lax.dynamic_slice_in_dim(
                        cache[name], slot, 1, axis=1)
            lg, new_lane = model.decode_chunk(
                params, {"token": tokens[None]}, lane,
                start[None], nvalid[None], ctx)
            newc = {}
            for name in cache:
                if name in ("pk", "pv"):
                    newc[name] = new_lane[name]
                elif name == "ptab":
                    newc[name] = cache[name]   # rows set at staging
                else:
                    newc[name] = jax.lax.dynamic_update_slice_in_dim(
                        cache[name], new_lane[name], slot, axis=1)
            st["cache"] = newc
            if is_last:
                lgf = lg[0].astype(jnp.float32)
                greedy = jnp.argmax(lgf).astype(jnp.int32)
                k_next, k_draw = jax.random.split(key)
                sampled = jax.random.categorical(
                    k_draw, lgf / jnp.maximum(temp, 1e-6)).astype(jnp.int32)
                hot = temp > 0.0
                t0 = jnp.where(hot, sampled, greedy)
                plen = start + nvalid
                st["cur"] = st["cur"].at[slot].set(t0)
                st["pos"] = st["pos"].at[slot].set(plen)
                st["plen"] = st["plen"].at[slot].set(plen)
                st["gen"] = st["gen"].at[slot].set(1)
                st["out"] = st["out"].at[slot, 0].set(t0)
                st["max_new"] = st["max_new"].at[slot].set(max_new)
                done = max_new <= 1
                if eos is not None:
                    done |= t0 == jnp.int32(eos)
                st["active"] = st["active"].at[slot].set(~done)
                st["temp"] = st["temp"].at[slot].set(temp)
                st["rng"] = st["rng"].at[slot].set(
                    jnp.where(hot, k_next, key))
            return st
        return chunk

    def _can_prefill_whole(self, plen: int) -> bool:
        if not self._local or plen < 2:
            return False
        if self.cfg.arch_type in ("ssm", "hybrid"):
            # the SSD chunked scan needs the sequence to tile its chunk
            return plen % self.cfg.ssm.chunk == 0
        return True

    def _admission_mode(self, plen: int) -> str:
        if self._prefill_mode == "inject" or not self._local:
            return "inject"
        if self._prefill_mode == "whole":
            return "whole" if self._can_prefill_whole(plen) else "inject"
        # "auto"/"chunked": chunked wherever the architecture allows
        if self.cfg.arch_type in ("ssm", "hybrid"):
            c = self.prefill_chunk
            # decode_chunk has no per-token SSD masking: every dispatched
            # chunk must be full and SSD-chunk-aligned
            if c % self.cfg.ssm.chunk == 0 and plen % c == 0:
                return "chunked"
            if not self.paged and self._can_prefill_whole(plen):
                return "whole"
            return "inject"
        return "chunked"

    def _build_step(self, sample: bool):
        decode, eos, S = self._decode, self.eos_id, self.max_seq

        def step(params, st):
            B = st["cur"].shape[0]
            active, pos = st["active"], st["pos"]
            logits, new_cache = decode(params, {"token": st["cur"][:, None]},
                                       st["cache"], pos)

            # cache retention: fixed lanes revert inactive slots' writes
            # (leaves are (layers, B, ...)); the paged pool and tables pass
            # through - released rows already dropped their scatters, and a
            # finished-but-unharvested row's rewrite is idempotent (same
            # frozen inputs -> same bytes into its own pages)
            cache = {}
            for name, new in new_cache.items():
                if name in _PAGED_LEAVES:
                    cache[name] = new
                else:
                    a = active.reshape((1, B) + (1,) * (new.ndim - 2))
                    cache[name] = jnp.where(a, new, st["cache"][name])

            # sampling lives INSIDE the compiled step: greedy argmax plus
            # (when any admitted request is hot) per-slot temperature/
            # categorical on per-slot PRNG streams
            logits = logits.astype(jnp.float32)
            greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            if sample:
                keys = jax.vmap(jax.random.split)(st["rng"])  # (B, 2, 2)
                hot = st["temp"] > 0.0
                scaled = logits / jnp.maximum(st["temp"], 1e-6)[:, None]
                sampled = jax.vmap(jax.random.categorical)(
                    keys[:, 1], scaled).astype(jnp.int32)
                tok = jnp.where(hot, sampled, greedy)
                rng = jnp.where(hot[:, None], keys[:, 0], st["rng"])
            else:
                tok, rng = greedy, st["rng"]

            nxt = pos + 1
            in_prompt = nxt < st["plen"]
            prompt_next = jnp.take_along_axis(
                st["prompt"], jnp.clip(nxt, 0, S - 1)[:, None], axis=1)[:, 0]
            emit = active & ~in_prompt                 # tok was generated
            rows = jnp.arange(B)
            gidx = jnp.clip(st["gen"], 0, S - 1)
            out = st["out"].at[rows, gidx].set(
                jnp.where(emit, tok, st["out"][rows, gidx]))
            gen = st["gen"] + emit.astype(jnp.int32)
            done = emit & (gen >= st["max_new"])
            if eos is not None:
                done |= emit & (tok == jnp.int32(eos))
            done |= active & (nxt >= S)                # cache full
            alive = active & ~done
            cur = jnp.where(in_prompt, prompt_next, tok)
            cur = jnp.where(alive, cur, st["cur"])
            pos = jnp.where(alive, jnp.minimum(nxt, S - 1), pos)
            return dict(cache=cache, cur=cur, pos=pos, plen=st["plen"],
                        gen=gen, max_new=st["max_new"], active=alive,
                        temp=st["temp"], rng=rng, prompt=st["prompt"],
                        out=out)
        return step

    # ------------------------------------------------------------------
    # scheduler API
    # ------------------------------------------------------------------

    @property
    def free_slots(self) -> int:
        return sum(h is None for h in self._slot_handle)

    @property
    def inflight(self) -> int:
        return sum(h is not None for h in self._slot_handle)

    @property
    def queued(self) -> int:
        return len(self._pending)

    @property
    def free_pages(self) -> int:
        return self._pool.free_pages if self.paged else 0

    def _request_pages(self, req: Request) -> int:
        # cache rows actually written: prompt + all generated tokens but
        # the last (which is emitted, never fed back)
        return self._pool.pages_for(len(req.prompt) + req.max_new_tokens - 1)

    def submit(self, req: Request) -> int:
        """Queue a request; returns its handle. Claims a free slot
        immediately when one is available (preempting a lower SLO class
        under slot/page pressure)."""
        plen = len(req.prompt)
        if plen < 1:
            raise ValueError("empty prompt")
        if req.slo not in SLO_PRIORITY:
            raise ValueError(f"unknown SLO class {req.slo!r}; expected one "
                             f"of {sorted(SLO_PRIORITY)}")
        if plen + req.max_new_tokens - 1 > self.max_seq:
            raise ValueError(
                f"prompt_len={plen} + max_new={req.max_new_tokens} - 1 "
                f"exceeds max_seq={self.max_seq}")
        if self.paged and self._request_pages(req) > self.num_pages:
            raise ValueError(
                f"request needs {self._request_pages(req)} pages; the pool "
                f"holds {self.num_pages}")
        h = self._next_handle
        self._next_handle += 1
        self._requests[h] = req
        # fold on the submission ordinal since the last (re)seed: identical
        # (requests, key) sequences after a reseed() draw identical
        # sampling streams, and the key survives preemption-requeue so a
        # resumed request replays its exact draws
        self._req_key[h] = jax.random.fold_in(self._base_key,
                                              self._admit_seq)
        self._admit_seq += 1
        self._submit_t[h] = time.perf_counter()
        self._enqueue(h)
        self._schedule()
        return h

    def _enqueue(self, h: int):
        """Insert into the pending queue ordered by (SLO class desc,
        arrival asc) - handles are arrival-ordered, so a preempted request
        resumes ahead of later arrivals in its class."""
        pr = SLO_PRIORITY[self._requests[h].slo]
        keyf = lambda hh: (-SLO_PRIORITY[self._requests[hh].slo], hh)
        lo = 0
        me = (-pr, h)
        while lo < len(self._pending) and keyf(self._pending[lo]) < me:
            lo += 1
        self._pending.insert(lo, h)

    def _schedule(self, allow_harvest: bool = True):
        """Admit from the head of the priority queue while resources
        allow. Under pressure, first collect any already-finished slots
        (so a completed request is never "preempted"), then preempt
        strictly-lower-SLO occupants."""
        while self._pending:
            h = self._pending[0]
            req = self._requests[h]
            if self._try_admit(h, req):
                self._pending.pop(0)
                continue
            if allow_harvest and self.inflight:
                allow_harvest = False
                if self._collect_finished():
                    continue
            if not self._try_preempt_for(req):
                break

    def _try_admit(self, handle: int, req: Request) -> bool:
        free = [s for s, owner in enumerate(self._slot_handle)
                if owner is None]
        if not free:
            return False
        pages = None
        if self.paged:
            pages = self._pool.alloc(self._request_pages(req))
            if pages is None:
                return False
        self._admit(free[0], handle, req, pages)
        return True

    def _try_preempt_for(self, req: Request) -> bool:
        """Reclaim slot+pages from the lowest-SLO, most-recently-admitted
        occupant strictly below ``req``'s class. Returns False (nothing
        touched) when no such victim exists or even evicting all of them
        could not seat the request."""
        pr = SLO_PRIORITY[req.slo]
        victims = [(SLO_PRIORITY[self._requests[h].slo], -h, s)
                   for s, h in enumerate(self._slot_handle)
                   if h is not None and h in self._requests
                   and SLO_PRIORITY[self._requests[h].slo] < pr]
        if self.preempt_mode == "kill":
            # killed handles leave self._requests; look them up anyway
            victims = [(SLO_PRIORITY[self._requests[h].slo], -h, s)
                       for s, h in enumerate(self._slot_handle)
                       if h is not None
                       and SLO_PRIORITY[self._requests[h].slo] < pr]
        if not victims:
            return False
        if self.paged:
            reclaim = sum(len(self._slot_pages[s] or ())
                          for _, _, s in victims)
            if self._pool.free_pages + reclaim < self._request_pages(req):
                return False
        victims.sort()
        self._preempt(victims[0][2])
        return True

    def _preempt(self, slot: int):
        h = self._slot_handle[slot]
        self.stats["preemptions"] += 1
        mid_prefill = slot in self._prefill_q
        if self.preempt_mode == "kill":
            if mid_prefill:
                req = self._requests.pop(h)
                self._results[h] = Result(tokens=[],
                                          prompt_len=len(req.prompt),
                                          handle=h,
                                          finish_reason="preempted")
            else:
                snap = self._sync()
                n = int(snap["gen"][slot])
                req = self._requests.pop(h)
                self._results[h] = Result(
                    tokens=[int(t) for t in snap["out"][slot, :n]],
                    prompt_len=len(req.prompt), handle=h,
                    finish_reason="preempted")
            self._req_key.pop(h, None)
        else:
            # requeue-and-recompute: the request (and its sampling key)
            # goes back to the head of its SLO class
            self._enqueue(h)
        self._free_slot(slot, release=True)

    def _free_slot(self, slot: int, release: bool):
        h = self._slot_handle[slot]
        self._slot_handle[slot] = None
        self._slot_done_step[slot] = 0
        self._prefill_q.pop(slot, None)
        self._hot.discard(h)
        if self.paged and self._slot_pages[slot] is not None:
            self._pool.free(self._slot_pages[slot])
            self._slot_pages[slot] = None
        if release:
            self._state = self._release_fn(self._state, slot)

    def _admit(self, slot: int, handle: int, req: Request,
               pages: Optional[List[int]]):
        plen = len(req.prompt)
        key = self._req_key[handle]
        if self.paged:
            npag = self.max_seq // self.page_size
            row = np.full((npag,), self.num_pages, np.int32)
            row[:len(pages)] = pages
            ptab_row = jnp.asarray(row)
            self._slot_pages[slot] = pages
        else:
            ptab_row = jnp.zeros((1,), jnp.int32)  # unused placeholder
        self._slot_handle[slot] = handle
        mode = self._admission_mode(plen)
        if mode == "whole":
            fn = self._prefill_fns.get(plen)
            if fn is None:
                fn = jax.jit(self._build_prefill(plen), donate_argnums=(1,))
                self._prefill_fns[plen] = fn
            self._state = fn(
                self.params, self._state, jnp.int32(slot),
                jnp.asarray(np.asarray(req.prompt, np.int32)),
                jnp.int32(req.max_new_tokens),
                jnp.float32(req.temperature), key)
            self._finalize_admission(slot, handle, req,
                                     remaining=req.max_new_tokens - 1)
        elif mode == "chunked":
            self._state = self._stage_fn(self._state, jnp.int32(slot),
                                         ptab_row)
            self._prefill_q[slot] = dict(
                handle=handle, tokens=np.asarray(req.prompt, np.int32),
                next=0, plen=plen, max_new=req.max_new_tokens,
                temp=req.temperature, key=key)
            nchunks = -(-plen // self.prefill_chunk)
            # provisional bound until the final chunk lands
            self._slot_done_step[slot] = (self._steps + nchunks
                                          + req.max_new_tokens)
            self._advance_prefill()    # first chunk goes out immediately
        else:
            prompt = np.zeros((self.max_seq,), np.int32)
            prompt[:plen] = np.asarray(req.prompt, np.int32)
            self._state = self._admit_fn(
                self._state, jnp.int32(slot), jnp.asarray(prompt),
                jnp.int32(plen), jnp.int32(req.max_new_tokens),
                jnp.float32(req.temperature), key, ptab_row)
            self._finalize_admission(slot, handle, req,
                                     remaining=plen + req.max_new_tokens - 1)
        self.stats["admitted"] += 1
        self.stats["max_inflight"] = max(self.stats["max_inflight"],
                                         self.inflight)

    def _finalize_admission(self, slot: int, handle: int, req: Request,
                            remaining: int):
        self._slot_done_step[slot] = self._steps + remaining
        if req.temperature > 0:
            self._hot.add(handle)
        if handle not in self.ttft_s and handle in self._submit_t:
            self.ttft_s[handle] = (time.perf_counter()
                                   - self._submit_t[handle])

    def _chunk_fn(self, is_last: bool) -> Callable:
        fn = self._chunk_fns.get(is_last)
        if fn is None:
            fn = jax.jit(self._build_chunk(is_last), donate_argnums=(1,))
            self._chunk_fns[is_last] = fn
        return fn

    def _advance_prefill(self):
        """Dispatch ONE prompt chunk for the oldest mid-prefill slot.
        ``step()`` calls this before every decode dispatch, so long
        prompts stream in without ever stalling the decode batch."""
        if not self._prefill_q:
            return
        slot, pp = next(iter(self._prefill_q.items()))
        c = self.prefill_chunk
        lo = pp["next"]
        hi = min(lo + c, pp["plen"])
        tok = np.zeros((c,), np.int32)
        tok[:hi - lo] = pp["tokens"][lo:hi]
        is_last = hi >= pp["plen"]
        fn = self._chunk_fn(is_last)
        self._state = fn(self.params, self._state, jnp.int32(slot),
                         jnp.asarray(tok), jnp.int32(lo),
                         jnp.int32(hi - lo), jnp.int32(pp["max_new"]),
                         jnp.float32(pp["temp"]), pp["key"])
        pp["next"] = hi
        self.stats["chunk_dispatches"] += 1
        if is_last:
            del self._prefill_q[slot]
            h = pp["handle"]
            self._finalize_admission(
                slot, h, self._requests[h],
                remaining=max(0, pp["max_new"] - 1))

    def _step_callable(self, sample: bool) -> Callable:
        """The ready-to-dispatch decode step: first use per variant loads
        the AOT artifact (or compiles and exports one) - restarts with a
        warm ``aot_dir`` never trace or compile the decode step."""
        fn = self._step_ready.get(sample)
        if fn is None:
            jitted = self._step_sample if sample else self._step_greedy
            facts = {"program": "serve_decode", "model_cfg": self.cfg,
                     "slots": self.slots, "max_seq": self.max_seq,
                     "eos": self.eos_id, "sample": sample,
                     "quantized": is_quantized(self.params),
                     "fused_matmul": self.fused_matmul,
                     "paged": self.paged, "page_size": self.page_size,
                     "num_pages": self.num_pages,
                     "prefill": self._prefill_mode,
                     "prefill_chunk": self.prefill_chunk}
            fn = aot.load_or_compile(jitted, (self.params, self._state),
                                     aot_dir=self._aot_dir, facts=facts,
                                     stats=self.stats)
            self._step_ready[sample] = fn
        return fn

    def step(self):
        """One decode step for every slot (a single device dispatch),
        preceded by at most one chunked-prefill dispatch. While the
        pending queue is non-empty, finished slots are harvested as soon
        as one *can* have finished (plus every ``sync_interval`` steps
        when an EOS may end a request early), so queued requests claim
        slots mid-flight without a per-token host sync."""
        self._advance_prefill()
        fn = self._step_callable(bool(self._hot))
        self._state = fn(self.params, self._state)
        self.stats["dispatches"] += 1
        self._steps += 1
        if self._pending:
            bound = min((self._slot_done_step[s]
                         for s, h in enumerate(self._slot_handle)
                         if h is not None), default=0)
            if self._steps >= bound or (
                    self.eos_id is not None
                    and self._steps % self.sync_interval == 0):
                self.harvest()

    def _sync(self):
        self.stats["syncs"] += 1
        keys = ("active", "gen", "plen", "out")
        return jax.device_get({k: self._state[k] for k in keys})

    def harvest(self) -> List[int]:
        """Collect finished slots into results, free them (returning their
        pages to the pool), and admit queued requests. Returns the handles
        that completed on this call."""
        finished = self._collect_finished()
        self._schedule(allow_harvest=False)
        return finished

    def _collect_finished(self) -> List[int]:
        snap = self._sync()
        finished = []
        for s in range(self.slots):
            h = self._slot_handle[s]
            if h is None or snap["active"][s] or s in self._prefill_q:
                continue
            n = int(snap["gen"][s])
            req = self._requests.pop(h)   # bounded host state: one entry
            reason = "length"             # per in-flight request only
            if n < req.max_new_tokens:
                reason = ("eos" if self.eos_id is not None
                          and n > 0 and int(snap["out"][s, n - 1]) == self.eos_id
                          else "cache_full")
            self._results[h] = Result(
                tokens=[int(t) for t in snap["out"][s, :n]],
                prompt_len=int(snap["plen"][s]), handle=h,
                finish_reason=reason)
            self._req_key.pop(h, None)
            self._free_slot(s, release=self.paged)
            finished.append(h)
        return finished

    def drain(self, max_steps: Optional[int] = None) -> Dict[int, Result]:
        """Step until every submitted request has finished; returns the
        results not yet delivered as ``{handle: Result}``. Results are
        handed out once (here or via ``result()``) - the session holds no
        per-request state afterwards, so long-running sessions stay
        bounded."""
        outstanding = self.inflight + self.queued
        budget = (max_steps if max_steps is not None
                  else (outstanding + self.slots) * 2 * self.max_seq
                  + self.max_seq)
        while self.inflight or self._pending:
            if budget <= 0:
                raise RuntimeError("drain exceeded its step budget")
            if self._prefill_q:
                # one chunk advances per step: burst exactly through the
                # outstanding chunks, then recompute bounds
                burst = sum(-(-(pp["plen"] - pp["next"])
                              // self.prefill_chunk) or 1
                            for pp in self._prefill_q.values())
            elif self._pending:
                # step() harvests on its own bound-aware cadence
                burst = 8
            elif self.eos_id is not None:
                burst = self.sync_interval  # poll for early finishes
            else:
                # no EOS: slots finish exactly at their known bound - step
                # straight there and harvest once (O(requests) syncs)
                nxt = min(self._slot_done_step[s]
                          for s, h in enumerate(self._slot_handle)
                          if h is not None)
                burst = max(1, nxt - self._steps)
            burst = min(burst, budget)
            for _ in range(burst):
                self.step()
            budget -= burst
            if not self._pending:
                self.harvest()
        out, self._results = self._results, {}
        return out

    def reseed(self, key: jax.Array):
        """Set the base sampling key for subsequently admitted requests
        (restarting the per-submission key sequence, so the same requests
        under the same key reproduce their draws)."""
        self._base_key = _raw_key(key)
        self._admit_seq = 0

    def result(self, handle: int) -> Optional[Result]:
        """Pop a finished request's result (None while still running)."""
        return self._results.pop(handle, None)
