"""Continuous-batching serve sessions over a fixed pool of decode slots.

``ServeSession`` replaces the old batch-synchronous ``Engine.generate``:

  * ``submit(Request) -> handle`` claims a free slot (or queues); new
    requests join mid-flight as others finish - the batch never drains to
    restart.
  * ``step()`` runs ONE jitted decode step over all slots: token embedding,
    attention against each slot's own cache prefix (per-slot positions -
    slot i attends exactly its ``pos_i`` written entries, never padding or
    a previous occupant's rows), and sampling (greedy + per-slot
    temperature via a temperature vector and per-slot PRNG keys) all inside
    the compiled step. The host dispatches and moves on: zero per-token
    device->host transfers.
  * ``drain()`` runs until every submitted request finished and returns
    ``{handle: Result}``.

Decode state keeps a fixed shape - (slots,) control vectors + a
(layers, slots, max_seq, ...) cache - so exactly one compiled decode step
is reused for the whole session, with the state buffers donated through
it. Admission runs one batched prefill over the prompt and scatters the
KV/SSM cache into the claimed slot lane (compiled once per distinct
prompt length, like the old engine's per-shape prefill); where prefill
can't apply (mesh ``decode_fn`` sessions, SSD chunk-misaligned prompts)
the prompt is injected through the decode step one token per dispatch.

The decode callable is pluggable: the default wraps
``model.decode_step`` locally (dequantizing ``QuantizedParams`` per layer
at use); pass ``decode_fn=`` from ``repro.dist.serve.make_serve_step`` to
run the same session over a mesh - single-device and sharded serving are
one API.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import ShardCtx
from repro.perf import aot
from repro.perf import cache as perf_cache
from repro.serve.quantized import is_quantized, make_dequant_gather


def _raw_key(key: jax.Array) -> jax.Array:
    """Normalize legacy (2,) uint32 / new-style typed PRNG keys to the raw
    uint32 data the per-slot key buffer stores."""
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        key = jax.random.key_data(key)
    key = jnp.asarray(key, jnp.uint32)
    if key.shape != (2,):
        raise ValueError("ServeSession needs a threefry PRNG key "
                         f"(2 uint32 words); got key data {key.shape}")
    return key


@dataclasses.dataclass
class Request:
    prompt: Sequence[int]
    max_new_tokens: int = 16
    temperature: float = 0.0


@dataclasses.dataclass
class Result:
    tokens: List[int]
    prompt_len: int
    handle: int = -1
    finish_reason: str = "length"       # "length" | "eos" | "cache_full"


class ServeSession:
    """Slot-scheduled continuous-batching session.

    model: repro.models.model.Model (token-input decoder LM).
    params: the model's parameter tree; may contain ``QuantizedLeaf``
        leaves from ``quantize_params`` (local decode path only).
    slots: number of concurrent decode lanes (the fixed batch width).
    max_seq: per-slot cache length; a request needs
        ``len(prompt) + max_new_tokens - 1 <= max_seq``.
    eos_id: optional token id that finishes a request early.
    decode_fn: optional ``(params, inputs, cache, pos) -> (logits, cache)``
        override, e.g. from ``dist.serve.make_serve_step(..., "decode")``.
    sync_interval: while requests are queued AND a slot may have finished
        early (EOS configured), harvest every N steps. Without an EOS the
        scheduler knows each slot's earliest possible finish step
        host-side and harvests only then - O(requests) syncs, never
        O(tokens); with an empty queue the steady-state loop never syncs.
    aot_dir: AOT artifact directory (``repro.perf.aot``) for the compiled
        decode step, keyed on (model config digest, slots, max_seq,
        sample mode, quantization, arg signature). A warm dir makes the
        first dispatch skip trace+lower+compile; local decode path only
        (a mesh ``decode_fn`` closure can't be digested, so it falls back
        to plain jit). ``stats`` records ``compilations`` vs
        ``aot_loads``.
    """

    def __init__(self, model, params, *, slots: int = 8, max_seq: int = 256,
                 eos_id: Optional[int] = None,
                 decode_fn: Optional[Callable] = None,
                 base_key: Optional[jax.Array] = None, seed: int = 0,
                 sync_interval: int = 8, aot_dir: Optional[str] = None,
                 fused_matmul: bool = True):
        cfg = model.cfg
        if cfg.input_mode != "tokens" or cfg.arch_type == "encdec":
            raise ValueError("ServeSession serves token-input decoder LMs")
        self.model, self.cfg = model, cfg
        self.slots, self.max_seq, self.eos_id = slots, max_seq, eos_id
        self.sync_interval = max(1, sync_interval)
        self.params = params
        self._local = decode_fn is None
        # fused_matmul: quantized projections contract straight from codes
        # (repro.comm.matmul); False restores dequantize-then-matmul.
        # Bitwise-identical tokens either way - this is a perf knob.
        self.fused_matmul = bool(fused_matmul) and is_quantized(params)
        self._ctx = (ShardCtx(param_gather=make_dequant_gather(
                         fused=fused_matmul))
                     if is_quantized(params) else ShardCtx())
        if decode_fn is None:
            ctx = self._ctx
            decode_fn = lambda p, i, c, pos: model.decode_step(p, i, c, pos,
                                                               ctx)
        elif is_quantized(params):
            raise ValueError("QuantizedParams require the local decode path;"
                             " a mesh decode_fn brings its own weight wire")
        self._decode = decode_fn
        self._prefill_fns: Dict[int, Callable] = {}  # keyed by prompt len
        # two step variants: sessions whose admitted requests are all
        # greedy never pay (or compile) the categorical sampling pass
        self._step_greedy = jax.jit(self._build_step(sample=False),
                                    donate_argnums=(1,))
        self._step_sample = jax.jit(self._build_step(sample=True),
                                    donate_argnums=(1,))
        self._admit_fn = jax.jit(self._build_admit(), donate_argnums=(0,))
        self._aot_dir = aot_dir if self._local else None
        self._step_ready: Dict[bool, Callable] = {}  # sample -> executable
        perf_cache.ensure_persistent_cache()  # opt-in via env, see cache.py
        self._state = self._init_state()
        self._base_key = _raw_key(base_key if base_key is not None
                                  else jax.random.PRNGKey(seed))
        self._hot: set = set()          # handles in slots with temp > 0
        self._slot_handle: List[Optional[int]] = [None] * slots
        self._slot_done_step = [0] * slots   # earliest possible finish
        self._pending = collections.deque()
        self._requests: Dict[int, Request] = {}
        self._results: Dict[int, Result] = {}
        self._next_handle = 0
        self._admit_seq = 0             # admissions since the last reseed
        self._steps = 0
        self.stats = {"dispatches": 0, "syncs": 0, "admitted": 0,
                      "compilations": 0, "aot_loads": 0}

    # ------------------------------------------------------------------
    # device-side state + compiled programs
    # ------------------------------------------------------------------

    def _init_state(self):
        B, S = self.slots, self.max_seq
        cache = self.model.init_cache(B, max_seq_local=S)
        z = lambda dt: jnp.zeros((B,), dt)
        return dict(cache=cache, cur=z(jnp.int32), pos=z(jnp.int32),
                    plen=z(jnp.int32), gen=z(jnp.int32),
                    max_new=z(jnp.int32), active=z(bool),
                    temp=z(jnp.float32),
                    rng=jnp.zeros((B, 2), jnp.uint32),
                    prompt=jnp.zeros((B, S), jnp.int32),
                    out=jnp.zeros((B, S), jnp.int32))

    def _build_admit(self):
        def admit(st, slot, prompt, plen, max_new, temp, key):
            st = dict(st)
            st["prompt"] = st["prompt"].at[slot].set(prompt)
            st["cur"] = st["cur"].at[slot].set(prompt[0])
            st["pos"] = st["pos"].at[slot].set(0)
            st["plen"] = st["plen"].at[slot].set(plen)
            st["gen"] = st["gen"].at[slot].set(0)
            st["max_new"] = st["max_new"].at[slot].set(max_new)
            st["active"] = st["active"].at[slot].set(True)
            st["temp"] = st["temp"].at[slot].set(temp)
            st["rng"] = st["rng"].at[slot].set(key)
            # Per-slot positions already mask attention to the new
            # occupant's own written prefix, but recurrent state (SSM,
            # conv tail) accumulates - zero the slot's cache lane.
            st["cache"] = jax.tree.map(lambda c: c.at[:, slot].set(0),
                                       st["cache"])
            return st
        return admit

    def _build_prefill(self, plen: int):
        """Admission via one batched prefill over the whole prompt: fills
        the slot's cache lane and emits the first generated token, so the
        decode loop starts at the generation boundary (len(prompt) fewer
        dispatches per request than token injection)."""
        model, S, eos, ctx = self.model, self.max_seq, self.eos_id, self._ctx

        def prefill(params, st, slot, prompt, max_new, temp, key):
            batch = {"tokens": prompt[None], "targets": prompt[None],
                     "mask": jnp.ones((1, plen), jnp.float32)}
            logits, lane = model.prefill(params, batch, max_seq_local=S,
                                         ctx=ctx)
            lg = logits[0, plen - 1].astype(jnp.float32)
            greedy = jnp.argmax(lg).astype(jnp.int32)
            k_next, k_draw = jax.random.split(key)
            sampled = jax.random.categorical(
                k_draw, lg / jnp.maximum(temp, 1e-6)).astype(jnp.int32)
            hot = temp > 0.0
            t0 = jnp.where(hot, sampled, greedy)
            st = dict(st)
            st["cache"] = {
                k: st["cache"][k].at[:, slot].set(
                    lane[k][:, 0].astype(st["cache"][k].dtype))
                for k in st["cache"]}
            st["prompt"] = st["prompt"].at[slot].set(
                jnp.zeros((S,), jnp.int32).at[:plen].set(prompt))
            st["cur"] = st["cur"].at[slot].set(t0)
            st["pos"] = st["pos"].at[slot].set(plen)
            st["plen"] = st["plen"].at[slot].set(plen)
            st["gen"] = st["gen"].at[slot].set(1)
            st["out"] = st["out"].at[slot, 0].set(t0)
            st["max_new"] = st["max_new"].at[slot].set(max_new)
            done = max_new <= 1
            if eos is not None:
                done |= t0 == jnp.int32(eos)
            st["active"] = st["active"].at[slot].set(~done)
            st["temp"] = st["temp"].at[slot].set(temp)
            st["rng"] = st["rng"].at[slot].set(
                jnp.where(hot, k_next, key))
            return st
        return prefill

    def _can_prefill(self, plen: int) -> bool:
        if not self._local or plen < 2:
            return False
        if self.cfg.arch_type in ("ssm", "hybrid"):
            # the SSD chunked scan needs the sequence to tile its chunk
            return plen % self.cfg.ssm.chunk == 0
        return True

    def _build_step(self, sample: bool):
        decode, eos, S = self._decode, self.eos_id, self.max_seq

        def step(params, st):
            B = st["cur"].shape[0]
            active, pos = st["active"], st["pos"]
            logits, new_cache = decode(params, {"token": st["cur"][:, None]},
                                       st["cache"], pos)

            def keep(new, old):  # cache leaves are (layers, B, ...)
                a = active.reshape((1, B) + (1,) * (new.ndim - 2))
                return jnp.where(a, new, old)

            cache = jax.tree.map(keep, new_cache, st["cache"])

            # sampling lives INSIDE the compiled step: greedy argmax plus
            # (when any admitted request is hot) per-slot temperature/
            # categorical on per-slot PRNG streams
            logits = logits.astype(jnp.float32)
            greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            if sample:
                keys = jax.vmap(jax.random.split)(st["rng"])  # (B, 2, 2)
                hot = st["temp"] > 0.0
                scaled = logits / jnp.maximum(st["temp"], 1e-6)[:, None]
                sampled = jax.vmap(jax.random.categorical)(
                    keys[:, 1], scaled).astype(jnp.int32)
                tok = jnp.where(hot, sampled, greedy)
                rng = jnp.where(hot[:, None], keys[:, 0], st["rng"])
            else:
                tok, rng = greedy, st["rng"]

            nxt = pos + 1
            in_prompt = nxt < st["plen"]
            prompt_next = jnp.take_along_axis(
                st["prompt"], jnp.clip(nxt, 0, S - 1)[:, None], axis=1)[:, 0]
            emit = active & ~in_prompt                 # tok was generated
            rows = jnp.arange(B)
            gidx = jnp.clip(st["gen"], 0, S - 1)
            out = st["out"].at[rows, gidx].set(
                jnp.where(emit, tok, st["out"][rows, gidx]))
            gen = st["gen"] + emit.astype(jnp.int32)
            done = emit & (gen >= st["max_new"])
            if eos is not None:
                done |= emit & (tok == jnp.int32(eos))
            done |= active & (nxt >= S)                # cache full
            alive = active & ~done
            cur = jnp.where(in_prompt, prompt_next, tok)
            cur = jnp.where(alive, cur, st["cur"])
            pos = jnp.where(alive, jnp.minimum(nxt, S - 1), pos)
            return dict(cache=cache, cur=cur, pos=pos, plen=st["plen"],
                        gen=gen, max_new=st["max_new"], active=alive,
                        temp=st["temp"], rng=rng, prompt=st["prompt"],
                        out=out)
        return step

    # ------------------------------------------------------------------
    # scheduler API
    # ------------------------------------------------------------------

    @property
    def free_slots(self) -> int:
        return sum(h is None for h in self._slot_handle)

    @property
    def inflight(self) -> int:
        return sum(h is not None for h in self._slot_handle)

    @property
    def queued(self) -> int:
        return len(self._pending)

    def submit(self, req: Request) -> int:
        """Queue a request; returns its handle. Claims a free slot
        immediately when one is available."""
        plen = len(req.prompt)
        if plen < 1:
            raise ValueError("empty prompt")
        if plen + req.max_new_tokens - 1 > self.max_seq:
            raise ValueError(
                f"prompt_len={plen} + max_new={req.max_new_tokens} - 1 "
                f"exceeds max_seq={self.max_seq}")
        h = self._next_handle
        self._next_handle += 1
        self._requests[h] = req
        free = [s for s, owner in enumerate(self._slot_handle)
                if owner is None]
        if free:
            self._admit(free[0], h, req)
        else:
            self._pending.append(h)
        return h

    def _admit(self, slot: int, handle: int, req: Request):
        plen = len(req.prompt)
        # fold on the admission ordinal since the last (re)seed, not the
        # lifetime handle: identical (requests, key) sequences after a
        # reseed() draw identical sampling streams
        key = jax.random.fold_in(self._base_key, self._admit_seq)
        self._admit_seq += 1
        if self._can_prefill(plen):
            fn = self._prefill_fns.get(plen)
            if fn is None:
                fn = jax.jit(self._build_prefill(plen), donate_argnums=(1,))
                self._prefill_fns[plen] = fn
            self._state = fn(
                self.params, self._state, jnp.int32(slot),
                jnp.asarray(np.asarray(req.prompt, np.int32)),
                jnp.int32(req.max_new_tokens),
                jnp.float32(req.temperature), key)
            remaining = req.max_new_tokens - 1  # first token emitted here
        else:
            prompt = np.zeros((self.max_seq,), np.int32)
            prompt[:plen] = np.asarray(req.prompt, np.int32)
            self._state = self._admit_fn(
                self._state, jnp.int32(slot), jnp.asarray(prompt),
                jnp.int32(plen), jnp.int32(req.max_new_tokens),
                jnp.float32(req.temperature), key)
            remaining = plen + req.max_new_tokens - 1
        self._slot_handle[slot] = handle
        self._slot_done_step[slot] = self._steps + remaining
        if req.temperature > 0:
            self._hot.add(handle)
        self.stats["admitted"] += 1

    def _step_callable(self, sample: bool) -> Callable:
        """The ready-to-dispatch decode step: first use per variant loads
        the AOT artifact (or compiles and exports one) - restarts with a
        warm ``aot_dir`` never trace or compile the decode step."""
        fn = self._step_ready.get(sample)
        if fn is None:
            jitted = self._step_sample if sample else self._step_greedy
            facts = {"program": "serve_decode", "model_cfg": self.cfg,
                     "slots": self.slots, "max_seq": self.max_seq,
                     "eos": self.eos_id, "sample": sample,
                     "quantized": is_quantized(self.params),
                     "fused_matmul": self.fused_matmul}
            fn = aot.load_or_compile(jitted, (self.params, self._state),
                                     aot_dir=self._aot_dir, facts=facts,
                                     stats=self.stats)
            self._step_ready[sample] = fn
        return fn

    def step(self):
        """One decode step for every slot (a single device dispatch). While
        the pending queue is non-empty, finished slots are harvested as
        soon as one *can* have finished (plus every ``sync_interval`` steps
        when an EOS may end a request early), so queued requests claim
        slots mid-flight without a per-token host sync."""
        fn = self._step_callable(bool(self._hot))
        self._state = fn(self.params, self._state)
        self.stats["dispatches"] += 1
        self._steps += 1
        if self._pending:
            bound = min((self._slot_done_step[s]
                         for s, h in enumerate(self._slot_handle)
                         if h is not None), default=0)
            if self._steps >= bound or (
                    self.eos_id is not None
                    and self._steps % self.sync_interval == 0):
                self.harvest()

    def _sync(self):
        self.stats["syncs"] += 1
        keys = ("active", "gen", "plen", "out")
        return jax.device_get({k: self._state[k] for k in keys})

    def harvest(self) -> List[int]:
        """Collect finished slots into results, free them, and admit queued
        requests. Returns the handles that completed on this call."""
        snap = self._sync()
        finished = []
        for s in range(self.slots):
            h = self._slot_handle[s]
            if h is None or snap["active"][s]:
                continue
            n = int(snap["gen"][s])
            req = self._requests.pop(h)   # bounded host state: one entry
            reason = "length"             # per in-flight request only
            if n < req.max_new_tokens:
                reason = ("eos" if self.eos_id is not None
                          and n > 0 and int(snap["out"][s, n - 1]) == self.eos_id
                          else "cache_full")
            self._results[h] = Result(
                tokens=[int(t) for t in snap["out"][s, :n]],
                prompt_len=int(snap["plen"][s]), handle=h,
                finish_reason=reason)
            self._slot_handle[s] = None
            self._hot.discard(h)
            finished.append(h)
        while self._pending and self.free_slots:
            h = self._pending.popleft()
            slot = self._slot_handle.index(None)
            self._admit(slot, h, self._requests[h])
        return finished

    def drain(self, max_steps: Optional[int] = None) -> Dict[int, Result]:
        """Step until every submitted request has finished; returns the
        results not yet delivered as ``{handle: Result}``. Results are
        handed out once (here or via ``result()``) - the session holds no
        per-request state afterwards, so long-running sessions stay
        bounded."""
        outstanding = self.inflight + self.queued
        budget = (max_steps if max_steps is not None
                  else (outstanding + self.slots) * self.max_seq + self.max_seq)
        while self.inflight or self._pending:
            if budget <= 0:
                raise RuntimeError("drain exceeded its step budget")
            if self._pending:
                # step() harvests on its own bound-aware cadence
                burst = 8
            elif self.eos_id is not None:
                burst = self.sync_interval  # poll for early finishes
            else:
                # no EOS: slots finish exactly at their known bound - step
                # straight there and harvest once (O(requests) syncs)
                nxt = min(self._slot_done_step[s]
                          for s, h in enumerate(self._slot_handle)
                          if h is not None)
                burst = max(1, nxt - self._steps)
            burst = min(burst, budget)
            for _ in range(burst):
                self.step()
            budget -= burst
            if not self._pending:
                self.harvest()
        out, self._results = self._results, {}
        return out

    def reseed(self, key: jax.Array):
        """Set the base sampling key for subsequently admitted requests
        (restarting the per-admission key sequence, so the same requests
        under the same key reproduce their draws)."""
        self._base_key = _raw_key(key)
        self._admit_seq = 0

    def result(self, handle: int) -> Optional[Result]:
        """Pop a finished request's result (None while still running)."""
        return self._results.pop(handle, None)
