"""Paged KV cache: one physical page pool + per-slot page tables.

Fixed-lane serving reserves a whole ``(layers, slots, max_seq, ...)``
cache lane per slot, so memory - not compute - caps concurrency: a slot
holding an 8-token request pins the same bytes as one holding a
``max_seq``-token request. Here the cache is a single physical pool of
``num_pages`` pages of ``page_size`` tokens each, and every slot owns
only the pages its tokens actually occupy: concurrency is bounded by
*tokens in flight*, not ``slots * max_seq``. This is the serving
analogue of the paper's bytes-for-throughput tradeoff - spend cache
bytes only on information that exists.

Layout (per layer, carried through the decode ``lax.scan``):

  * pool  ``pk``/``pv``: (num_pages, page_size, n_kv_heads, head_dim)
  * table ``ptab``: (slots, max_seq // page_size) int32 global page ids;
    ``num_pages`` (one past the last page) is the RELEASED sentinel - a
    freed slot's writes scatter out of bounds (dropped) and its view
    columns are masked invalid, so a recycled page can never be
    corrupted by its previous owner.

``gather_pages`` materializes a slot's contiguous cache view from its
table - the one new device primitive paging needs. It follows the
``repro.comm.matmul`` pattern exactly: a jnp gather reference that is
the bitwise oracle, a Pallas kernel (scalar-prefetched page table drives
the block index map, one page copy per grid step) for TPU, interpret
mode elsewhere, and an explicit ``backend=`` always wins. Decode then
runs the unchanged ``decode_attention`` math over the view, which is
how paged decode stays bitwise identical to fixed-lane decode: the view
equals the lane at every valid position and masking kills the rest.

``PagePool`` is the host-side allocator the scheduler drives: a free
list (LIFO, deterministic), ``alloc``/``free`` by page count, and exact
occupancy accounting for admission and preemption decisions. It holds
no device state - the device sees only ``ptab`` rows.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.comm import codec as C


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# gather: page pool + table -> contiguous per-slot view
# ---------------------------------------------------------------------------

def _gather_jnp(pool, ptab):
    """Reference: one gather over the page axis. (B, npag) indices into a
    (P, ps, K, hd) pool -> (B, npag*ps, K, hd) view."""
    B, npag = ptab.shape
    _, ps, K, hd = pool.shape
    view = jnp.take(pool, ptab, axis=0)          # (B, npag, ps, K, hd)
    return view.reshape(B, npag * ps, K, hd)


def _gather_body(tab_ref, pool_ref, o_ref):
    # the page id was already consumed by the index map; the body is a
    # straight VMEM copy of one page. pool block (1, ps, K, hd) lands in
    # out block (1, 1, ps, K, hd).
    del tab_ref
    o_ref[0] = pool_ref[...]


def _gather_pallas(pool, ptab, *, interpret):
    B, npag = ptab.shape
    P, ps, K, hd = pool.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, npag),
        in_specs=[pl.BlockSpec((1, ps, K, hd),
                               lambda b, j, tab: (tab[b, j], 0, 0, 0))],
        out_specs=pl.BlockSpec((1, 1, ps, K, hd),
                               lambda b, j, tab: (b, j, 0, 0, 0)),
    )
    out = pl.pallas_call(
        _gather_body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, npag, ps, K, hd), pool.dtype),
        interpret=interpret,
    )(ptab, pool)
    return out.reshape(B, npag * ps, K, hd)


def _pallas_covers(pool, ptab) -> bool:
    # one page per grid step: any in-range table works; degenerate pools
    # (empty page axis) fall back
    return pool.shape[0] > 0 and ptab.shape[1] > 0


def gather_pages(pool, ptab, *, backend: Optional[str] = None) -> jax.Array:
    """Contiguous cache view of each slot's pages.

    pool: (num_pages, page_size, K, hd) physical pages (one layer).
    ptab: (B, npag) int32 page ids; entries are clipped into the pool, so
        RELEASED-sentinel rows read *some* page - callers mask those view
        columns invalid (``decode_attention``'s ``extra_valid``), exactly
        like fixed-lane masking of positions beyond ``total_len``.

    Returns (B, npag * page_size, K, hd). Bitwise identical to the jnp
    gather on every backend (a gather moves bytes; there is nothing to
    round), asserted by ``tests/test_paged.py``.
    """
    ptab = jnp.clip(jnp.asarray(ptab, jnp.int32), 0, pool.shape[0] - 1)
    bk = C.resolve_backend(backend, pool.size, tile=pool.size // max(
        pool.shape[0], 1))
    if bk == "pallas" and _pallas_covers(pool, ptab):
        return _gather_pallas(pool, ptab, interpret=_interpret())
    return _gather_jnp(pool, ptab)


# ---------------------------------------------------------------------------
# host-side page allocator
# ---------------------------------------------------------------------------

def pages_for(ntokens: int, page_size: int) -> int:
    """Pages needed to hold ``ntokens`` cache rows."""
    return max(0, -(-int(ntokens) // int(page_size)))


class PagePool:
    """Free-list allocator over the physical page pool (host state only).

    LIFO free list: allocation order is deterministic for a given
    request schedule, and reuse cycles deliberately fragment the id
    space - the device never cares (the table indirection absorbs it),
    which ``tests/test_paged.py`` exercises directly.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 1 or page_size < 1:
            raise ValueError("PagePool needs num_pages >= 1, page_size >= 1")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self._free: List[int] = list(range(num_pages - 1, -1, -1))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    def pages_for(self, ntokens: int) -> int:
        return pages_for(ntokens, self.page_size)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` pages, or None (and no change) when the pool can't
        cover the request - the scheduler then queues or preempts."""
        if n > len(self._free):
            return None
        taken = [self._free.pop() for _ in range(n)]
        return taken

    def free(self, pages: List[int]) -> None:
        for p in pages:
            if not 0 <= p < self.num_pages:
                raise ValueError(f"freeing foreign page {p}")
        self._free.extend(pages)
        if len(self._free) > self.num_pages:
            raise RuntimeError("double free: free list exceeds the pool")

    def nbytes(self, n_layers: int, page_bytes: int) -> int:
        """Physical pool bytes (all layers) for sizing comparisons."""
        return n_layers * self.num_pages * page_bytes
