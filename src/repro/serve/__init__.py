"""Serving: continuous-batching sessions over code-resident quantized
weights (the paper's Q_x "Size" motivation, applied for real)."""
from repro.serve.engine import Engine
from repro.serve.quantized import (QuantizedLeaf, is_quantized,
                                   make_dequant_gather, params_nbytes,
                                   quantize_params)
from repro.serve.session import Request, Result, ServeSession

__all__ = ["Engine", "QuantizedLeaf", "Request", "Result", "ServeSession",
           "is_quantized", "make_dequant_gather", "params_nbytes",
           "quantize_params"]
