"""Serving: continuous-batching sessions over code-resident quantized
weights (the paper's Q_x "Size" motivation, applied for real), with a
paged KV cache bounding concurrency by tokens in flight."""
from repro.serve.engine import Engine
from repro.serve.paged import PagePool, gather_pages, pages_for
from repro.serve.quantized import (QuantizedLeaf, cache_nbytes,
                                   is_quantized, make_dequant_gather,
                                   params_nbytes, quantize_params)
from repro.serve.session import Request, Result, ServeSession

__all__ = ["Engine", "PagePool", "QuantizedLeaf", "Request", "Result",
           "ServeSession", "cache_nbytes", "gather_pages", "is_quantized",
           "make_dequant_gather", "pages_for", "params_nbytes",
           "quantize_params"]
