"""Canonical definition of the paper's quantizer grids + Adam+EF leaf math.

This module is *the* single source of truth for the update arithmetic
(Algorithm 1 lines 3-6) and the four grids (log Q_g, uniform Q_x,
TernGrad ternary, Zheng-et-al blockwise sign). Every other layer is a
view of these functions:

  * ``repro.opt.engine``   - backend dispatch (jnp vs Pallas) around them;
  * ``repro.kernels.*``    - Pallas kernel bodies *call* these functions on
    their VMEM-resident tiles, so kernels cannot drift from the oracle;
  * ``repro.core.quantizers`` - the QTensor wire objects encode/decode
    through them;
  * ``repro.dist.modes``   - the distributed per-mode updaters.

All functions are pure jnp, shape-polymorphic, and operate on explicit
scales (the two-pass scheme: pass 1 amax, pass 2 quantize). Stochastic
grids take pre-drawn uniforms so both backends consume identical bits.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def block_amax(x: jax.Array) -> jax.Array:
    """Per-call global amax (the scale pass)."""
    return jnp.max(jnp.abs(x.astype(jnp.float32)))


def amax_scale(x: jax.Array) -> jax.Array:
    """Amax scale with the zero-guard every channel must share: the
    bit-equivalence tests depend on the scales matching across layers."""
    amax = block_amax(x)
    return jnp.where(amax > 0, amax, 1.0).astype(jnp.float32)


# ---------------------------------------------------------------------------
# log grid (the paper's Q_g)
# ---------------------------------------------------------------------------

def log_quantize(x: jax.Array, scale: jax.Array, k_g: int) -> jax.Array:
    """Nearest-in-linear-space log-grid codes given a scale.

    Code layout: 0 encodes 0; signed code c with |c| in [1, k_g+1]
    encodes +/- 2^{-(k_g+1-|c|)}.
    """
    x = x.astype(jnp.float32)
    s = jnp.maximum(scale, 1e-30)
    y = jnp.abs(x) / s
    safe_y = jnp.where(y > 0, y, 1.0)
    e_float = -jnp.log2(safe_y)
    e_lo = jnp.floor(e_float)
    # midpoint in linear space between 2^-e_lo and 2^-(e_lo+1)
    mid = 1.5 * jnp.exp2(-(e_lo + 1.0))
    e_near = jnp.where(y >= mid, e_lo, e_lo + 1.0)
    e_near = jnp.clip(e_near, 0.0, float(k_g))
    # zero threshold: halfway to the smallest level
    is_zero = (y < jnp.exp2(-float(k_g)) * 0.5) | (x == 0.0)
    mag = jnp.where(is_zero, 0.0, float(k_g) + 1.0 - e_near)
    return jnp.where(x < 0, -mag, mag).astype(jnp.int8)


def log_dequantize(codes: jax.Array, scale: jax.Array, k_g: int) -> jax.Array:
    c = codes.astype(jnp.float32)
    mag = jnp.abs(c)
    val = jnp.exp2(mag - (float(k_g) + 1.0))
    val = jnp.where(mag == 0, 0.0, val)
    return jnp.sign(c) * val * scale


@functools.lru_cache(maxsize=None)
def log_dequant_table(k_g: int, bits: int) -> np.ndarray:
    """Scale-1 dequant values for every ``bits``-wide lane code, ordered by
    raw lane value (index = code + 2^{bits-1}).

    A k_g log grid has only 2k_g+3 representable values, so decode can be a
    table gather instead of a per-element exp2. The table is built by
    evaluating :func:`log_dequantize` itself rather than recomputing powers
    of two host-side: XLA lowers exp2 as exp(x*ln2), which is off by an ulp
    for large integral arguments, and bit-identity must hold for *every*
    representable lane code, in-range or not.
    """
    n = 1 << bits
    # first call may happen under an outer jit trace (the codec entry
    # points build it lazily); force compile-time eval so the oracle runs
    # concretely and the table is a plain host constant.
    with jax.ensure_compile_time_eval():
        codes = jnp.arange(-(n // 2), n // 2, dtype=jnp.int32)
        table = log_dequantize(codes, jnp.float32(1.0), k_g)
    return np.asarray(table)


def log_dequantize_lut(codes: jax.Array, scale: jax.Array, lut: jax.Array) -> jax.Array:
    """Table form of :func:`log_dequantize`: ``lut[code + n/2] * scale``.

    Bit-identical to the oracle because the table holds ``sign(c) * val``
    at scale 1 and the original associates as ``(sign(c) * val) * scale``.
    ``lut`` comes from :func:`log_dequant_table`; codes must be lane-range
    (|c| < 2^{bits-1}), which every packed payload guarantees.
    """
    lut = jnp.asarray(lut, dtype=jnp.float32)
    idx = codes.astype(jnp.int32) + lut.shape[0] // 2
    return jnp.take(lut, idx, axis=0, mode="clip") * scale


# ---------------------------------------------------------------------------
# uniform grid (the paper's Q_x)
# ---------------------------------------------------------------------------

def uniform_code_dtype(k_x: int):
    """Codes live in [-2^k, 2^k]: int8 holds k_x <= 6, int16 k_x <= 14."""
    if k_x <= 6:
        return jnp.int8
    return jnp.int16 if k_x <= 14 else jnp.int32


def uniform_quantize(x: jax.Array, scale: jax.Array, k_x: int) -> jax.Array:
    n = float(2 ** k_x)
    y = jnp.clip(x.astype(jnp.float32) / jnp.maximum(scale, 1e-30), -1.0, 1.0)
    return jnp.round(y * n).astype(uniform_code_dtype(k_x))


def uniform_dequantize(codes: jax.Array, scale: jax.Array, k_x: int) -> jax.Array:
    n = float(2 ** k_x)
    return codes.astype(jnp.float32) / n * scale


@functools.lru_cache(maxsize=None)
def uniform_dequant_table(k_x: int, bits: int) -> np.ndarray:
    """Scale-1 uniform dequant values per ``bits``-wide lane code, ordered
    by raw lane value (index = code + 2^{bits-1}) - the uniform-grid twin
    of :func:`log_dequant_table`, built by evaluating the oracle itself.
    ``codes / 2^k`` is an exact power-of-two division, so the gathered
    value times ``scale`` rounds identically to the elementwise form.
    """
    n = 1 << bits
    with jax.ensure_compile_time_eval():
        codes = jnp.arange(-(n // 2), n // 2, dtype=jnp.int32)
        table = uniform_dequantize(codes, jnp.float32(1.0), k_x)
    return np.asarray(table)


# the gather is grid-agnostic: it applies any scale-1 lane table and
# multiplies by scale. Alias it under a neutral name for uniform-grid
# callers (repro.comm.matmul).
dequantize_lut = log_dequantize_lut


# ---------------------------------------------------------------------------
# ternary grid (TernGrad baseline)
# ---------------------------------------------------------------------------

def ternary_quantize(x: jax.Array, u: jax.Array, scale: jax.Array) -> jax.Array:
    """Unbiased stochastic ternary codes {-1, 0, +1}. ``u`` are uniforms in
    [0, 1) drawn outside (``jax.random.uniform(key, x.shape)``) so the jnp
    and Pallas backends consume identical randomness; ``u < |x|/scale`` is
    exactly ``jax.random.bernoulli(key, |x|/scale)``."""
    x = x.astype(jnp.float32)
    p = jnp.abs(x) / jnp.maximum(scale, 1e-30)
    b = (u < p).astype(jnp.int8)
    return jnp.sign(x).astype(jnp.int8) * b


def ternary_dequantize(codes: jax.Array, scale: jax.Array) -> jax.Array:
    return codes.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# blockwise sign grid (Zheng et al. '19 baseline)
# ---------------------------------------------------------------------------

def blockwise_quantize(x2d: jax.Array):
    """(nb, block) f32 -> (sign codes int8, per-block mean-|.| scales)."""
    x2d = x2d.astype(jnp.float32)
    scale = jnp.mean(jnp.abs(x2d), axis=-1)
    return jnp.sign(x2d).astype(jnp.int8), scale


def blockwise_dequantize(codes2d: jax.Array, scales: jax.Array) -> jax.Array:
    return codes2d.astype(jnp.float32) * scales[..., None]


# ---------------------------------------------------------------------------
# Adam+EF leaf math (Algorithm 1 lines 3-6)
# ---------------------------------------------------------------------------

def adam_ef_moments(g, m, v, e, *, alpha_t, beta, theta_t, eps):
    """Moment updates + the full-precision Delta_t + e_t (pre-quantize).

    Returns (m_new, v_new, delta_plus_e). The ``m / sqrt(v + eps)``
    formulation is load-bearing: the Pallas kernel body calls this same
    function, so both backends round identically and the bit-equivalence
    guarantees hold.
    """
    g = g.astype(jnp.float32)
    v_new = theta_t * v + (1.0 - theta_t) * g * g
    m_new = beta * m + (1.0 - beta) * g
    delta_plus_e = alpha_t * m_new / jnp.sqrt(v_new + eps) + e
    return m_new, v_new, delta_plus_e


def adam_ef_quantize(delta_plus_e, scale, k_g: int):
    """Codes + EF residual (Algorithm 1 lines 5-6)."""
    codes = log_quantize(delta_plus_e, scale, k_g)
    deq = log_dequantize(codes, scale, k_g)
    e_new = delta_plus_e - deq
    return codes, e_new
