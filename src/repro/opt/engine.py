"""The backend-dispatched optimizer update engine.

One implementation of the paper's update core (moments -> Delta+e -> Q_g
-> residual) and the four quantizer grids, behind a ``backend`` switch:

  * ``backend="jnp"``    - pure-jnp path (the canonical ``repro.opt.grids``
    math under plain XLA fusion);
  * ``backend="pallas"`` - the fused Pallas kernels (interpret mode off
    TPU), whose bodies call the *same* ``grids`` functions, so codes,
    scales, and EF residuals are bit-identical to the jnp backend;
  * ``backend=None``     - auto: Pallas on TPU for tensors at least one
    (BLOCK_ROWS x LANES) tile, jnp everywhere else.

Both the single-machine optimizer (``repro.core.qadam``) and the
distributed per-mode updaters (``repro.dist.modes``) consume this module;
``repro.kernels.ops`` re-exports the public entry points for
backward compatibility.

Layout handling: arbitrary-shape tensors are flattened and zero-padded to
the kernels' (R, 128) tile layout (R a multiple of BLOCK_ROWS), then
restored.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.opt import grids
from repro.comm.codec import BACKENDS, resolve_backend as _resolve
from repro.kernels import quantize as qk
from repro.kernels import adam_ef as ak

TILE = qk.BLOCK_ROWS * qk.LANES


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def resolve_backend(backend: Optional[str], numel: Optional[int] = None) -> str:
    """Auto backend policy - one definition, in ``repro.comm.codec``;
    the engine's tile threshold is its own (BLOCK_ROWS x LANES)."""
    return _resolve(backend, numel, tile=TILE)


def _to_tiles(x: jax.Array) -> Tuple[jax.Array, int]:
    flat = x.reshape(-1)
    numel = flat.shape[0]
    pad = (-numel) % TILE
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, qk.LANES), numel


def _from_tiles(x2d: jax.Array, numel: int, shape) -> jax.Array:
    return x2d.reshape(-1)[:numel].reshape(shape)


# ---------------------------------------------------------------------------
# log grid (Q_g)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k_g", "backend"))
def quantize_log(x: jax.Array, k_g: int = 6,
                 backend: Optional[str] = None):
    """Paper's Q_g encode: per-tensor amax scale + log-grid int8 codes."""
    if resolve_backend(backend, x.size) == "jnp":
        scale = jnp.maximum(grids.block_amax(x), 1e-30)
        return grids.log_quantize(x, scale, k_g), scale
    x2d, numel = _to_tiles(x.astype(jnp.float32))
    scale = jnp.maximum(qk.amax_pallas(x2d, interpret=_interpret()), 1e-30)
    codes2d = qk.log_quantize_pallas(x2d, scale, k_g, interpret=_interpret())
    return _from_tiles(codes2d, numel, x.shape), scale


@functools.partial(jax.jit, static_argnames=("k_g", "backend", "out_dtype"))
def dequantize_log(codes: jax.Array, scale: jax.Array, k_g: int = 6,
                   backend: Optional[str] = None, out_dtype=jnp.float32):
    if resolve_backend(backend, codes.size) == "jnp":
        return grids.log_dequantize(codes, scale, k_g).astype(out_dtype)
    c2d, numel = _to_tiles(codes)
    out = qk.log_dequantize_pallas(c2d, scale, k_g, out_dtype=out_dtype,
                                   interpret=_interpret())
    return _from_tiles(out, numel, codes.shape)


# ---------------------------------------------------------------------------
# uniform grid (Q_x)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k_x", "absolute", "backend"))
def quantize_uniform(x: jax.Array, k_x: int = 7, absolute: bool = True,
                     backend: Optional[str] = None):
    """Paper's Q_x encode (absolute grid over [-0.5, 0.5] by default).
    Codes are int8 for k_x <= 6, int16 above (codes reach +/- 2^k_x)."""
    bk = resolve_backend(backend, x.size)
    if absolute:
        scale = jnp.float32(0.5)
    elif bk == "jnp":
        scale = jnp.maximum(grids.block_amax(x), 1e-30)
    else:
        x2d0, _ = _to_tiles(x.astype(jnp.float32))
        scale = jnp.maximum(qk.amax_pallas(x2d0, interpret=_interpret()),
                            1e-30)
    if bk == "jnp":
        return grids.uniform_quantize(x, scale, k_x), scale
    x2d, numel = _to_tiles(x.astype(jnp.float32))
    codes2d = qk.uniform_quantize_pallas(x2d, scale, k_x,
                                         interpret=_interpret())
    return _from_tiles(codes2d, numel, x.shape), scale


@functools.partial(jax.jit, static_argnames=("k_x", "backend", "out_dtype"))
def dequantize_uniform(codes: jax.Array, scale: jax.Array, k_x: int = 7,
                       backend: Optional[str] = None, out_dtype=jnp.float32):
    if resolve_backend(backend, codes.size) == "jnp":
        return grids.uniform_dequantize(codes, scale, k_x).astype(out_dtype)
    c2d, numel = _to_tiles(codes)
    out = qk.uniform_dequantize_pallas(c2d, scale, k_x, out_dtype=out_dtype,
                                       interpret=_interpret())
    return _from_tiles(out, numel, codes.shape)


# ---------------------------------------------------------------------------
# ternary grid (TernGrad)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("backend",))
def quantize_ternary(x: jax.Array, key: jax.Array,
                     backend: Optional[str] = None):
    """Unbiased stochastic ternary codes + amax scale. The uniforms are
    drawn here (one stream for both backends), matching
    ``jax.random.bernoulli(key, |x|/scale)`` draw-for-draw."""
    x = x.astype(jnp.float32)
    scale = grids.amax_scale(x)
    u = jax.random.uniform(key, x.shape)
    if resolve_backend(backend, x.size) == "jnp":
        return grids.ternary_quantize(x, u, scale), scale
    x2d, numel = _to_tiles(x)
    u2d, _ = _to_tiles(u)
    codes2d = qk.ternary_quantize_pallas(x2d, u2d, scale,
                                         interpret=_interpret())
    return _from_tiles(codes2d, numel, x.shape), scale


# ---------------------------------------------------------------------------
# blockwise sign grid (Zheng et al. '19)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("block", "backend"))
def quantize_blockwise(x: jax.Array, block: int = 256,
                       backend: Optional[str] = None):
    """Sign codes + per-block mean-|.| scales over flat blocks of ``block``
    elements (zero-padded tail). Returns ((nb, block) int8, (nb,) f32)."""
    flat = x.astype(jnp.float32).reshape(-1)
    numel = flat.shape[0]
    nb = -(-numel // block)
    x2d = jnp.pad(flat, (0, nb * block - numel)).reshape(nb, block)
    if resolve_backend(backend, numel) == "jnp":
        return grids.blockwise_quantize(x2d)
    rpad = (-nb) % qk.BLOCKWISE_ROWS
    x2dp = jnp.pad(x2d, ((0, rpad), (0, 0)))
    codes, scales = qk.blockwise_quantize_pallas(x2dp,
                                                 interpret=_interpret())
    return codes[:nb], scales[:nb]


# ---------------------------------------------------------------------------
# Adam+EF update core (Algorithm 1/3 lines 3-6)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("backend",))
def adam_ef_moments(g, m, v, e, alpha_t, beta, theta_t, eps,
                    backend: Optional[str] = None):
    """Pass A: moment updates + full-precision Delta_t + e_t.
    Returns (m', v', delta_plus_e)."""
    if resolve_backend(backend, g.size) == "jnp":
        return grids.adam_ef_moments(g, m, v, e, alpha_t=alpha_t, beta=beta,
                                     theta_t=theta_t, eps=eps)
    shape = g.shape
    g2d, numel = _to_tiles(g.astype(jnp.float32))
    m2d, _ = _to_tiles(m)
    v2d, _ = _to_tiles(v)
    e2d, _ = _to_tiles(e)
    hp = jnp.stack([jnp.float32(alpha_t), jnp.float32(beta),
                    jnp.float32(theta_t), jnp.float32(eps)])
    m2, v2, de2, _ = ak.adam_moments_pallas(g2d, m2d, v2d, e2d, hp,
                                            interpret=_interpret())
    return (_from_tiles(m2, numel, shape), _from_tiles(v2, numel, shape),
            _from_tiles(de2, numel, shape))


@functools.partial(jax.jit, static_argnames=("k_g", "backend"))
def ef_quantize(de, scale, k_g: int, backend: Optional[str] = None):
    """Pass B: log-grid codes + new EF residual e' = de - deq(codes)."""
    if resolve_backend(backend, de.size) == "jnp":
        return grids.adam_ef_quantize(de, scale, k_g)
    de2d, numel = _to_tiles(de)
    codes2d, e2d = ak.ef_quantize_pallas(de2d, scale, k_g,
                                         interpret=_interpret())
    return (_from_tiles(codes2d, numel, de.shape),
            _from_tiles(e2d, numel, de.shape))


@functools.partial(jax.jit, static_argnames=("k_g", "backend"))
def adam_ef_step(g, m, v, e, alpha_t, beta, theta_t, eps,
                 k_g: int = 6, backend: Optional[str] = None):
    """Fused worker inner loop of Algorithm 3: returns
    (m', v', codes, scale, e')."""
    bk = resolve_backend(backend, g.size)
    if bk == "jnp":
        m_n, v_n, de = grids.adam_ef_moments(
            g, m, v, e, alpha_t=alpha_t, beta=beta, theta_t=theta_t, eps=eps)
        scale = grids.amax_scale(de)
        codes, e_n = grids.adam_ef_quantize(de, scale, k_g)
        return m_n, v_n, codes, scale, e_n
    shape = g.shape
    g2d, numel = _to_tiles(g.astype(jnp.float32))
    m2d, _ = _to_tiles(m)
    v2d, _ = _to_tiles(v)
    e2d, _ = _to_tiles(e)
    hp = jnp.stack([jnp.float32(alpha_t), jnp.float32(beta),
                    jnp.float32(theta_t), jnp.float32(eps)])
    m_n2, v_n2, de2, amax = ak.adam_moments_pallas(
        g2d, m2d, v2d, e2d, hp, interpret=_interpret())
    scale = jnp.where(amax > 0, amax, 1.0).astype(jnp.float32)
    codes2, e_n2 = ak.ef_quantize_pallas(de2, scale, k_g,
                                         interpret=_interpret())
    return (_from_tiles(m_n2, numel, shape), _from_tiles(v_n2, numel, shape),
            _from_tiles(codes2, numel, shape), scale,
            _from_tiles(e_n2, numel, shape))


@functools.partial(jax.jit,
                   static_argnames=("k_g", "error_feedback", "backend"))
def adam_ef_update(g, m, v, e, alpha_t, beta, theta_t, eps, k_g: int,
                   error_feedback: bool = True,
                   backend: Optional[str] = None):
    """The complete single-machine Algorithm 1 leaf update: returns the
    *dequantized* delta Q_g(Delta_t + e_t) plus the new optimizer state
    (delta_deq, m', v', e')."""
    m2, v2, codes, scale, e2 = adam_ef_step(
        g, m, v, e, alpha_t, beta, theta_t, eps, k_g=k_g, backend=backend)
    deq = dequantize_log(codes, scale, k_g, backend=backend)
    if not error_feedback:
        e2 = jnp.zeros_like(e2)
    return deq, m2, v2, e2
