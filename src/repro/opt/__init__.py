"""One optimizer engine for the paper's update core.

  grids     - canonical jnp definition of the Adam+EF leaf math and the
              log / uniform / ternary / blockwise quantizer grids
  engine    - backend dispatch ("jnp" | "pallas" | None=auto) around the
              grids; consumed by repro.core.qadam and repro.dist.modes
  multistep - compat re-export of the lax.scan-chunked, buffer-donating
              step builders (canonical home: repro.train.session, whose
              TrainSession owns the full training loop)
"""
from repro.opt import grids, engine  # noqa: F401
from repro.opt.engine import resolve_backend  # noqa: F401
