"""One optimizer engine for the paper's update core.

  grids     - canonical jnp definition of the Adam+EF leaf math and the
              log / uniform / ternary / blockwise quantizer grids
  engine    - backend dispatch ("jnp" | "pallas" | None=auto) around the
              grids; consumed by repro.core.qadam and repro.dist.modes
  multistep - lax.scan-chunked, buffer-donating training drivers that
              amortize per-step Python dispatch
"""
from repro.opt import grids, engine  # noqa: F401
from repro.opt.engine import resolve_backend  # noqa: F401
