"""One optimizer engine for the paper's update core.

  grids     - canonical jnp definition of the Adam+EF leaf math and the
              log / uniform / ternary / blockwise quantizer grids
  engine    - backend dispatch ("jnp" | "pallas" | None=auto) around the
              grids; consumed by repro.core.qadam and repro.dist.modes
  multistep - compat re-export of the lax.scan-chunked, buffer-donating
              step builders (canonical home: repro.train.session, whose
              TrainSession owns the full training loop)

``engine`` is imported lazily (PEP 562): the ``repro.comm`` kernel stack
sits between ``grids`` and ``engine`` (grids -> comm -> engine), so an
eager import here would close an import cycle when comm pulls grids.
"""
from repro.opt import grids  # noqa: F401


def __getattr__(name):
    if name in ("engine", "multistep"):
        import importlib
        return importlib.import_module(f"repro.opt.{name}")
    if name == "resolve_backend":
        from repro.opt.engine import resolve_backend
        return resolve_backend
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
