"""lax.scan-chunked training drivers for the single-machine optimizer.

The classic loop pays one Python dispatch + jit-cache lookup + host sync
per step; for small models that overhead rivals the update math itself.
These helpers compile K optimizer steps into ONE program (`lax.scan` over
a stacked leading axis) with the parameter/state buffers donated, so the
hot loop runs K steps per Python round-trip and updates in place.

    opt = qadam(QAdamConfig(...))
    chunk = make_chunked_train_step(opt, loss_fn)
    params, state, losses = chunk(params, state, stacked_batches)

``benchmarks/run.py --only kernels`` measures the per-step win vs the
per-step ``jax.jit`` loop.
"""
from __future__ import annotations

from typing import Callable

import jax

from repro.core.qadam import Optimizer, apply_updates


def stack_batches(batch_list):
    """Stack a list of same-shape batch pytrees along a new leading axis
    (the scan axis)."""
    import jax.numpy as jnp
    return jax.tree.map(lambda *xs: jnp.stack(xs), *batch_list)


def make_chunked_update(opt: Optimizer, donate: bool = True) -> Callable:
    """K pure optimizer updates per call: ``fn(params, state, gstack)``
    with ``gstack`` a gradient pytree stacked over a leading step axis.
    Returns (params, state)."""
    def chunk(params, state, gstack):
        def body(carry, g):
            p, s = carry
            upd, s2 = opt.update(g, s, p)
            return (apply_updates(p, upd), s2), None
        (p2, s2), _ = jax.lax.scan(body, (params, state), gstack)
        return p2, s2
    return jax.jit(chunk, donate_argnums=(0, 1) if donate else ())


def make_chunked_train_step(opt: Optimizer, loss_fn: Callable,
                            donate: bool = True) -> Callable:
    """K full steps (Q_x forward params -> grad -> engine update -> apply)
    per call: ``fn(params, state, batches)`` with ``batches`` a batch
    pytree stacked over a leading step axis. Returns
    (params, state, per-step losses)."""
    def chunk(params, state, batches):
        def body(carry, batch):
            p, s = carry
            fp = opt.forward_params(p, s)
            loss, g = jax.value_and_grad(loss_fn)(fp, batch)
            upd, s2 = opt.update(g, s, p)
            return (apply_updates(p, upd), s2), loss
        (p2, s2), losses = jax.lax.scan(body, (params, state), batches)
        return p2, s2, losses
    return jax.jit(chunk, donate_argnums=(0, 1) if donate else ())
