"""Compat shim: the lax.scan-chunked single-machine step builders moved
into ``repro.train.session`` (the ``TrainSession`` substrate owns ALL
training drivers now - it wraps these same builders behind its
prefetching, ring-buffered, resumable loop).

    opt = qadam(QAdamConfig(...))
    chunk = make_chunked_train_step(opt, loss_fn)
    params, state, losses = chunk(params, state, stacked_batches)

remains supported for direct use; prefer
``TrainSession.from_optimizer(opt, loss_fn, params, batches)`` for a
full loop. ``benchmarks/run.py --only kernels`` measures the per-step
win vs the per-step ``jax.jit`` loop.
"""
from __future__ import annotations

from repro.train.session import (make_chunked_train_step,  # noqa: F401
                                 make_chunked_update, stack_batches)

__all__ = ["make_chunked_update", "make_chunked_train_step",
           "stack_batches"]
