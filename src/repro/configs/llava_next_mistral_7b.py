"""llava-next-mistral-7b [vlm] — mistral-7b decoder; ViT/SigLIP tower +
anyres tiling projector stubbed: inputs arrive as (B, S, 4096) patch+text
embeddings. [hf:llava-hf/llava-v1.6-mistral-7b-hf]

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.
"""
import dataclasses

from repro.models.config import ModelConfig

ARCH_ID = "llava-next-mistral-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="vlm",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=32000,
        input_mode="embeddings",
        rope_theta=1_000_000.0, tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
        d_ff=256, vocab_size=512, dtype="float32")
