"""gemma3-4b [dense] — 5:1 local(1024):global attention, qk-norm, dual rope
bases (local 10k / global 1M), 128k context. [hf:google/gemma-3-1b-pt]

34L d_model=2560 8H (GQA kv=4, head_dim=256) d_ff=10240 vocab=262144.
"""
import dataclasses

from repro.models.config import ModelConfig

ARCH_ID = "gemma3-4b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="dense",
        n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, head_dim=256,
        d_ff=10240, vocab_size=262144,
        pattern="lllllg", window=1024,
        qk_norm=True, post_norm=True, emb_scale=True, tie_embeddings=True,
        rope_theta=1_000_000.0, rope_theta_local=10_000.0,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=512, window=16, dtype="float32")
