"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060]

64L d_model=2560 ssm_state=128, expand=2 -> d_inner=5120, head_dim=64
(80 SSM heads), vocab=50280.
"""
import dataclasses

from repro.models.config import ModelConfig, SSMConfig

ARCH_ID = "mamba2-2.7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="ssm",
        n_layers=64, d_model=2560, n_heads=1, n_kv_heads=1,
        d_ff=0, vocab_size=50280,
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2, d_conv=4,
                      n_groups=1, chunk=128),
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=128, vocab_size=512,
        ssm=SSMConfig(d_state=16, head_dim=32, expand=2, d_conv=4,
                      n_groups=1, chunk=8),
        dtype="float32")
