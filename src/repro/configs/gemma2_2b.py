"""gemma2-2b [dense] — alternating local(4096):global attention, attention
and final logit softcaps, pre+post sublayer norms. [arXiv:2408.00118]

26L d_model=2304 8H (GQA kv=4, head_dim=256) d_ff=9216 vocab=256000.
"""
import dataclasses

from repro.models.config import ModelConfig

ARCH_ID = "gemma2-2b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="dense",
        n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, head_dim=256,
        d_ff=9216, vocab_size=256000,
        pattern="lg", window=4096,
        attn_softcap=50.0, final_softcap=30.0,
        post_norm=True, emb_scale=True, tie_embeddings=True,
        rope_theta=10_000.0,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=512, window=16, dtype="float32")
