"""whisper-small [audio] — enc-dec, conv frontend stubbed. [arXiv:2212.04356]

12L decoder (+12L encoder) d_model=768 12H (kv=12, MHA) d_ff=3072
vocab=51865. Audio arrives as (B, 1500, 768) frame embeddings (the
mel+conv frontend is the brief's sanctioned stub).
"""
import dataclasses

from repro.models.config import ModelConfig

ARCH_ID = "whisper-small"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="encdec",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=3072, vocab_size=51865,
        encoder_layers=12, encoder_seq=1500,
        input_mode="audio+tokens",
        act="gelu", norm="layernorm", tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, encoder_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, d_ff=256, vocab_size=512, encoder_seq=16,
        dtype="float32")
