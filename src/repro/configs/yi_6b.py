"""yi-6b [dense] — llama-architecture GQA. [arXiv:2403.04652]

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
import dataclasses

from repro.models.config import ModelConfig

ARCH_ID = "yi-6b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4,
        d_ff=11008, vocab_size=64000,
        rope_theta=5_000_000.0, tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
        d_ff=256, vocab_size=512, dtype="float32")
