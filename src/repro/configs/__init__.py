"""Architecture registry + assigned input shapes.

``get_config(arch_id)`` returns the exact assigned configuration;
``get_config(arch_id, smoke=True)`` the reduced CPU-smoke variant
(2 layers, d_model <= 512, <= 4 experts).
"""
from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.models.config import ModelConfig

_MODULES = {
    "whisper-small": "repro.configs.whisper_small",
    "mamba2-2.7b": "repro.configs.mamba2_2p7b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "llava-next-mistral-7b": "repro.configs.llava_next_mistral_7b",
    "hymba-1.5b": "repro.configs.hymba_1p5b",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "yi-6b": "repro.configs.yi_6b",
    "qwen2.5-14b": "repro.configs.qwen2p5_14b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick",
}

ARCH_IDS = tuple(_MODULES)

# assigned input shapes: name -> (seq_len, global_batch, kind)
INPUT_SHAPES: Dict[str, Tuple[int, int, str]] = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}

# long_500k needs sub-quadratic attention; these archs are full-attention
# (or architecturally capped, whisper) -> skipped, see DESIGN.md §5.
LONG_CONTEXT_ARCHS = ("mamba2-2.7b", "hymba-1.5b", "gemma2-2b", "gemma3-4b")


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; choose from {ARCH_IDS}")
    mod = importlib.import_module(_MODULES[arch_id])
    return mod.smoke_config() if smoke else mod.config()


def shape_applicable(arch_id: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch_id in LONG_CONTEXT_ARCHS
    return True
