"""llama4-maverick-400b-a17b [moe] — 128 routed experts top-1 + 1 shared.
[hf:meta-llama/Llama-4-Scout-17B-16E]

48L d_model=5120 40H (GQA kv=8) expert d_ff=8192 vocab=202048.
Text backbone (early-fusion vision arrives as embeddings in the VLM arch);
every layer is MoE (the released model interleaves; noted in DESIGN.md).
"""
import dataclasses

from repro.models.config import ModelConfig, MoEConfig

ARCH_ID = "llama4-maverick-400b-a17b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=8192, vocab_size=202048,
        moe=MoEConfig(n_experts=128, top_k=1, n_shared=1, d_ff_expert=8192),
        rope_theta=500_000.0, tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
        d_ff=64, vocab_size=512,
        moe=MoEConfig(n_experts=4, top_k=1, n_shared=1, d_ff_expert=64),
        dtype="float32")
