"""deepseek-moe-16b [moe] — fine-grained MoE: 2 shared + 64 routed top-6.
[arXiv:2401.06066]

28L d_model=2048 16H (kv=16, MHA) expert d_ff=1408 vocab=102400.
"""
import dataclasses

from repro.models.config import ModelConfig, MoEConfig

ARCH_ID = "deepseek-moe-16b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="moe",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab_size=102400,
        moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_ff_expert=1408),
        rope_theta=10_000.0, tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=64, vocab_size=512,
        moe=MoEConfig(n_experts=4, top_k=2, n_shared=1, d_ff_expert=64),
        dtype="float32")
