"""qwen2.5-14b [dense] — GQA with QKV bias. [hf:Qwen/Qwen2.5-0.5B]

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.
"""
import dataclasses

from repro.models.config import ModelConfig

ARCH_ID = "qwen2.5-14b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="dense",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=13824, vocab_size=152064,
        qkv_bias=True, rope_theta=1_000_000.0, tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
        d_ff=256, vocab_size=512, dtype="float32")
