"""hymba-1.5b [hybrid] — parallel attention + mamba heads per layer, SWA
everywhere except 3 global layers (first/middle/last), 128 meta tokens.
[arXiv:2411.13676]

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Meta tokens are realized as a learned per-layer KV prefix + learned SSM
initial state (see DESIGN.md hardware-adaptation notes).
"""
import dataclasses

from repro.models.config import ModelConfig, SSMConfig


ARCH_ID = "hymba-1.5b"


def _pattern(n_layers: int) -> str:
    # global attention at the first, middle, and last layer
    pat = ["l"] * n_layers
    for i in (0, n_layers // 2, n_layers - 1):
        pat[i] = "g"
    return "".join(pat)


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
        d_ff=5504, vocab_size=32001,
        pattern=_pattern(32), window=1024,
        ssm=SSMConfig(d_state=16, head_dim=64, expand=2, d_conv=4,
                      n_groups=1, chunk=128),
        meta_tokens=128, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab_size=512, pattern=_pattern(2), window=16,
        ssm=SSMConfig(d_state=16, head_dim=32, expand=2, d_conv=4,
                      n_groups=1, chunk=8),
        meta_tokens=8, dtype="float32")
