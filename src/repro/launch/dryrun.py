"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination against placeholder devices and extract the roofline terms.

  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.jsonl
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi

``main()`` sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512``
before any jax import (jax locks the device count at first init;
setdefault so the test harness can run a reduced 8-device pass). The
flag is scoped to the CLI entry: merely *importing* this module - e.g.
for ``parse_collectives`` - must not pin the process to 512 placeholder
devices.

Per combination this records:
  * compiled.memory_analysis()  - bytes per device (proves it fits)
  * compiled.cost_analysis()    - per-device HLO FLOPs / bytes. XLA counts
    a while-loop (scan-over-layers) body ONCE, so totals are calibrated by
    additionally compiling fully-UNROLLED 1-layer and 2-layer variants:
    metric(L) = entry + L*body exactly (the body HLO is layer-independent;
    per-layer heterogeneity rides in scanned flag arrays).
  * collective bytes parsed from the (unrolled-calibrated) compiled HLO,
    with a ring cost model per op kind.
  * the three roofline terms + dominant bottleneck (v5e hardware model).
"""
import argparse
import dataclasses
import json
import os
import re
import time
from typing import Dict, Optional

import numpy as np


# --------------------------------------------------------------------------
# HLO collective parsing
# --------------------------------------------------------------------------

_STABLE_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2,
                       "i64": 8, "ui64": 8, "i32": 4, "ui32": 4,
                       "i16": 2, "ui16": 2, "i8": 1, "ui8": 1, "i1": 1,
                       "f8E4M3FN": 1, "f8E5M2": 1}

_ST_OP_RE = re.compile(r'"stablehlo\.(all_gather|all_to_all|reduce_scatter'
                       r'|all_reduce|collective_permute)"')
_ST_RES_RE = re.compile(r"->\s*(\(?tensor<[^)]*?)(?:\s*$|\s*\()")
_ST_TENSOR_RE = re.compile(r"tensor<([0-9x]*)x?([a-z][a-zA-Z0-9]*)>")
_ST_GROUPS_RE = re.compile(r"replica_groups\s*=\s*dense<[^>]*>\s*:\s*"
                           r"tensor<(\d+)x(\d+)xi64>")


def _st_result_bytes(line: str) -> int:
    m = _ST_RES_RE.search(line)
    seg = m.group(1) if m else line[line.rfind("->"):]
    total = 0
    for dims, dt in _ST_TENSOR_RE.findall(seg):
        if dt not in _STABLE_DTYPE_BYTES:
            continue
        numel = 1
        for d in dims.split("x"):
            if d:
                numel *= int(d)
        total += numel * _STABLE_DTYPE_BYTES[dt]
    return total


def parse_collectives(stablehlo_text: str) -> Dict:
    """Sum modeled per-device wire bytes of every collective in the LOWERED
    StableHLO (original dtypes - the compiled CPU module upcasts bf16 to
    f32, which would inflate wire bytes 2x vs the TPU target).

    jax emits rematerialized scan bodies as shared `closed_call` functions
    invoked once per (unrolled) layer, so op counts are propagated through
    the call graph with multiplicities.

    Ring cost model per op (n = group size, S = result bytes):
      all_gather: S*(n-1)/n ; reduce_scatter: S*(n-1) (input = S*n);
      all_reduce: 2*S*(n-1)/n ; all_to_all: S*(n-1)/n ;
      collective_permute: S.
    """
    names = {"all_gather": "all-gather", "all_reduce": "all-reduce",
             "reduce_scatter": "reduce-scatter", "all_to_all": "all-to-all",
             "collective_permute": "collective-permute"}
    kinds = tuple(names.values())
    funcs: Dict[str, dict] = {}
    cur = None
    pending = None
    func_re = re.compile(r"func\.func\s+(?:private\s+|public\s+)?@([\w.$-]+)")
    call_re = re.compile(r"call\s+@([\w.$-]+)")
    for line in stablehlo_text.splitlines():
        fm = func_re.search(line)
        if fm:
            cur = fm.group(1)
            funcs[cur] = {"events": [], "calls": {}}
            pending = None
            continue
        if cur is None:
            continue
        f = funcs[cur]
        m = _ST_OP_RE.search(line)
        if m:
            kind = names[m.group(1)]
            gm = _ST_GROUPS_RE.search(line)
            n = int(gm.group(2)) if gm else 1
            if "->" in line:
                f["events"].append((kind, n, _st_result_bytes(line)))
            else:
                pending = (kind, n)
        elif pending and "}) :" in line and "->" in line:
            kind, n = pending
            f["events"].append((kind, n, _st_result_bytes(line)))
            pending = None
        for callee in call_re.findall(line):
            f["calls"][callee] = f["calls"].get(callee, 0) + 1

    def event_bytes(kind, n, size):
        if kind == "all-gather":
            return size * (n - 1) / n
        if kind == "reduce-scatter":
            return size * (n - 1)
        if kind == "all-reduce":
            return 2 * size * (n - 1) / n
        if kind == "all-to-all":
            return size * (n - 1) / n
        return size

    memo: Dict[str, tuple] = {}

    def totals(fname, stack=()):
        if fname in memo:
            return memo[fname]
        if fname not in funcs or fname in stack:
            return ({k: 0.0 for k in kinds}, {k: 0 for k in kinds})
        agg = {k: 0.0 for k in kinds}
        cnt = {k: 0 for k in kinds}
        f = funcs[fname]
        for kind, n, size in f["events"]:
            agg[kind] += event_bytes(kind, n, size)
            cnt[kind] += 1
        for callee, times in f["calls"].items():
            sub, subc = totals(callee, stack + (fname,))
            for k in kinds:
                agg[k] += times * sub[k]
                cnt[k] += times * subc[k]
        memo[fname] = (agg, cnt)
        return memo[fname]

    entry = "main" if "main" in funcs else (next(iter(funcs)) if funcs
                                            else None)
    agg, cnt = totals(entry) if entry else (
        {k: 0.0 for k in kinds}, {k: 0 for k in kinds})
    per_kind = dict(agg)
    per_kind["total"] = sum(agg.values())
    per_kind["counts"] = cnt
    return per_kind


# --------------------------------------------------------------------------
# lowering one configuration
# --------------------------------------------------------------------------

def _batch_sds(cfg, gbatch, seq, enc_seq, sds, Wb):
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    fdt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    b = {}
    if cfg.input_mode == "embeddings":
        b["embeds"] = sds((gbatch, seq, cfg.d_model), fdt,
                          P(Wb, "model", None))
    else:
        b["tokens"] = sds((gbatch, seq), jnp.int32, P(Wb, "model"))
    if cfg.input_mode == "audio+tokens":
        b["audio"] = sds((gbatch, enc_seq, cfg.d_model), fdt,
                         P(Wb, "model", None))
    b["targets"] = sds((gbatch, seq), jnp.int32, P(Wb, "model"))
    b["mask"] = sds((gbatch, seq), jnp.float32, P(Wb, "model"))
    return b


def _lower_one(cfg, kind, mesh, gbatch, seq, enc_seq, W, batch_shardable,
               train_overrides, train_art=None):
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models.model import Model
    from repro.dist.serve import make_serve_step
    from repro.dist.step import (make_train_step, TrainConfig, ServeConfig,
                                 state_template)

    model = Model(cfg)
    ms = dict(zip(mesh.axis_names, mesh.devices.shape))
    Nm = ms["model"]
    Wb = W if batch_shardable else None

    def sds(shape, dtype, spec):
        return jax.ShapeDtypeStruct(shape, dtype,
                                    sharding=NamedSharding(mesh, spec))

    if kind == "train":
        # build_and_compile pre-builds the artifacts for its codec
        # accounting; the calibration re-lowerings (modified n_layers)
        # build their own
        art = train_art
        if art is None:
            tc = TrainConfig(worker_axes=W, **(train_overrides or {}))
            art = make_train_step(model, mesh, tc)
        # the chunked state layout (incl. per-mode extra leaves) comes
        # from one place - no hand-reconstruction of shapes here
        state = state_template(art)
        batch = _batch_sds(cfg, gbatch, seq, enc_seq, sds, Wb)
        return jax.jit(art.step_fn).lower(state, batch)

    if kind == "prefill":
        sc = ServeConfig(worker_axes=W, batch_dim_shardable=batch_shardable)
        step, pspecs, _ = make_serve_step(model, mesh, sc, kind="prefill")
        pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        ptree = jax.tree.map(lambda l, s: sds(l.shape, jnp.float32, s),
                             pshapes, pspecs)
        batch = _batch_sds(cfg, gbatch, seq, enc_seq, sds, Wb)
        return jax.jit(step).lower(ptree, batch)

    # decode
    sc = ServeConfig(worker_axes=W, batch_dim_shardable=batch_shardable)
    step, pspecs, (ispecs, cspecs) = make_serve_step(model, mesh, sc,
                                                     kind="decode")
    pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    ptree = jax.tree.map(lambda l, s: sds(l.shape, jnp.float32, s),
                         pshapes, pspecs)
    cshapes = jax.eval_shape(
        lambda: Model(cfg).init_cache(gbatch, max_seq_local=seq,
                                      encoder_seq_local=enc_seq))
    ctree = jax.tree.map(lambda l, s: sds(l.shape, l.dtype, s),
                         cshapes, cspecs)
    if cfg.input_mode == "embeddings":
        itree = {"embeds": sds((gbatch, 1, cfg.d_model), jnp.bfloat16
                               if cfg.dtype == "bfloat16" else jnp.float32,
                               ispecs["embeds"])}
    else:
        itree = {"token": sds((gbatch, 1), jnp.int32, ispecs["token"])}
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return jax.jit(step).lower(ptree, itree, ctree, pos)


# --------------------------------------------------------------------------
# dry-run driver
# --------------------------------------------------------------------------

def apply_model_overrides(cfg, overrides: Optional[dict]):
    """dataclasses.replace on ModelConfig, with ssm./moe. nesting."""
    if not overrides:
        return cfg
    top, ssm_o, moe_o = {}, {}, {}
    for k, v in overrides.items():
        if k.startswith("ssm."):
            ssm_o[k[4:]] = v
        elif k.startswith("moe."):
            moe_o[k[4:]] = v
        else:
            top[k] = v
    if ssm_o and cfg.ssm is not None:
        top["ssm"] = dataclasses.replace(cfg.ssm, **ssm_o)
    if moe_o and cfg.moe is not None:
        top["moe"] = dataclasses.replace(cfg.moe, **moe_o)
    return dataclasses.replace(cfg, **top)


def _cost_analysis(compiled) -> Dict:
    """compiled.cost_analysis() returns [dict] on jax<=0.4.x and a plain
    dict on newer releases; normalize to a dict."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def build_and_compile(arch: str, shape_name: str, multi_pod: bool,
                      mesh_override=None, smoke: bool = False,
                      train_overrides: Optional[dict] = None,
                      model_overrides: Optional[dict] = None,
                      calibrate: bool = True, adaptive: bool = False,
                      adapt_budget: float = 0.6) -> Dict:
    import jax

    from repro.configs import get_config, INPUT_SHAPES, shape_applicable
    from repro.launch.mesh import (make_production_mesh, PEAK_FLOPS_BF16,
                                   HBM_BW, ICI_BW_PER_LINK)

    t_start = time.time()
    cfg = apply_model_overrides(get_config(arch, smoke=smoke),
                                model_overrides)
    seq, gbatch, kind = INPUT_SHAPES[shape_name]
    if smoke:
        seq, gbatch = 64, 8
    mesh = mesh_override if mesh_override is not None else \
        make_production_mesh(multi_pod=multi_pod)
    ms = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_dev = int(np.prod(mesh.devices.shape))

    if not shape_applicable(arch, shape_name):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "full-attention arch: long_500k needs "
                          "sub-quadratic attention (DESIGN.md §5)"}

    enc_seq = 0
    if cfg.arch_type == "encdec":
        enc_seq = cfg.encoder_seq if smoke else 1536  # 1500 padded /16

    worker_axes = tuple(a for a in ("pod", "data") if a in ms)
    W = worker_axes
    batch_shardable = bool(W) and gbatch % int(
        np.prod([ms[a] for a in W])) == 0

    result = {"arch": arch, "shape": shape_name, "kind": kind,
              "mesh": "x".join(str(s) for s in mesh.devices.shape),
              "n_devices": n_dev, "skipped": False,
              "seq": seq, "global_batch": gbatch}

    train_art = None
    if kind == "train":
        # analytic wire accounting from the codec registry (the same
        # single source of truth as train.loop.comm_bytes_per_step),
        # recorded next to the HLO-parsed collective bytes; the same
        # artifacts feed the main lowering below.
        from repro.models.model import Model
        from repro.dist.step import make_train_step, TrainConfig
        from repro.train.loop import comm_bytes_per_step
        tc = TrainConfig(worker_axes=W, **(train_overrides or {}))
        if adaptive:
            # solve the bit plan under the uniform prior (no gradient
            # history pre-run) and lower the planned step; the per-leaf
            # report and the registry accounting both come from the
            # allocator output, not hand-rolled formulas. Calibration
            # is forced off: it re-lowers with n_layers 2/3, whose leaf
            # counts no longer match the plan length.
            from repro.adapt.controller import plan_for_model
            tc, train_art, rep = plan_for_model(
                Model(cfg), mesh, tc, budget_ratio=adapt_budget)
            result["bit_plan"] = rep
            calibrate = False
        else:
            train_art = make_train_step(Model(cfg), mesh, tc)
        result["comm_accounting"] = comm_bytes_per_step(train_art, tc)

    lowered = _lower_one(cfg, kind, mesh, gbatch, seq, enc_seq, W,
                         batch_shardable, train_overrides,
                         train_art=train_art)
    t_lower = time.time()
    compiled = lowered.compile()
    t_compile = time.time()

    ca = _cost_analysis(compiled)
    ma = compiled.memory_analysis()

    flops = float(ca.get("flops", 0.0))
    bytes_acc = float(ca.get("bytes accessed", 0.0))
    coll_bytes = parse_collectives(lowered.as_text())["total"]
    coll_detail = None

    if calibrate:
        pts = []
        for L in (2, 3):
            reps = {"n_layers": L, "scan_unroll": True}
            if cfg.encoder_layers:
                reps["encoder_layers"] = L
            cfg_l = dataclasses.replace(cfg, **reps)
            lw = _lower_one(cfg_l, kind, mesh, gbatch, seq, enc_seq, W,
                            batch_shardable, train_overrides)
            coll = parse_collectives(lw.as_text())
            cp = lw.compile()
            cal = _cost_analysis(cp)
            pts.append((float(cal.get("flops", 0.0)),
                        float(cal.get("bytes accessed", 0.0)),
                        coll["total"], coll))
        L_true = cfg.n_layers
        L1 = 2
        df = pts[1][0] - pts[0][0]
        db = pts[1][1] - pts[0][1]
        dc = pts[1][2] - pts[0][2]
        flops = pts[0][0] + (L_true - L1) * df
        bytes_acc = pts[0][1] + (L_true - L1) * db
        coll_bytes = pts[0][2] + (L_true - L1) * dc
        coll_detail = {
            k: pts[0][3][k] + (L_true - L1) * (pts[1][3][k] - pts[0][3][k])
            for k in ("all-gather", "all-reduce", "reduce-scatter",
                      "all-to-all", "collective-permute")}
    t_cal = time.time()

    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = bytes_acc / HBM_BW
    t_coll = coll_bytes / ICI_BW_PER_LINK
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    bottleneck = max(terms, key=terms.get)

    n_params = cfg.n_params()
    n_active = cfg.n_active_params()
    if kind == "train":
        model_flops = 6 * n_active * gbatch * seq / n_dev
    elif kind == "prefill":
        model_flops = 2 * n_active * gbatch * seq / n_dev
    else:
        model_flops = 2 * n_active * gbatch / n_dev

    result.update({
        "lower_s": round(t_lower - t_start, 2),
        "compile_s": round(t_compile - t_lower, 2),
        "calibrate_s": round(t_cal - t_compile, 2),
        "hlo_flops": flops, "hlo_bytes": bytes_acc,
        "collective_bytes": coll_bytes,
        "collectives": coll_detail,
        "roofline": terms, "bottleneck": bottleneck.replace("_s", ""),
        "model_flops": model_flops,
        "useful_flops_ratio": (model_flops / flops) if flops else None,
        "n_params": n_params, "n_active_params": n_active,
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "code_bytes": ma.generated_code_size_in_bytes,
        },
    })
    return result


def main():
    # must precede the first jax import (the lazy imports inside the
    # compile helpers): jax locks the device count at first init
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=512")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL here")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs (test harness)")
    ap.add_argument("--no-calibrate", action="store_true")
    ap.add_argument("--adaptive", action="store_true",
                    help="train shapes: solve the repro.adapt bit plan "
                         "and report per-leaf lanes + projected wire "
                         "bytes (implies --no-calibrate for them)")
    ap.add_argument("--adapt-budget", type=float, default=0.6,
                    help="a2a byte budget vs the fixed log-grid wire")
    ap.add_argument("--train-overrides", default=None,
                    help="json dict of TrainConfig overrides")
    ap.add_argument("--model-overrides", default=None,
                    help='json dict, e.g. {"moe.dispatch":"sort"}')
    args = ap.parse_args()

    from repro.configs import ARCH_IDS, INPUT_SHAPES

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    overrides = json.loads(args.train_overrides) if args.train_overrides \
        else None
    m_overrides = json.loads(args.model_overrides) if args.model_overrides \
        else None

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
                mesh_override = None
                if args.smoke:
                    import jax
                    mesh_override = (
                        jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
                        if mp else jax.make_mesh((2, 2), ("data", "model")))
                    tag = f"{arch} x {shape} x smoke-{'2x2x2' if mp else '2x2'}"
                try:
                    res = build_and_compile(
                        arch, shape, mp, mesh_override=mesh_override,
                        smoke=args.smoke, train_overrides=overrides,
                        model_overrides=m_overrides,
                        calibrate=not args.no_calibrate,
                        adaptive=args.adaptive,
                        adapt_budget=args.adapt_budget)
                    res["multi_pod"] = mp
                    if overrides:
                        res["train_overrides"] = overrides
                    if m_overrides:
                        res["model_overrides"] = m_overrides
                    if res.get("skipped"):
                        print(f"[SKIP] {tag}: {res['reason']}", flush=True)
                    else:
                        r = res["roofline"]
                        print(
                            f"[OK] {tag}: flops={res['hlo_flops']:.3g} "
                            f"bytes={res['hlo_bytes']:.3g} "
                            f"coll={res['collective_bytes']:.3g} "
                            f"bottleneck={res['bottleneck']} "
                            f"(c={r['compute_s']:.4f}s m={r['memory_s']:.4f}s"
                            f" x={r['collective_s']:.4f}s) "
                            f"useful={res['useful_flops_ratio'] and round(res['useful_flops_ratio'], 3)} "
                            f"compile={res['compile_s']}s", flush=True)
                        if res.get("bit_plan"):
                            bp = res["bit_plan"]
                            lanes = {}
                            for row in bp["rows"]:
                                lanes[row["spec"]] = \
                                    lanes.get(row["spec"], 0) + 1
                            print(
                                f"     bit plan: "
                                + " ".join(f"{s}x{n}" for s, n
                                           in sorted(lanes.items()))
                                + f" | a2a {bp['plan_bytes']}B/step "
                                f"(budget {bp['budget_bytes']}B, fixed "
                                f"{bp['baseline_bytes']}B)", flush=True)
                except Exception as ex:  # noqa
                    res = {"arch": arch, "shape": shape, "multi_pod": mp,
                           "error": f"{type(ex).__name__}: {ex}"}
                    print(f"[FAIL] {tag}: {res['error']}", flush=True)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(res) + "\n")


if __name__ == "__main__":
    main()
