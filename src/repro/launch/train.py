"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
      --steps 50 --data 2 --model 2 --grad-bits 4 --weight-bits 7

Runs QAdam-EF distributed training (Algorithms 2+3) on a local mesh (or
the production mesh under a real TPU runtime) through ``TrainSession``:
batches are prefetched and staged to device on a background thread,
losses stay device-resident between log boundaries, and checkpoints are
written asynchronously. `--mode dp_adam` gives the conventional
data-parallel Adam baseline; `--no-ef` ablates error feedback;
`--grad-bits/--weight-bits 0` turn each quantized channel off.

`--steps` is the TOTAL step budget: with `--resume`, the session restores
the newest checkpoint under `--ckpt-dir` (step counter, optimizer/PRNG
state, and data-stream position - bit-identical to never stopping) and
runs only the remaining steps. `--adaptive --resume` additionally
restores the checkpointed bit plan and stats EMA.

`--topology NxD` exchanges quantized updates hierarchically
(``repro.dist.topology``): fp gradients reduce over the fast intra-node
tier first, the quantized+EF exchange crosses only the node tier.
`--multihost` initializes ``jax.distributed`` for one-process-per-host
runs; CI simulates hosts with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
"""
from __future__ import annotations

import argparse
import json

import numpy as np


def _run_adaptive(args, model, mesh, tc):
    """--adaptive path: drive the run through the repro.adapt
    controller (stats ring -> bit allocation -> codec swaps at replan
    boundaries) instead of a plain session."""
    import jax
    import math
    from repro.adapt.controller import AdaptConfig, AdaptiveController
    from repro.configs import get_config
    from repro.data.pipeline import batch_for_model
    from repro.train.session import SessionConfig

    cfg = get_config(args.arch, smoke=args.smoke)
    batches = batch_for_model(cfg, args.seq, args.global_batch,
                              seed=args.seed)
    sc = SessionConfig(log_every=args.log_every,
                       ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
                       ckpt_keep=args.ckpt_keep, ckpt_codec=args.ckpt_codec,
                       scan_chunk=args.scan_chunk, prefetch=args.prefetch,
                       aot_dir=args.aot_dir)
    acfg = AdaptConfig(budget_ratio=args.adapt_budget,
                       replan_every=args.replan_every,
                       ema_decay=args.adapt_ema)
    ctl = AdaptiveController(model, mesh, tc, batches, acfg, sc,
                             key=jax.random.PRNGKey(args.seed),
                             verify=args.adapt_verify)
    print(f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"workers={ctl.art.n_workers}")
    try:
        start = ctl.resume(args.ckpt_dir) if args.resume else 0
        if start:
            print(f"resumed from step {start} ({args.ckpt_dir}), "
                  f"plan restored: "
                  f"{_plan_summary(ctl.tc.bit_plan) if ctl.tc.bit_plan else 'initial log grid'}")
        remaining = args.steps - start
        if remaining <= 0:
            print(f"nothing to do: checkpoint at step {start} >= "
                  f"--steps {args.steps}")
            return
        ctl.run(remaining)
        windows = math.ceil(remaining / args.replan_every)
        if args.adapt_verify:
            # every plan already passed accounted == measured (see
            # AdaptiveController verify); here: the only host syncs are
            # the per-window stats harvests + the log-boundary loss
            # harvests - nothing per step.
            expected = windows if args.log_every == 0 else None
            if expected is not None:
                assert ctl.stats["syncs"] == expected, \
                    (f"{ctl.stats['syncs']} syncs != {expected} "
                     f"replan windows: a per-step host sync crept in")
            print(f"adapt-verify OK: {len(ctl.plan_log)} plans exact, "
                  f"{ctl.stats['syncs']} syncs / {windows} windows")
        losses = [h for h in ctl.session.history if "loss" in h]
        if not losses:
            losses = [{"step": s, "loss": v}
                      for s, v in ctl.session.harvest_losses()]
    finally:
        ctl.close()
    print(f"session stats: {ctl.stats}")
    for e in ctl.plan_log:
        a2a = e["comm"]["update_exchange_bytes"]
        print(f"plan @{e['step']}: a2a {a2a/1e6:.3f}MB/step "
              f"({'initial log grid' if e['bit_plan'] is None else ''}"
              f"{'' if e['bit_plan'] is None else _plan_summary(e['bit_plan'])})")
    if args.history_out:
        with open(args.history_out, "w") as f:
            json.dump({"arch": args.arch, "history": ctl.session.history,
                       "plan_log": [
                           {"step": e["step"], "comm": e["comm"],
                            "bit_plan": (list(e["bit_plan"])
                                         if e["bit_plan"] else None)}
                           for e in ctl.plan_log],
                       "stats": ctl.stats}, f, indent=1)
    if losses:
        print("final loss:", losses[-1]["loss"])


def _plan_summary(plan):
    counts = {}
    for spec in plan:
        counts[spec] = counts.get(spec, 0) + 1
    return " ".join(f"{s}x{n}" for s, n in sorted(counts.items()))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100,
                    help="total step budget (resume counts toward it)")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--data", type=int, default=1, help="data axis size")
    ap.add_argument("--model", type=int, default=1, help="model axis size")
    ap.add_argument("--pod", type=int, default=0, help="pod axis size")
    ap.add_argument("--topology", default=None, metavar="SPEC",
                    help="worker exchange topology: 'flat' (default) or "
                         "'NxD' = HierarchicalTopology(nodes=N, "
                         "devices_per_node=D); NxD implies --pod N "
                         "--data D when those are left default")
    ap.add_argument("--multihost", action="store_true",
                    help="initialize jax.distributed before device "
                         "queries (one process per host)")
    ap.add_argument("--coordinator", default=None, metavar="ADDR",
                    help="--multihost coordinator address host:port")
    ap.add_argument("--num-processes", type=int, default=None,
                    help="--multihost total process count")
    ap.add_argument("--process-id", type=int, default=None,
                    help="--multihost rank of this process")
    ap.add_argument("--tune-buckets", action="store_true",
                    help="sweep exchange_bucket_bytes against measured "
                         "step time before training and run with the "
                         "winner (perf.autotune.tune_exchange_buckets)")
    ap.add_argument("--alpha", type=float, default=1e-3)
    ap.add_argument("--beta", type=float, default=0.99)
    ap.add_argument("--theta", type=float, default=0.999)
    ap.add_argument("--schedule", default="constant")
    ap.add_argument("--grad-bits", type=int, default=6,
                    help="log-grid k_g; 0 = fp32 wire")
    ap.add_argument("--weight-bits", type=int, default=6,
                    help="uniform k_x; 0 = bf16 wire")
    ap.add_argument("--weight-absolute", action="store_true",
                    help="the paper's absolute [-0.5,0.5] grid")
    ap.add_argument("--model-gather-quant", type=int, default=0,
                    help="int8 FSDP gather bits (beyond-paper), 0=off")
    ap.add_argument("--no-ef", action="store_true")
    ap.add_argument("--mode", default="qadam",
                    choices=["qadam", "efadam", "dp_adam", "terngrad",
                             "ef_sgd", "adaptive"])
    ap.add_argument("--adaptive", action="store_true",
                    help="runtime-adaptive per-leaf bit allocation "
                         "(repro.adapt): stats-driven replans every "
                         "--replan-every steps under --adapt-budget")
    ap.add_argument("--adapt-budget", type=float, default=0.6,
                    help="a2a byte budget as a fraction of the fixed "
                         "log:6 wire")
    ap.add_argument("--replan-every", type=int, default=25)
    ap.add_argument("--adapt-ema", type=float, default=0.8,
                    help="stats EMA decay per step")
    ap.add_argument("--adapt-verify", action="store_true",
                    help="assert exact byte accounting at every plan "
                         "and zero steady-state host syncs")
    ap.add_argument("--scan-chunk", type=int, default=1,
                    help=">1: lax.scan this many steps per compiled call")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="batches staged to device ahead (0 = sync pulls)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-keep", type=int, default=3,
                    help="versioned checkpoints kept (keep-last-N)")
    ap.add_argument("--ckpt-codec", default=None,
                    help="repro.comm codec spec for compressed moment "
                         "snapshots, e.g. uniform_amax:7:w8 (lossy)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest checkpoint under --ckpt-dir")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--history-out", default=None)
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="persistent XLA compilation cache dir (default "
                         "$REPRO_COMPILE_CACHE or ~/.cache/repro/xla)")
    ap.add_argument("--no-compile-cache", action="store_true")
    ap.add_argument("--aot-dir", default=None, metavar="DIR",
                    help="AOT step-artifact dir: restart/resume loads the "
                         "serialized compiled train step instead of "
                         "tracing+compiling (repro.perf.aot)")
    args = ap.parse_args()
    if args.resume and not args.ckpt_dir:
        ap.error("--resume requires --ckpt-dir")
    args.adaptive = args.adaptive or args.mode == "adaptive"
    if args.multihost:
        if not (args.coordinator and args.num_processes is not None
                and args.process_id is not None):
            ap.error("--multihost requires --coordinator, "
                     "--num-processes and --process-id")
        import jax
        jax.distributed.initialize(args.coordinator, args.num_processes,
                                   args.process_id)

    import jax
    from repro import perf
    if not args.no_compile_cache:
        cache_dir = perf.enable_persistent_cache(args.compile_cache)
        if cache_dir:
            print(f"compile cache: {cache_dir}")
    from repro.configs import get_config
    from repro.models.model import Model
    from repro.launch.mesh import make_local_mesh
    from repro.dist.step import make_train_step, TrainConfig
    from repro.train.loop import comm_bytes_per_step
    from repro.train.session import SessionConfig, TrainSession
    from repro.data.pipeline import batch_for_model

    from repro.dist import topology as T
    topo = T.parse_topology(args.topology)
    if isinstance(topo, T.HierarchicalTopology):
        n, d = topo.nodes, topo.devices_per_node
        if args.pod == 0 and args.data == 1:
            # NxD picks the mesh too: pod = node axis, data = intra axis
            args.pod, args.data = n, d
        elif max(args.pod, 1) * args.data != n * d:
            ap.error(f"--topology {args.topology} needs {n * d} workers "
                     f"but --pod/--data give "
                     f"{max(args.pod, 1) * args.data}")

    cfg = get_config(args.arch, smoke=args.smoke)
    model = Model(cfg)
    mesh = make_local_mesh(data=args.data, model=args.model, pod=args.pod)
    tc = TrainConfig(
        alpha=args.alpha, beta=args.beta, theta=args.theta,
        schedule=args.schedule,
        grad_k=args.grad_bits or None,
        weight_k=args.weight_bits or None,
        weight_absolute=args.weight_absolute,
        model_gather_quant=args.model_gather_quant or None,
        error_feedback=not args.no_ef,
        worker_axes=("pod", "data"),
        topology=topo,
        mode="adaptive" if args.adaptive else args.mode)
    if args.tune_buckets:
        from repro.perf.autotune import tune_exchange_buckets
        # probe batch from a fresh same-seed generator: the training
        # stream position is untouched
        probe = next(batch_for_model(cfg, args.seq, args.global_batch,
                                     seed=args.seed))
        rep = tune_exchange_buckets(model, mesh, tc, probe)
        tc = rep["config"]
        print(f"tuned exchange bucket: {rep['best']} B "
              f"(speedup {rep['speedup']:.2f}x vs default "
              f"{rep['default']} B)")
    if args.adaptive:
        _run_adaptive(args, model, mesh, tc)
        return
    art = make_train_step(model, mesh, tc)
    comm = comm_bytes_per_step(art, tc)
    print(f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"workers={art.n_workers}")
    print(f"comm/device/step: exchange={comm['update_exchange_bytes']/1e6:.2f}MB "
          f"broadcast={comm['weight_broadcast_bytes']/1e6:.2f}MB")
    if comm["tiers"]["intra"]["total"]:
        print(f"  per tier: inter={comm['tiers']['inter']['total']/1e6:.2f}MB "
              f"intra={comm['tiers']['intra']['total']/1e6:.2f}MB")

    batches = batch_for_model(cfg, args.seq, args.global_batch,
                              seed=args.seed)
    sc = SessionConfig(log_every=args.log_every, ckpt_every=args.ckpt_every,
                       ckpt_dir=args.ckpt_dir, ckpt_keep=args.ckpt_keep,
                       ckpt_codec=args.ckpt_codec,
                       scan_chunk=args.scan_chunk, prefetch=args.prefetch,
                       aot_dir=args.aot_dir)
    sess = TrainSession.from_artifacts(art, batches, sc,
                                       key=jax.random.PRNGKey(args.seed))
    try:
        start = sess.resume(args.ckpt_dir) if args.resume else 0
        if start:
            print(f"resumed from step {start} ({args.ckpt_dir})")
        remaining = args.steps - start
        if remaining <= 0:
            print(f"nothing to do: checkpoint at step {start} >= "
                  f"--steps {args.steps}")
            return
        sess.run(remaining)
        losses = [h for h in sess.history if "loss" in h]
        if not losses:   # --log-every 0: nothing harvested during run
            losses = [{"step": s, "loss": v}
                      for s, v in sess.harvest_losses()]
    finally:
        sess.close()
    history = sess.history
    print(f"session stats: {sess.stats}")
    if args.history_out:
        with open(args.history_out, "w") as f:
            json.dump({"arch": args.arch, "history": history,
                       "comm": comm, "stats": sess.stats}, f, indent=1)
    if losses:
        print("final loss:", losses[-1]["loss"])


if __name__ == "__main__":
    main()
