"""Production mesh builders.

A FUNCTION (not module-level state) so importing never touches jax device
initialization. Production target: TPU v5e, 16x16 = 256 chips per pod;
multi-pod adds a leading 'pod' axis (2 pods = 512 chips).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Mesh over however many local devices exist (tests/examples)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


# v5e hardware model for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW_PER_LINK = 50e9        # B/s per link
