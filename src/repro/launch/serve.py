"""Serving launcher: continuous-batching ServeSession with (optionally)
code-resident Q_x weights (the paper's 'Size' column, held as int codes).

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
      --requests 8 --slots 4 --max-new 16 --quantized

Submitting more requests than slots exercises the scheduler: queued
requests claim slots mid-flight as earlier ones finish.
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--quantized", action="store_true",
                    help="code-resident Q_x weights (packed codes + scales;"
                         " projections run the fused dequant-matmul)")
    ap.add_argument("--k-x", type=int, default=6)
    ap.add_argument("--no-pack", action="store_true",
                    help="keep codes unpacked (one int8/int16 per code)"
                         " instead of the registry's 3/4/6-bit lanes")
    ap.add_argument("--no-fused-matmul", action="store_true",
                    help="dequantize-then-matmul instead of contracting"
                         " straight from codes (debug/perf comparison)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: one physical page pool + per-slot"
                         " page tables; concurrency is bounded by tokens in"
                         " flight, not slots * max_seq")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per page (max_seq must be a multiple)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="physical pool pages (default: fixed-lane-equal"
                         " memory, slots * max_seq / page_size)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="chunked-prefill tokens per admission dispatch")
    ap.add_argument("--slo-mix", action="store_true",
                    help="tag requests round-robin interactive/standard/"
                         "batch to exercise priority admission+preemption")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="persistent XLA compilation cache dir (default "
                         "$REPRO_COMPILE_CACHE or ~/.cache/repro/xla)")
    ap.add_argument("--no-compile-cache", action="store_true")
    ap.add_argument("--aot-dir", default=None, metavar="DIR",
                    help="AOT artifact dir for the compiled decode step "
                         "(repro.perf.aot): warm restarts skip compilation")
    args = ap.parse_args()

    import jax
    from repro import perf
    if not args.no_compile_cache:
        cache_dir = perf.enable_persistent_cache(args.compile_cache)
        if cache_dir:
            print(f"compile cache: {cache_dir}")
    import numpy as np
    from repro.configs import get_config
    from repro.models.model import Model
    from repro.serve import (Request, ServeSession, params_nbytes,
                             quantize_params)

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.arch_type == "encdec" or cfg.input_mode != "tokens":
        raise SystemExit("serve CLI demo supports token-input decoder LMs")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    fp_bytes = params_nbytes(params)
    if args.quantized:
        params = quantize_params(params, k_x=args.k_x,
                                 pack=not args.no_pack)
        q_bytes = params_nbytes(params)
        print(f"arch={args.arch} params={fp_bytes / 1e6:.1f}MB fp32 -> "
              f"{q_bytes / 1e6:.1f}MB resident codes "
              f"({q_bytes / fp_bytes:.2f}x, measured)")
    else:
        print(f"arch={args.arch} params={fp_bytes / 1e6:.1f}MB fp32")

    session = ServeSession(model, params, slots=args.slots,
                           max_seq=args.max_seq, seed=args.seed,
                           aot_dir=args.aot_dir,
                           fused_matmul=not args.no_fused_matmul,
                           paged=args.paged, page_size=args.page_size,
                           num_pages=args.num_pages,
                           prefill_chunk=args.prefill_chunk)
    if args.paged:
        print(f"paged cache: {session.num_pages} pages x "
              f"{session.page_size} tokens "
              f"({session.num_pages * session.page_size} tokens vs "
              f"{args.slots * args.max_seq} fixed-lane)")
    rng = np.random.default_rng(args.seed)
    slos = ["interactive", "standard", "batch"]
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size,
                                             size=args.prompt_len)),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature,
                    slo=slos[i % 3] if args.slo_mix else "standard")
            for i in range(args.requests)]
    t0 = time.time()
    handles = [session.submit(r) for r in reqs]
    results = session.drain()
    dt = time.time() - t0
    total_new = sum(len(results[h].tokens) for h in handles)
    print(f"generated {total_new} tokens over {args.requests} requests on "
          f"{args.slots} slots in {dt:.2f}s ({total_new / dt:.1f} tok/s); "
          f"stats={session.stats}")
    for i, h in enumerate(handles):
        r = results[h]
        print(f"  req{i}: {r.tokens[:12]}{'...' if len(r.tokens) > 12 else ''}"
              f" [{r.finish_reason}]")


if __name__ == "__main__":
    main()
