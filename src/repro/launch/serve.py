"""Serving launcher: batched generation with (optional) quantized-resident
weights (Q_x model-size reduction, paper Tables 2-3 'Size' column).

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
      --requests 4 --max-new 16 --quantized
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--quantized", action="store_true",
                    help="int-coded resident weights (k_x=6)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models.model import Model
    from repro.serve.engine import Engine, Request

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.arch_type == "encdec" or cfg.input_mode != "tokens":
        raise SystemExit("serve CLI demo supports token-input decoder LMs")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    nbytes = sum(int(np.prod(l.shape)) * 4 for l in jax.tree.leaves(params))
    print(f"arch={args.arch} params={nbytes/1e6:.1f}MB fp32"
          + (" (serving int-coded, ~/4)" if args.quantized else ""))

    eng = Engine(model, params, max_seq=args.max_seq,
                 quantized=args.quantized)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size,
                                             size=args.prompt_len)),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature)
            for _ in range(args.requests)]
    t0 = time.time()
    results = eng.generate(reqs)
    dt = time.time() - t0
    total_new = sum(len(r.tokens) for r in results)
    print(f"generated {total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s batched)")
    for i, r in enumerate(results):
        print(f"  req{i}: {r.tokens[:12]}{'...' if len(r.tokens) > 12 else ''}")


if __name__ == "__main__":
    main()
