"""Fused dequant-matmul (repro.comm.matmul): the contract is BITWISE
equality with dequantize-then-jnp.dot at every supported lane width
(3/4/6-bit packed, 8/16-bit raw), per-tensor and per-layer scales, both
backends, both orientations, plus the row-gather (embedding) path and
the shape-fallback rules.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import matmul as MM
from repro.serve.quantized import quantize_params

# k_x -> registry lane width: 3/4/6-bit lanes pack, 8/16-bit stay raw
KX_CASES = [(1, 3), (2, 4), (4, 6), (6, 8), (14, 16)]
BACKENDS = ["jnp", "pallas"]  # pallas = interpret mode off-TPU


def _leaf(k_x, shape, *, stacked=False, key=0):
    w = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    params = {"blocks": {"w": w}} if stacked else {"w": w}
    q = quantize_params(params, k_x=k_x, min_numel=1, pack=True)
    return (w, q["blocks"]["w"] if stacked else q["w"])


class TestBitwiseParity:
    @pytest.mark.parametrize("k_x,bits", KX_CASES)
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_per_tensor(self, k_x, bits, backend):
        _, leaf = _leaf(k_x, (40, 384))
        assert leaf.pack_bits == (bits if bits < 8 else 0)
        x = jax.random.normal(jax.random.PRNGKey(1), (5, 40), jnp.float32)
        ref = jax.jit(lambda x: x @ leaf.dequantize().astype(x.dtype))(x)
        got = jax.jit(lambda x: leaf.astype(x.dtype).matmul(
            x, backend=backend))(x)
        assert got.dtype == ref.dtype
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))

    @pytest.mark.parametrize("k_x,bits", KX_CASES)
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_per_layer_scales(self, k_x, bits, backend):
        # stacked (L, K, N) leaf: one amax scale per layer, shape (L,)
        _, leaf = _leaf(k_x, (3, 24, 256), stacked=True)
        assert leaf.scale.shape == (3,)
        x = jax.random.normal(jax.random.PRNGKey(2), (3, 4, 24), jnp.float32)
        deq = leaf.dequantize()  # (L, K, N)
        ref = jnp.stack([x[l] @ deq[l].astype(x.dtype) for l in range(3)])
        got = leaf.astype(x.dtype).matmul(x, backend=backend)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))

    @pytest.mark.parametrize("k_x,bits", KX_CASES)
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_transpose(self, k_x, bits, backend):
        # tied-embedding head orientation: logits = x @ W.T, W (V, d)
        _, leaf = _leaf(k_x, (256, 48))
        x = jax.random.normal(jax.random.PRNGKey(3), (6, 48), jnp.float32)
        ref = jax.jit(lambda x: x @ leaf.dequantize().astype(x.dtype).T)(x)
        got = jax.jit(lambda x: leaf.astype(x.dtype).matmul_t(
            x, backend=backend))(x)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))

    def test_reflection_dispatch(self):
        # models write ``x @ w.astype(x.dtype)``; jax arrays defer to the
        # leaf's __rmatmul__, so that exact spelling hits the fused path
        _, leaf = _leaf(6, (32, 128))
        x = jax.random.normal(jax.random.PRNGKey(4), (2, 32), jnp.float32)
        ref = x @ leaf.dequantize().astype(x.dtype)
        got = jax.jit(lambda x: x @ leaf.astype(x.dtype))(x)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))

    def test_cast_chain_bf16(self):
        # dequant -> leaf dtype -> activation dtype must stay two casts;
        # bf16 activations catch any collapsed-cast shortcut
        _, leaf = _leaf(2, (32, 256))
        x = jax.random.normal(jax.random.PRNGKey(5), (3, 32), jnp.bfloat16)
        ref = x @ leaf.dequantize().astype(x.dtype)
        for backend in BACKENDS:
            got = leaf.astype(x.dtype).matmul(x, backend=backend)
            assert got.dtype == ref.dtype
            np.testing.assert_array_equal(
                np.asarray(ref, np.float32), np.asarray(got, np.float32))

    def test_batched_lead_dims(self):
        # (B, S, K) activations flatten through the same kernel
        _, leaf = _leaf(2, (32, 128))
        x = jax.random.normal(jax.random.PRNGKey(6), (2, 5, 32), jnp.float32)
        ref = x @ leaf.dequantize().astype(x.dtype)
        for backend in BACKENDS:
            got = leaf.astype(x.dtype).matmul(x, backend=backend)
            np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


class TestTake:
    @pytest.mark.parametrize("k_x", [2, 6])
    def test_row_gather_matches_full_dequant(self, k_x):
        _, leaf = _leaf(k_x, (64, 96))
        idx = jnp.asarray([[0, 63, 7], [12, 12, 1]])
        ref = leaf.dequantize()[idx]
        got = jax.jit(leaf.take)(idx)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))

    def test_cast_applied(self):
        _, leaf = _leaf(2, (16, 96))
        idx = jnp.asarray([3, 1])
        got = leaf.astype(jnp.bfloat16).take(idx)
        assert got.dtype == jnp.bfloat16
        ref = leaf.dequantize()[idx].astype(jnp.bfloat16)
        np.testing.assert_array_equal(
            np.asarray(ref, np.float32), np.asarray(got, np.float32))


class TestFallbacks:
    def test_uncovered_width_falls_back_bitwise(self):
        # n=100 is not a multiple of mm_cols(): the pallas request must
        # silently take the dequantize-then-matmul path, same bits out
        _, leaf = _leaf(6, (24, 100))
        x = jax.random.normal(jax.random.PRNGKey(7), (4, 24), jnp.float32)
        ref = x @ leaf.dequantize().astype(x.dtype)
        got = leaf.astype(x.dtype).matmul(x, backend="pallas")
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))

    def test_auto_backend_is_jnp_off_tpu(self):
        if jax.default_backend() == "tpu":
            pytest.skip("auto resolves to pallas on TPU")
        _, leaf = _leaf(6, (24, 128))
        x = jax.random.normal(jax.random.PRNGKey(8), (4, 24), jnp.float32)
        ref = x @ leaf.dequantize().astype(x.dtype)
        got = leaf.astype(x.dtype).matmul(x)  # backend=None -> auto
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


class TestMmCols:
    def test_set_and_clear_override(self):
        bk = jax.default_backend()
        assert MM.mm_cols() == MM.MM_COLS
        try:
            MM.set_mm_cols(256, backend=bk)
            assert MM.mm_cols() == 256
        finally:
            MM.set_mm_cols(None, backend=bk)
        assert MM.mm_cols() == MM.MM_COLS

    def test_rejects_non_multiple_of_128(self):
        with pytest.raises(ValueError):
            MM.set_mm_cols(96)

    def test_wider_tile_still_bitwise(self):
        _, leaf = _leaf(2, (32, 512))
        x = jax.random.normal(jax.random.PRNGKey(9), (4, 32), jnp.float32)
        ref = x @ leaf.dequantize().astype(x.dtype)
        try:
            MM.set_mm_cols(256)
            got = leaf.astype(x.dtype).matmul(x, backend="pallas")
        finally:
            MM.set_mm_cols(None)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))
