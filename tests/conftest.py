"""Test bootstrap: make `pytest -x -q` work from the repo root without the
PYTHONPATH=src incantation, and register the `slow` marker used by the
subprocess-based multi-device suite."""
import os
import sys

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
# subprocess tests (tests/dist_scripts) inherit the environment, not
# sys.path - keep both in sync.
os.environ["PYTHONPATH"] = _SRC + (
    os.pathsep + os.environ["PYTHONPATH"]
    if os.environ.get("PYTHONPATH") else "")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: multi-device subprocess tests (compile-heavy; deselect "
        "with -m 'not slow')")
