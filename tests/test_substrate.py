"""Data pipeline, checkpointing, serve engine, and train-loop tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import Model
from repro.data import pipeline as dp
from repro.checkpoint import store
from repro.serve.engine import Engine, Request


class TestDataPipeline:
    def test_lm_batches_deterministic(self):
        cfg = dp.LMDataConfig(vocab_size=100, seq_len=32, global_batch=4,
                              seed=7)
        a = next(dp.lm_batches(cfg))
        b = next(dp.lm_batches(cfg))
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))
        assert a["tokens"].shape == (4, 32)
        # targets are next-token shifted
        full_a = np.asarray(a["tokens"])
        full_t = np.asarray(a["targets"])
        np.testing.assert_array_equal(full_a[:, 1:], full_t[:, :-1])

    def test_induction_structure_learnable(self):
        """Copy structure means a bigram/induction learner beats unigram."""
        cfg = dp.LMDataConfig(vocab_size=50, seq_len=128, global_batch=2,
                              seed=0, copy_period=32)
        b = next(dp.lm_batches(cfg))
        toks = np.asarray(b["tokens"])
        # inside each period, second half == first half
        assert (toks[:, 16:32] == toks[:, 0:16]).all()

    def test_model_aware_batches(self):
        for arch in ("llava-next-mistral-7b", "whisper-small"):
            cfg = get_config(arch, smoke=True)
            b = next(dp.batch_for_model(cfg, 16, 2))
            if cfg.input_mode == "embeddings":
                assert b["embeds"].shape == (2, 16, cfg.d_model)
            if cfg.input_mode == "audio+tokens":
                assert b["audio"].shape == (2, cfg.encoder_seq, cfg.d_model)

    def test_classification_dataset(self):
        x, y, xt, yt = dp.classification_dataset(dp.ClsDataConfig(
            n_train=256, n_test=64))
        assert x.shape == (256, 32) and yt.shape == (64,)
        bx, by = next(dp.classification_batches(x, y, 32))
        assert bx.shape == (32, 32)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(10, dtype=jnp.float32),
                "b": {"c": jnp.ones((3, 4), jnp.bfloat16),
                      "d": jnp.int32(7)}}
        store.save(str(tmp_path), tree, step=42)
        like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
        out = store.restore(str(tmp_path), like)
        for k, (u, v) in enumerate(zip(jax.tree.leaves(tree),
                                       jax.tree.leaves(out))):
            np.testing.assert_array_equal(np.asarray(u, np.float32),
                                          np.asarray(v, np.float32))
        assert store.latest_step(str(tmp_path)) == 42

    def test_train_state_roundtrip(self, tmp_path):
        from repro.core.qadam import QAdamConfig, qadam
        params = {"w": jnp.ones((8, 8))}
        opt = qadam(QAdamConfig())
        state = opt.init(params)
        store.save(str(tmp_path), {"params": params, "opt": state._asdict()},
                   step=1)
        out = store.restore(str(tmp_path),
                            {"params": params, "opt": state._asdict()})
        assert out["opt"]["count"] == 0


class TestServeEngine:
    def test_generate_batched(self):
        cfg = get_config("yi-6b", smoke=True)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = Engine(model, params, max_seq=48)
        reqs = [Request(prompt=[5, 6, 7, 8], max_new_tokens=6),
                Request(prompt=[9, 10, 11, 12], max_new_tokens=6)]
        res = eng.generate(reqs)
        assert len(res) == 2
        assert all(len(r.tokens) == 6 for r in res)
        assert all(0 <= t < cfg.vocab_size for r in res for t in r.tokens)

    def test_quantized_resident_consistency(self):
        cfg = get_config("yi-6b", smoke=True)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(1))
        reqs = [Request(prompt=[3, 4, 5, 6], max_new_tokens=4)]
        full = Engine(model, params, max_seq=32).generate(reqs)
        quant = Engine(model, params, max_seq=32,
                       quantized=True).generate(reqs)
        # mild perturbation: first token usually agrees
        assert full[0].tokens[0] == quant[0].tokens[0]

    def test_engine_matches_forward_greedy(self):
        """Engine's first generated token == argmax of forward logits."""
        cfg = get_config("gemma2-2b", smoke=True)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(2))
        prompt = [2, 3, 4, 5, 6, 7]
        batch = {"tokens": jnp.asarray([prompt], jnp.int32),
                 "targets": jnp.asarray([prompt], jnp.int32),
                 "mask": jnp.ones((1, len(prompt)), jnp.float32)}
        logits, _ = model.forward(params, batch)
        want = int(jnp.argmax(logits[0, -1]))
        res = Engine(model, params, max_seq=32).generate(
            [Request(prompt=prompt, max_new_tokens=2)])
        assert res[0].tokens[0] == want


class TestTrainLoop:
    def test_loop_runs_and_logs(self):
        from repro.launch.mesh import make_local_mesh
        from repro.dist.step import make_train_step, TrainConfig
        from repro.train.loop import train, LoopConfig, comm_bytes_per_step
        from repro.data.pipeline import batch_for_model

        cfg = get_config("yi-6b", smoke=True)
        model = Model(cfg)
        mesh = make_local_mesh(data=1, model=1)
        tc = TrainConfig(alpha=3e-3, grad_k=6, weight_k=None,
                         worker_axes=())
        art = make_train_step(model, mesh, tc)
        comm = comm_bytes_per_step(art, tc)
        assert comm["total_bytes"] > 0
        batches = batch_for_model(cfg, 32, 2, seed=0)
        logs = []
        state, hist = train(art, tc, batches,
                            LoopConfig(steps=8, log_every=4),
                            log=logs.append)
        assert len(hist) >= 2
        assert hist[-1]["loss"] < hist[0]["loss"] + 0.5
        assert any("loss" in l for l in logs)
