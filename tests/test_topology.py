"""repro.dist.topology: tier resolution, cache keying, per-tier byte
accounting, and the exchange-bucket tuner.

Everything here runs on ONE device (tier resolution and byte accounting
are mesh-free; the in-process train runs use a (1, 1) mesh). The real
2-node x 4-device hierarchical equivalence - bit-exact vs a sequential
two-worker Algorithm 2+3 reference - runs in a subprocess with 8
simulated devices (``tests/dist_scripts/topology_equiv.py``).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import comm
from repro.dist import topology as T
from repro.dist.modes import get_mode
from repro.dist.step import TrainConfig
from repro.perf import aot


class TestTiersResolution:
    def test_flat_spans_all_axes(self):
        t = T.FlatTopology().tiers(("pod", "data"), (2, 4))
        assert t.inter_axes == ("pod", "data")
        assert t.inter_sizes == (2, 4)
        assert t.intra_axes == () and t.intra_sizes == ()
        assert t.n_inter == 8 and t.n_intra == 1
        assert not t.hierarchical

    def test_hierarchical_prefix_split(self):
        t = T.HierarchicalTopology(2, 4).tiers(("pod", "data"), (2, 4))
        assert t.inter_axes == ("pod",) and t.inter_sizes == (2,)
        assert t.intra_axes == ("data",) and t.intra_sizes == (4,)
        assert t.n_inter == 2 and t.n_intra == 4
        assert t.hierarchical

    def test_multi_axis_inter_tier(self):
        t = T.HierarchicalTopology(8, 2).tiers(
            ("a", "b", "c"), (2, 4, 2))
        assert t.inter_axes == ("a", "b")
        assert t.intra_axes == ("c",)

    def test_single_axis_split_rejected(self):
        # nodes*devices matches the total but not an axis boundary
        with pytest.raises(ValueError, match="axis boundary"):
            T.HierarchicalTopology(2, 4).tiers(("data",), (8,))

    def test_wrong_total_rejected(self):
        with pytest.raises(ValueError):
            T.HierarchicalTopology(2, 4).tiers(("pod", "data"), (2, 2))

    def test_degenerate_one_by_one(self):
        t = T.HierarchicalTopology(1, 1).tiers(("data",), (1,))
        assert t.n_inter == 1 and t.n_intra == 1

    def test_flat_tiers_helper(self):
        assert T.flat_tiers(("data",), (4,)) \
            == T.FlatTopology().tiers(("data",), (4,))

    def test_parse(self):
        assert T.parse_topology(None) == T.FlatTopology()
        assert T.parse_topology("flat") == T.FlatTopology()
        assert T.parse_topology("2x4") == T.HierarchicalTopology(2, 4)
        topo = T.HierarchicalTopology(3, 2)
        assert T.parse_topology(topo) is topo
        with pytest.raises(ValueError, match="topology spec"):
            T.parse_topology("2x4x2")
        with pytest.raises(ValueError, match="topology spec"):
            T.parse_topology("fast")


class TestCacheKeys:
    """The topology must key every compile cache: TrainConfig hash (jit
    static arg / session step token) and the AOT facts digest."""

    def test_trainconfig_hash_distinct(self):
        flat = TrainConfig(topology=T.FlatTopology())
        hier = TrainConfig(topology=T.HierarchicalTopology(2, 4))
        hier2 = TrainConfig(topology=T.HierarchicalTopology(4, 2))
        assert len({hash(flat), hash(hier), hash(hier2)}) == 3
        assert flat != hier and hier != hier2

    def test_aot_digest_distinct(self):
        digs = {aot.digest(TrainConfig(topology=t)) for t in (
            T.FlatTopology(),
            T.HierarchicalTopology(2, 4),
            T.HierarchicalTopology(4, 2))}
        assert len(digs) == 3

    def test_default_equals_explicit_flat(self):
        # the default field value IS FlatTopology: no spurious recompile
        assert TrainConfig() == TrainConfig(topology=T.FlatTopology())


def _sliced_payload_nbytes(spec, numel, n_workers, n_src):
    """Ground truth for one leaf: encode a real tensor, keep the n_src
    rows that cross the exchange tier."""
    codec = comm.get_codec(spec)
    x = jnp.linspace(-1.0, 1.0, numel, dtype=jnp.float32)
    if isinstance(codec, comm.BlockwiseCodec):
        from repro.opt import engine
        codes2d, _ = engine.quantize_blockwise(x, codec.block)
        rows = comm.pad_rows(codes2d.reshape(-1)[:numel], n_workers)
        return comm.pack_rows(rows, codec.bits)[:n_src].nbytes
    payload, _ = comm.encode_rows(x, codec, n_workers,
                                  key=jax.random.PRNGKey(0))
    return payload[:n_src].nbytes


class TestLeafTierBytes:
    """Registry accounting == encoded payload bytes at every lane
    width, for flat and hierarchical tiers - all mesh-free."""

    HIER = T.Tiers(inter_axes=("pod",), inter_sizes=(2,),
                   intra_axes=("data",), intra_sizes=(4,))
    FLAT = T.flat_tiers(("pod", "data"), (2, 4))
    NUMEL, N_WORKERS = 8 * 97, 8   # c = 97: padding in play

    def _plan_tc(self, specs):
        return TrainConfig(mode="adaptive", worker_axes=("pod", "data"),
                           bit_plan=tuple(specs))

    @pytest.mark.parametrize("spec", sorted(
        __import__("repro.adapt.allocate", fromlist=["WIDTH_SPECS"])
        .WIDTH_SPECS.values()))
    def test_every_lane_width(self, spec):
        mode = get_mode("adaptive")
        tc = self._plan_tc([spec])
        c = self.NUMEL // self.N_WORKERS
        for tiers, n_src in ((self.FLAT, self.N_WORKERS),
                             (self.HIER, 2)):
            d = mode.leaf_tier_nbytes(tc, 0, c, self.NUMEL,
                                      self.N_WORKERS, tiers)
            want = _sliced_payload_nbytes(spec, self.NUMEL,
                                          self.N_WORKERS, n_src)
            assert d["inter"] == want, (spec, tiers, d, want)
        assert mode.leaf_tier_nbytes(
            tc, 0, c, self.NUMEL, self.N_WORKERS, self.HIER)["intra"] \
            == 4 * self.NUMEL * 4

    def test_flat_matches_legacy_wire_nbytes(self):
        mode = get_mode("qadam")
        tc = TrainConfig(grad_k=6)
        d = mode.leaf_tier_nbytes(tc, 0, 128, 1024, 8, self.FLAT)
        assert d == {"inter": mode.leaf_wire_nbytes(tc, 0, 128, 8),
                     "intra": 0}
        assert mode.leaf_tier_nbytes(tc, 0, 128, 1024, 8, None) == d

    def test_untiered_mode_ignores_hierarchy(self):
        mode = get_mode("dp_adam")
        assert not mode.tiered
        tc = TrainConfig(mode="dp_adam")
        d = mode.leaf_tier_nbytes(tc, 0, 128, 1024, 8, self.HIER)
        assert d["intra"] == 0
        assert d["inter"] == mode.leaf_wire_nbytes(tc, 0, 128, 8)

    def test_hier_inter_is_exact_fraction(self):
        mode = get_mode("qadam")
        tc = TrainConfig(grad_k=6)
        flat = mode.leaf_tier_nbytes(tc, 0, 128, 1024, 8, self.FLAT)
        hier = mode.leaf_tier_nbytes(tc, 0, 128, 1024, 8, self.HIER)
        assert flat["inter"] == 4 * hier["inter"]


@pytest.fixture(scope="module")
def small_setup():
    from repro.configs import get_config
    from repro.models.model import Model
    model = Model(get_config("yi-6b", smoke=True))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return model, mesh


def _batches(model, seed=0):
    k = jax.random.PRNGKey(seed)
    v = model.cfg.vocab_size
    while True:
        k, s = jax.random.split(k)
        tok = jax.random.randint(s, (2, 16), 0, v)
        yield {"tokens": tok, "targets": tok}


def _batch(model, seed=0):
    return next(_batches(model, seed))


class TestFlatIdentity:
    def test_default_vs_explicit_flat_bitwise(self, small_setup):
        from repro.dist.step import make_train_step
        model, mesh = small_setup
        batch = _batch(model)
        states = []
        for topo in (T.FlatTopology(), None):
            tc = TrainConfig(worker_axes=("data",))
            if topo is not None:
                tc = dataclasses.replace(tc, topology=topo)
            art = make_train_step(model, mesh, tc)
            assert art.tiers is not None and not art.tiers.hierarchical
            state = art.init_state(jax.random.PRNGKey(0))
            step = jax.jit(art.step_fn)
            for _ in range(2):
                state, metrics = step(state, batch)
            states.append(jax.tree.map(np.asarray, state))
        jax.tree.map(np.testing.assert_array_equal, *states)


class TestTopologyIsSwapCacheKey:
    def test_swap_artifacts_recompiles(self, small_setup):
        """Same mesh geometry, different topology object -> different
        TrainConfig -> a second compile cache entry (the step token is
        the config)."""
        from repro.dist.step import make_train_step
        from repro.train.session import SessionConfig, TrainSession
        model, mesh = small_setup
        tc1 = TrainConfig(worker_axes=("data",))
        tc2 = dataclasses.replace(
            tc1, topology=T.HierarchicalTopology(1, 1))
        art1 = make_train_step(model, mesh, tc1)
        sess = TrainSession.from_artifacts(
            art1, _batches(model), SessionConfig(log_every=0),
            key=jax.random.PRNGKey(0), log=lambda *_: None)
        try:
            sess.run(1)
            assert sess.stats["compilations"] == 1
            sess.swap_artifacts(make_train_step(model, mesh, tc2))
            sess.run(1)
            assert sess.stats["compilations"] == 2
            # swapping back must hit the cache, not recompile
            sess.swap_artifacts(art1)
            sess.run(1)
            assert sess.stats["compilations"] == 2
        finally:
            sess.close()


class TestBucketTuner:
    def test_tune_exchange_buckets(self, small_setup):
        from repro.perf.autotune import tune_exchange_buckets
        model, mesh = small_setup
        tc = TrainConfig(worker_axes=("data",))
        rep = tune_exchange_buckets(model, mesh, tc, _batch(model),
                                    candidates=(0, 1 << 20),
                                    steps=2, warmup=1)
        assert set(rep) == {"timings_s", "best", "default", "speedup",
                            "config"}
        # the incumbent joins the sweep, so tuned can never lose
        assert rep["speedup"] >= 1.0
        assert rep["default"] == tc.exchange_bucket_bytes
        assert rep["best"] in rep["timings_s"]
        assert rep["config"].exchange_bucket_bytes == rep["best"]
        assert rep["config"] == dataclasses.replace(
            tc, exchange_bucket_bytes=rep["best"])


@pytest.mark.slow
class TestHierarchicalEquivalence:
    def test_topology_equiv_2x4(self):
        """8 simulated devices: HierarchicalTopology(2, 4) bit-exact vs
        the sequential two-worker Algorithm 2+3 reference (qadam +
        efadam, EF residual carry included), flat degeneracy bitwise,
        per-tier accounting exact."""
        import os
        import subprocess
        import sys
        scripts = os.path.join(os.path.dirname(__file__), "dist_scripts")
        p = subprocess.run(
            [sys.executable, os.path.join(scripts, "topology_equiv.py")],
            capture_output=True, text=True, timeout=560)
        assert p.returncode == 0, f"{p.stdout}\n{p.stderr}"
        assert "OK" in p.stdout, p.stdout
