"""Sharded decode == single-device decode.

KV cache sequence-sharded over the model axis, batch over workers, int8
weight gather on; logits must match the unsharded decode path.

Usage: python serve_equiv.py <arch_id>
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

sys.path.insert(0, os.path.dirname(__file__))
from common import tiny_config

from repro.dist.serve import make_serve_step
from repro.dist.step import ServeConfig
from repro.dist import sharding as SH, collectives as C
from repro.models.model import Model
from repro.models.layers import ShardCtx
from repro.kernels import ref as KREF

arch = sys.argv[1] if len(sys.argv) > 1 else "yi-6b"
cfg = tiny_config(arch)
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))

B, S_MAX = 4, 32
mesh = jax.make_mesh((2, 2), ("data", "model"))
sc = ServeConfig(weight_k=6, weight_absolute=False, worker_axes=("data",))
step, param_specs, (input_specs, cache_specs) = make_serve_step(
    model, mesh, sc, kind="decode")

cache = model.init_cache(B, max_seq_local=S_MAX,
                         encoder_seq_local=cfg.encoder_seq or 0)
if cfg.arch_type == "encdec":
    audio = jax.random.normal(jax.random.PRNGKey(2),
                              (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    cache = model.prefill_encoder(params, audio, cache)

rng = np.random.default_rng(5)
toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B, 6), dtype=np.int32))

# ---- reference: single-device decode with Q_x(weights) (weight_k wire) ----
def qx(p, dim):
    if dim == SH.REPLICATED or p.ndim == 0:
        return p
    scale = jnp.maximum(jnp.max(jnp.abs(p)), 1e-30)
    codes = KREF.uniform_quantize(p, scale, 6)
    return KREF.uniform_dequantize(codes, scale, 6).astype(p.dtype)

layout = SH.build_layout(jax.eval_shape(model.init, jax.random.PRNGKey(0)), 2)
# reference quantizes per SHARD (matching the sharded gather): emulate by
# splitting each leaf on its shard dim, quantizing halves, re-concatenating
def qx_shardwise(p, dim, stk):
    if dim in (SH.REPLICATED,):
        return p
    off = 1 if stk else 0
    d = dim + off if dim >= 0 else off
    halves = jnp.split(p, 2, axis=d)
    return jnp.concatenate([qx(h, 0) for h in halves], axis=d)

qparams = jax.tree.map(qx_shardwise, params, layout.dims, layout.stacked)

ref_cache = dict(cache)
jit_ref = jax.jit(lambda p, i, c, pos: model.decode_step(p, i, c, pos))

jstep = jax.jit(step)
dcache = cache
logits_seq, ref_seq = [], []
for t in range(6):
    inp = {"token": toks[:, t:t + 1]}
    lg, dcache = jstep(params, inp, dcache, jnp.int32(t))
    rlg, ref_cache = jit_ref(qparams, inp, ref_cache, jnp.int32(t))
    logits_seq.append(np.asarray(lg, np.float32))
    ref_seq.append(np.asarray(rlg, np.float32))

for t, (a, b) in enumerate(zip(logits_seq, ref_seq)):
    np.testing.assert_allclose(a, b, rtol=5e-2, atol=5e-3,
                               err_msg=f"t={t}")
d = max(np.max(np.abs(a - b)) for a, b in zip(logits_seq, ref_seq))
print("max logits err:", d)

# ---- paged cache over the mesh == fixed lanes over the mesh (bitwise) ----
# (page pool sharded over the model axis; every shard holds the global
# page table and writes/reads only the rows in its local page range -
# the gathered view must equal the fixed lane at every valid position)
if cfg.arch_type != "ssm" and cfg.arch_type != "encdec":
    PS = 8
    npag = S_MAX // PS
    num_pages = B * npag               # 2 divides it: shards split evenly
    fixed_c = model.init_cache(B, max_seq_local=S_MAX)
    paged_c = model.init_cache(B, max_seq_local=S_MAX,
                               page_pool=(num_pages, PS))
    # a deliberately scrambled page assignment: the table indirection,
    # not the layout, must carry the order
    perm = np.random.default_rng(7).permutation(num_pages).astype(np.int32)
    paged_c["ptab"] = jnp.asarray(perm.reshape(B, npag))
    for t in range(toks.shape[1]):
        inp = {"token": toks[:, t:t + 1]}
        flg, fixed_c = jstep(params, inp, fixed_c, jnp.int32(t))
        plg, paged_c = jstep(params, inp, paged_c, jnp.int32(t))
        if cfg.meta_tokens:
            # the meta prefix is pinned to shard 0 while the slot's pages
            # may live on shard 1, so the flash psum combine splits the
            # columns differently than fixed lanes do: ulp-level, not
            # bitwise (local paged decode IS bitwise - tests/test_paged.py)
            np.testing.assert_allclose(np.asarray(flg), np.asarray(plg),
                                       rtol=1e-5, atol=1e-6,
                                       err_msg=f"paged mesh decode t={t}")
        else:
            np.testing.assert_array_equal(np.asarray(flg), np.asarray(plg),
                                          err_msg=f"paged mesh decode t={t}")
    print("mesh paged decode == mesh fixed-lane decode")

# ---- ServeSession over the SAME mesh step == batch-synchronous loop ----
# (single API for local and sharded serving: the session drives the
# shard_map'd decode with per-slot position vectors; greedy tokens must
# match a scalar-pos batch-synchronous loop over the identical step)
if cfg.arch_type != "encdec" and cfg.input_mode == "tokens":
    from repro.serve import ServeSession, Request

    prompts = [list(map(int, row)) for row in np.asarray(toks)]
    max_new = 5
    # reference: feed prompts batch-synchronously through the mesh step
    ref_cache2 = model.init_cache(B, max_seq_local=S_MAX)
    cur = toks[:, 0:1]
    ref_tokens = [[] for _ in range(B)]
    for t in range(toks.shape[1] + max_new - 1):
        lg, ref_cache2 = jstep(params, {"token": cur}, ref_cache2,
                               jnp.int32(t))
        nxt = np.asarray(jnp.argmax(lg, axis=-1), np.int32)
        if t + 1 < toks.shape[1]:
            cur = toks[:, t + 1:t + 2]
        else:
            for i in range(B):
                if len(ref_tokens[i]) < max_new:
                    ref_tokens[i].append(int(nxt[i]))
            cur = jnp.asarray(nxt[:, None])

    sess = ServeSession(model, params, slots=B, max_seq=S_MAX,
                        decode_fn=step)
    hs = [sess.submit(Request(prompt=p, max_new_tokens=max_new))
          for p in prompts]
    res = sess.drain()
    for i, h in enumerate(hs):
        assert res[h].tokens == ref_tokens[i], (
            f"mesh session row {i}: {res[h].tokens} != {ref_tokens[i]}")
    print("mesh ServeSession greedy == batch-synchronous loop")
print("OK")
