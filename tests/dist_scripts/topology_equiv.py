"""Hierarchical topology correctness on 8 simulated devices (2 nodes x
4 devices/node).

Three claims, train_equiv_single.py methodology:

1. **Two-level semantics** - HierarchicalTopology(2, 4) with per-node
   batches must match a sequential two-worker Algorithm 2+3 reference:
   the intra-node fp mean turns each node into one logical worker, so
   the 8-device run is the 2-worker parameter server with node
   gradients. Checked for qadam AND efadam (server EF on the broadcast),
   including the EF residual carry (worker-side ``e``, server-side
   ``es``).
2. **Node-leader EF granularity** - within a node every device carries
   a bitwise-identical ``e`` residual (they all see the node-mean
   gradient).
3. **Flat degeneracy** - with batches identical within each node, the
   hierarchical run is bitwise identical to the flat run on the same
   mesh (the intra mean of identical gradients is exact), and the
   per-tier byte accounting matches measured payload ``.nbytes`` with
   inter-tier bytes exactly 1/devices_per_node of flat.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
import numpy as np
import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.dirname(__file__))
from common import tiny_config, make_batch, unchunk_params

from repro import comm
from repro.adapt.controller import verify_accounting
from repro.core.qadam import QAdamConfig, qadam, apply_updates
from repro.dist import topology as T
from repro.dist.step import make_train_step, TrainConfig, _leaf_meta
from repro.models.model import Model
from repro.train.loop import comm_bytes_per_step

cfg = tiny_config("yi-6b")
model = Model(cfg)
mesh = jax.sharding.Mesh(
    np.array(jax.devices()[:8]).reshape(2, 4, 1), ("pod", "data", "model"))

B_w, S = 2, 32
b0 = make_batch(cfg, B_w, S, seed=3)
b1 = make_batch(cfg, B_w, S, seed=4)
# node 0 (workers 0-3) trains on b0, node 1 (workers 4-7) on b1; flat
# worker order is w = node * 4 + intra_index
batch = jax.tree.map(lambda a, b: jnp.concatenate([a] * 4 + [b] * 4, axis=0),
                     b0, b1)

HIER = T.HierarchicalTopology(nodes=2, devices_per_node=4)


def train_cfg(mode, topo):
    return TrainConfig(alpha=1e-2, beta=0.9, theta=0.9, schedule="sqrt",
                       grad_k=4, weight_k=7, weight_absolute=True,
                       worker_axes=("pod", "data"), mode=mode,
                       topology=topo)


def run_steps(tc, n):
    art = make_train_step(model, mesh, tc)
    state = art.init_state(jax.random.PRNGKey(0))
    step = jax.jit(art.step_fn)
    losses = []
    for _ in range(n):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    return art, state, losses


def unchunk_full(arr, layout, metas):
    """Model-shaped tree from a FULL-shard state leaf (m/v/e: every
    worker holds the whole leaf): take worker (0, 0)'s copy and undo the
    (Nm, X) model-chunk stacking exactly like ``unchunk_params``."""
    def rebuild(a, leaf, dim, stk, meta):
        a = np.asarray(a)[0, 0]          # (Nm, X), any worker's copy
        shards = [a[mi].reshape(-1)[: int(np.prod(meta.shp))]
                  .reshape(meta.shp) for mi in range(a.shape[0])]
        off = 1 if stk else 0
        if dim == -2:
            return np.concatenate(shards, axis=off)
        if dim >= 0:
            return np.concatenate(shards, axis=dim + off)
        return shards[0]
    return jax.tree.map(rebuild, arr, layout._leaves, layout.dims,
                        layout.stacked, metas)


def max_abs_err(tree_a, tree_b):
    err = jax.tree.map(
        lambda a, b: float(np.max(np.abs(np.asarray(a, np.float32)
                                         - np.asarray(b, np.float32)))),
        tree_a, tree_b)
    return max(jax.tree.leaves(err))


def assert_node_leader_residuals(state):
    """Within each node every device's EF residual is bitwise identical
    (they all quantize the same node-mean delta)."""
    for e in jax.tree.leaves(state["e"]):
        e = np.asarray(e)                 # (2, 4, Nm, X)
        for i in range(e.shape[0]):
            for j in range(1, e.shape[1]):
                np.testing.assert_array_equal(e[i, j], e[i, 0])


N_STEPS = 4


def lfn(which):
    wb = b0 if which == 0 else b1
    def f(p):
        ls, nt = model.loss(p, wb)
        return ls / nt, ls / nt
    return f


# ---------------------------------------------------------------------------
# 1a. qadam: hierarchical 2x4 vs sequential two-worker Algorithm 2+3
# ---------------------------------------------------------------------------
tc_q = train_cfg("qadam", HIER)
art_q, state_q, losses_q = run_steps(tc_q, N_STEPS)
metas = _leaf_meta(art_q.layout, art_q.n_workers)

params = model.init(jax.random.PRNGKey(0))
opt = qadam(QAdamConfig(alpha=1e-2, beta=0.9, theta=0.9, schedule="sqrt",
                        grad_q="log:4", weight_q="uniform:7",
                        weight_q_min_numel=2 ** 14))
o0, o1 = opt.init(params), opt.init(params)


# ONE jit program, like the distributed step (see train_equiv_single.py:
# eager-vs-jit float rounding flips quantizer-boundary codes).
@jax.jit
def ref_step(params, o0, o1):
    fp = opt.forward_params(params, o0)
    (l0, _), g0 = jax.value_and_grad(lfn(0), has_aux=True)(fp)
    (l1, _), g1 = jax.value_and_grad(lfn(1), has_aux=True)(fp)
    u0, o0 = opt.update(g0, o0, params)
    u1, o1 = opt.update(g1, o1, params)
    upd = jax.tree.map(lambda a, b: (a + b) / 2, u0, u1)
    return apply_updates(params, upd), o0, o1, (l0 + l1) / 2


ref_losses = []
for _ in range(N_STEPS):
    params, o0, o1, lmean = ref_step(params, o0, o1)
    ref_losses.append(float(lmean))

print("qadam hier losses:", losses_q)
print("qadam ref  losses:", ref_losses)
np.testing.assert_allclose(losses_q, ref_losses, rtol=2e-4, atol=1e-5)

rec = unchunk_params(state_q["master"], art_q.layout, metas, (2, 4), 1)
err = max_abs_err(rec, params)
print("qadam max param err vs two-worker reference:", err)
assert err < 5e-5, err

assert_node_leader_residuals(state_q)
# node 0's residual == reference worker 0's Algorithm-1 residual
e_rec = unchunk_full(state_q["e"], art_q.layout, metas)
err_e = max_abs_err(e_rec, o0.e)
print("qadam max worker-EF err vs reference:", err_e)
assert err_e < 5e-5, err_e

# ---------------------------------------------------------------------------
# 1b. efadam: adds server-side EF on the weight broadcast
# ---------------------------------------------------------------------------
tc_e = train_cfg("efadam", HIER)
art_e, state_e, losses_e = run_steps(tc_e, N_STEPS)

wcodec = comm.uniform_wire_codec(7, absolute=True)
MIN_N = tc_e.weight_q_min_numel
params2 = model.init(jax.random.PRNGKey(0))
opt2 = qadam(QAdamConfig(alpha=1e-2, beta=0.9, theta=0.9, schedule="sqrt",
                         grad_q="log:4", weight_q=None))
p0, p1 = opt2.init(params2), opt2.init(params2)
es_ref = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                      params2)


@jax.jit
def ref2_step(params, o0, o1, es):
    def bcast(p, e):
        if p.size < MIN_N:
            return p, e
        send = p.astype(jnp.float32) + e
        scale = jnp.float32(0.5)
        deq = wcodec.dequantize(wcodec.quantize(send, scale), scale)
        return deq.astype(p.dtype), send - deq

    out = jax.tree.map(bcast, params, es)
    is_pair = lambda x: isinstance(x, tuple)
    fp = jax.tree.map(lambda o: o[0], out, is_leaf=is_pair)
    es2 = jax.tree.map(lambda o: o[1], out, is_leaf=is_pair)
    (l0, _), g0 = jax.value_and_grad(lfn(0), has_aux=True)(fp)
    (l1, _), g1 = jax.value_and_grad(lfn(1), has_aux=True)(fp)
    u0, o0 = opt2.update(g0, o0, params)
    u1, o1 = opt2.update(g1, o1, params)
    upd = jax.tree.map(lambda a, b: (a + b) / 2, u0, u1)
    return apply_updates(params, upd), o0, o1, es2, (l0 + l1) / 2


ref_losses2 = []
for _ in range(N_STEPS):
    params2, p0, p1, es_ref, lmean2 = ref2_step(params2, p0, p1, es_ref)
    ref_losses2.append(float(lmean2))

print("efadam hier losses:", losses_e)
print("efadam ref  losses:", ref_losses2)
np.testing.assert_allclose(losses_e, ref_losses2, rtol=2e-4, atol=1e-5)

rec2 = unchunk_params(state_e["master"], art_e.layout, metas, (2, 4), 1)
err2 = max_abs_err(rec2, params2)
print("efadam max param err vs two-worker reference:", err2)
assert err2 < 5e-5, err2

assert_node_leader_residuals(state_e)
es_rec = unchunk_params(state_e["es"], art_e.layout, metas, (2, 4), 1)
err_es = max_abs_err(es_rec, es_ref)
print("efadam max server-EF err vs reference:", err_es)
assert err_es < 5e-5, err_es

# ---------------------------------------------------------------------------
# 2. flat degeneracy: identical batches within each node => hierarchical
#    bitwise == flat on the same mesh (and explicit FlatTopology bitwise
#    == the TrainConfig default)
# ---------------------------------------------------------------------------
tc_flat = train_cfg("qadam", T.FlatTopology())
art_f, state_f, losses_f = run_steps(tc_flat, 2)
_, state_d, losses_d = run_steps(train_cfg("qadam", None), 2)
assert losses_f == losses_d, (losses_f, losses_d)
jax.tree.map(np.testing.assert_array_equal, state_f, state_d)

art_h, state_h, losses_h = run_steps(tc_q, 2)
assert losses_h == losses_f, (losses_h, losses_f)
for k in ("master", "m", "v", "e"):
    jax.tree.map(np.testing.assert_array_equal, state_h[k], state_f[k])
print("flat degeneracy bitwise OK")

# ---------------------------------------------------------------------------
# 3. per-tier byte accounting: registry == measured, inter == flat / 4
# ---------------------------------------------------------------------------
for art_i, tc_i in ((art_q, tc_q), (art_e, tc_e), (art_f, tc_flat)):
    verify_accounting(art_i, tc_i)
flat_bytes = comm_bytes_per_step(art_f, tc_flat)
hier_bytes = comm_bytes_per_step(art_q, tc_q)
fi = flat_bytes["tiers"]["inter"]["total"]
hi = hier_bytes["tiers"]["inter"]["total"]
assert fi == 4 * hi, (fi, hi)
assert flat_bytes["tiers"]["intra"]["total"] == 0
assert hier_bytes["update_exchange_bytes"] * 4 \
    == flat_bytes["update_exchange_bytes"]
print(f"accounting OK: inter {hi} (hier) vs {fi} (flat) = 1/4")

print("OK")
