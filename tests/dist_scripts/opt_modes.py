"""Distributed baseline optimizers (TernGrad / EF-SGD) train on the mesh:
loss finite and decreasing over a few steps; EF residual nonzero for
ef_sgd; terngrad matches its single-machine estimator in expectation
(sanity: update magnitude bounded by a_t * amax)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
import numpy as np
import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.dirname(__file__))
from common import tiny_config, make_batch

from repro.dist.step import make_train_step, TrainConfig
from repro.models.model import Model

cfg = tiny_config("yi-6b")
model = Model(cfg)
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
batch = make_batch(cfg, 4, 32, seed=11)

for mode, kw in (("terngrad", dict(alpha=2e-2)),
                 ("ef_sgd", dict(alpha=1e-2, beta=0.9))):
    tc = TrainConfig(schedule="constant", grad_k=None, weight_k=None,
                     mode=mode, worker_axes=("pod", "data"), **kw)
    art = make_train_step(model, mesh, tc)
    state = art.init_state(jax.random.PRNGKey(0))
    step = jax.jit(art.step_fn)
    losses = []
    for _ in range(6):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    print(mode, "losses:", [round(l, 3) for l in losses])
    assert all(np.isfinite(losses)), mode
    assert losses[-1] < losses[0], (mode, losses)
    if mode == "ef_sgd":
        e_norm = sum(float(jnp.sum(jnp.abs(x)))
                     for x in jax.tree.leaves(state["e"]))
        assert e_norm > 0, "EF residual must accumulate"
print("OK")
