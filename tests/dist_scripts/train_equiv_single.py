"""Multi-worker QAdam (Algorithms 2+3) with identical per-worker batches
must reproduce single-machine Algorithm 1 exactly (paper Section 3.2:
identical workers => server average == single worker).

Mesh (4, 1): 4 workers, no model sharding => per-tensor quantization scales
match the single-machine path bit-for-bit (up to f32 reduction order).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
import numpy as np
import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.dirname(__file__))
from common import tiny_config, make_batch, unchunk_params

from repro.dist.step import make_train_step, TrainConfig, _leaf_meta
from repro.models.model import Model
from repro.core.qadam import QAdamConfig, qadam, apply_updates

cfg = tiny_config("yi-6b")
model = Model(cfg)
mesh = jax.make_mesh((4, 1), ("data", "model"))

tc = TrainConfig(alpha=1e-2, beta=0.9, theta=0.9, schedule="sqrt",
                 grad_k=4, weight_k=7, weight_absolute=True,
                 worker_axes=("data",))
art = make_train_step(model, mesh, tc)
state = art.init_state(jax.random.PRNGKey(0))

B_w, S = 2, 32
wbatch = make_batch(cfg, B_w, S, seed=3)
# identical data on all 4 workers
batch = jax.tree.map(lambda x: jnp.concatenate([x] * 4, axis=0), wbatch)

step = jax.jit(art.step_fn)
losses = []
for i in range(4):
    state, metrics = step(state, batch)
    losses.append(float(metrics["loss"]))

# ---- single-machine Algorithm 1 reference ----
params = model.init(jax.random.PRNGKey(0))
opt = qadam(QAdamConfig(alpha=1e-2, beta=0.9, theta=0.9, schedule="sqrt",
                        grad_q="log:4", weight_q="uniform:7",
                        weight_q_min_numel=2 ** 14))
ostate = opt.init(params)
ref_losses = []
def lfn(p):
    ls, nt = model.loss(p, wbatch)
    return ls / nt, ls / nt


# The reference must be compiled as ONE program, exactly like the jitted
# distributed step: eager-vs-jit runs of the same graph differ by ~1e-8
# in the gradients (XLA fusion changes float rounding), which flips
# log-grid codes sitting on quantizer boundaries and shows up as
# ~1e-4 master-weight deviations - compilation modes, not algorithms.
@jax.jit
def ref_step(params, ostate):
    fp = opt.forward_params(params, ostate)
    (lmean, _), grads = jax.value_and_grad(lfn, has_aux=True)(fp)
    upd, ostate = opt.update(grads, ostate, params)
    return apply_updates(params, upd), ostate, lmean


for i in range(4):
    params, ostate, lmean = ref_step(params, ostate)
    ref_losses.append(float(lmean))

print("dist losses:", losses)
print("ref  losses:", ref_losses)
np.testing.assert_allclose(losses, ref_losses, rtol=2e-4, atol=1e-5)

metas = _leaf_meta(art.layout, art.n_workers)
rec = unchunk_params(state["master"], art.layout, metas, (4,), 1)
err = jax.tree.map(lambda a, b: float(np.max(np.abs(np.asarray(a) - np.asarray(b)))),
                   rec, params)
max_err = max(jax.tree.leaves(err))
print("max param err vs Algorithm 1:", max_err)
assert max_err < 5e-5, max_err

# ---------------------------------------------------------------------------
# efadam: two-way compression. Same identical-worker protocol; the
# sequential reference adds server-side error feedback on the weight
# channel: q_t = Q_x(x_t + es_t), es' = (x_t + es_t) - q_t, fwd/bwd at
# q_t. Both sides quantize through the SAME registry codec (absolute
# scale, so chunk-wise == element-wise), which is what makes the match
# bit-exact rather than approximate.
# ---------------------------------------------------------------------------
from repro import comm

tc2 = TrainConfig(alpha=1e-2, beta=0.9, theta=0.9, schedule="sqrt",
                  grad_k=4, weight_k=7, weight_absolute=True,
                  mode="efadam", worker_axes=("data",))
art2 = make_train_step(model, mesh, tc2)
state2 = art2.init_state(jax.random.PRNGKey(0))
step2 = jax.jit(art2.step_fn)
losses2 = []
for i in range(4):
    state2, metrics2 = step2(state2, batch)
    losses2.append(float(metrics2["loss"]))

wcodec = comm.uniform_wire_codec(7, absolute=True)
MIN_N = tc2.weight_q_min_numel
params2 = model.init(jax.random.PRNGKey(0))
opt2 = qadam(QAdamConfig(alpha=1e-2, beta=0.9, theta=0.9, schedule="sqrt",
                         grad_q="log:4", weight_q=None))
ostate2 = opt2.init(params2)
es_ref = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                      params2)


@jax.jit
def ref2_step(params, ostate, es):
    def bcast(p, e):
        if p.size < MIN_N:
            return p, e
        send = p.astype(jnp.float32) + e
        scale = jnp.float32(0.5)
        deq = wcodec.dequantize(wcodec.quantize(send, scale), scale)
        return deq.astype(p.dtype), send - deq

    out = jax.tree.map(bcast, params, es)
    is_pair = lambda x: isinstance(x, tuple)
    fp = jax.tree.map(lambda o: o[0], out, is_leaf=is_pair)
    es2 = jax.tree.map(lambda o: o[1], out, is_leaf=is_pair)
    (lmean, _), grads = jax.value_and_grad(lfn, has_aux=True)(fp)
    upd, ostate = opt2.update(grads, ostate, params)
    return apply_updates(params, upd), ostate, es2, lmean


ref_losses2 = []
for i in range(4):
    params2, ostate2, es_ref, lmean2 = ref2_step(params2, ostate2, es_ref)
    ref_losses2.append(float(lmean2))

print("efadam dist losses:", losses2)
print("efadam ref  losses:", ref_losses2)
np.testing.assert_allclose(losses2, ref_losses2, rtol=2e-4, atol=1e-5)

rec2 = unchunk_params(state2["master"], art2.layout, metas, (4,), 1)
err2 = jax.tree.map(lambda a, b: float(np.max(np.abs(np.asarray(a)
                                                     - np.asarray(b)))),
                    rec2, params2)
max_err2 = max(jax.tree.leaves(err2))
print("efadam max param err vs sequential two-way reference:", max_err2)
assert max_err2 < 5e-5, max_err2

es_rec = unchunk_params(state2["es"], art2.layout, metas, (4,), 1)
err_es = jax.tree.map(lambda a, b: float(np.max(np.abs(np.asarray(a)
                                                       - np.asarray(b)))),
                      es_rec, es_ref)
max_err_es = max(jax.tree.leaves(err_es))
print("efadam max server-EF err vs reference:", max_err_es)
assert max_err_es < 5e-5, max_err_es
print("OK")
