"""Multi-worker QAdam (Algorithms 2+3) with identical per-worker batches
must reproduce single-machine Algorithm 1 exactly (paper Section 3.2:
identical workers => server average == single worker).

Mesh (4, 1): 4 workers, no model sharding => per-tensor quantization scales
match the single-machine path bit-for-bit (up to f32 reduction order).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
import numpy as np
import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.dirname(__file__))
from common import tiny_config, make_batch, unchunk_params

from repro.dist.step import make_train_step, TrainConfig, _leaf_meta
from repro.models.model import Model
from repro.core.qadam import QAdamConfig, qadam, apply_updates

cfg = tiny_config("yi-6b")
model = Model(cfg)
mesh = jax.make_mesh((4, 1), ("data", "model"))

tc = TrainConfig(alpha=1e-2, beta=0.9, theta=0.9, schedule="sqrt",
                 grad_k=4, weight_k=7, weight_absolute=True,
                 worker_axes=("data",))
art = make_train_step(model, mesh, tc)
state = art.init_state(jax.random.PRNGKey(0))

B_w, S = 2, 32
wbatch = make_batch(cfg, B_w, S, seed=3)
# identical data on all 4 workers
batch = jax.tree.map(lambda x: jnp.concatenate([x] * 4, axis=0), wbatch)

step = jax.jit(art.step_fn)
losses = []
for i in range(4):
    state, metrics = step(state, batch)
    losses.append(float(metrics["loss"]))

# ---- single-machine Algorithm 1 reference ----
params = model.init(jax.random.PRNGKey(0))
opt = qadam(QAdamConfig(alpha=1e-2, beta=0.9, theta=0.9, schedule="sqrt",
                        grad_q="log:4", weight_q="uniform:7",
                        weight_q_min_numel=2 ** 14))
ostate = opt.init(params)
ref_losses = []
def lfn(p):
    ls, nt = model.loss(p, wbatch)
    return ls / nt, ls / nt


# The reference must be compiled as ONE program, exactly like the jitted
# distributed step: eager-vs-jit runs of the same graph differ by ~1e-8
# in the gradients (XLA fusion changes float rounding), which flips
# log-grid codes sitting on quantizer boundaries and shows up as
# ~1e-4 master-weight deviations - compilation modes, not algorithms.
@jax.jit
def ref_step(params, ostate):
    fp = opt.forward_params(params, ostate)
    (lmean, _), grads = jax.value_and_grad(lfn, has_aux=True)(fp)
    upd, ostate = opt.update(grads, ostate, params)
    return apply_updates(params, upd), ostate, lmean


for i in range(4):
    params, ostate, lmean = ref_step(params, ostate)
    ref_losses.append(float(lmean))

print("dist losses:", losses)
print("ref  losses:", ref_losses)
np.testing.assert_allclose(losses, ref_losses, rtol=2e-4, atol=1e-5)

metas = _leaf_meta(art.layout, art.n_workers)
rec = unchunk_params(state["master"], art.layout, metas, (4,), 1)
err = jax.tree.map(lambda a, b: float(np.max(np.abs(np.asarray(a) - np.asarray(b)))),
                   rec, params)
max_err = max(jax.tree.leaves(err))
print("max param err vs Algorithm 1:", max_err)
assert max_err < 5e-5, max_err
print("OK")
