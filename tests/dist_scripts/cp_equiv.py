"""Context/model-parallel correctness: with dp_adam (partition-invariant
gradient averaging), a (2,2,2) pod×data×model mesh must produce the same
losses and master weights as an unsharded (4,1) run - for EVERY model
family (attention KV gather, SSD chunk-state passing, conv halo exchange,
MoE all_to_all, enc-dec, meta-token prefix).

Usage: python cp_equiv.py <arch_id>
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
import numpy as np
import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.dirname(__file__))
from common import tiny_config, make_batch, unchunk_params

from repro.dist.step import make_train_step, TrainConfig, _leaf_meta
from repro.models.model import Model

arch = sys.argv[1] if len(sys.argv) > 1 else "yi-6b"
cfg = tiny_config(arch)
import dataclasses as _dc
if os.environ.get("REPRO_SSD_EXCHANGE") and cfg.ssm is not None:
    cfg = _dc.replace(cfg, ssm=_dc.replace(
        cfg.ssm, cp_exchange=os.environ["REPRO_SSD_EXCHANGE"]))
if os.environ.get("REPRO_MOE_DISPATCH") and cfg.moe is not None:
    cfg = _dc.replace(cfg, moe=_dc.replace(
        cfg.moe, dispatch=os.environ["REPRO_MOE_DISPATCH"]))
if cfg.moe is not None:
    # capacity drops depend on the token partition (per-shard slot
    # assignment); make the equivalence drop-free so routing math is exact
    import dataclasses
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
model = Model(cfg)

B, S = 4, 32
batch = make_batch(cfg, B, S, seed=7)

tc_kw = dict(alpha=1e-2, beta=0.9, theta=0.9, schedule="constant",
             grad_k=None, weight_k=None, mode="dp_adam")

results = {}
for name, shape, axes, waxes in [
        ("sharded", (2, 2, 2), ("pod", "data", "model"), ("pod", "data")),
        ("flat", (4, 1), ("data", "model"), ("data",))]:
    mesh = jax.make_mesh(shape, axes)
    art = make_train_step(model, mesh, TrainConfig(worker_axes=waxes,
                                                   **tc_kw))
    state = art.init_state(jax.random.PRNGKey(0))
    step = jax.jit(art.step_fn)
    losses = []
    for _ in range(3):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    metas = _leaf_meta(art.layout, art.n_workers)
    ms = dict(zip(mesh.axis_names, mesh.devices.shape))
    wsz = tuple(ms[a] for a in art.worker_axes)
    params = unchunk_params(state["master"], art.layout, metas, wsz,
                            ms["model"])
    results[name] = (losses, params)
    print(name, "losses:", losses)

l_a, p_a = results["sharded"]
l_b, p_b = results["flat"]
np.testing.assert_allclose(l_a, l_b, rtol=2e-3, atol=1e-4)
errs = jax.tree.map(
    lambda a, b: float(np.max(np.abs(np.asarray(a) - np.asarray(b)))),
    p_a, p_b)
flat_errs = jax.tree.leaves(errs)
print("max param err:", max(flat_errs))
# MoE: top-k routing near-ties can flip under a different f32 reduction
# order; the effect is bounded but not bit-reproducible.
tol = 1e-3 if cfg.moe is not None else 2e-4
assert max(flat_errs) < tol, max(flat_errs)
print("OK")
