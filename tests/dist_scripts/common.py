"""Shared helpers for the multi-device (subprocess) tests.

Each script in this directory sets XLA_FLAGS before importing jax, builds a
small mesh out of the 8 simulated CPU devices, and prints 'OK' on success.
"""
import dataclasses

import numpy as np


def tiny_config(arch: str):
    """Shrunken-but-divisible configs for 2-way model-axis sharding."""
    from repro.configs import get_config
    cfg = get_config(arch, smoke=True)
    return cfg


def make_batch(cfg, B, S, seed=0):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    b = {}
    if cfg.input_mode == "embeddings":
        b["embeds"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)).astype(np.float32))
    else:
        b["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(B, S)).astype(np.int32))
    if cfg.input_mode == "audio+tokens":
        b["audio"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model))
            .astype(np.float32))
    b["targets"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(B, S)).astype(np.int32))
    b["mask"] = jnp.ones((B, S), np.float32)
    return b


def unchunk_params(master_state, layout, metas, worker_axes_sizes, Nm):
    """Reconstruct model-shaped params from chunked master arrays (host)."""
    import jax
    import numpy as np

    def rebuild(arr, leaf, dim, stk, meta):
        arr = np.asarray(arr)
        n_workers = int(np.prod(worker_axes_sizes)) if worker_axes_sizes else 1
        arr = arr.reshape(n_workers, Nm, meta.c)
        shards = []
        for mi in range(Nm):
            flat = arr[:, mi, :].reshape(-1)[: int(np.prod(meta.shp))]
            shards.append(flat.reshape(meta.shp))
        off = 1 if stk else 0
        if dim == -2:
            return np.concatenate(shards, axis=off)
        if dim >= 0:
            return np.concatenate(shards, axis=dim + off)
        return shards[0]

    return jax.tree.map(rebuild, master_state, layout._leaves, layout.dims,
                        layout.stacked, metas)
