"""Communication accounting: train.loop.comm_bytes_per_step must agree,
byte for byte, with the *measured* packed payload buffers the codec
registry emits for the per-leaf wire geometry (_leaf_meta) - the 'Comm'
column of the paper's tables, with no hand-rolled byte formulas left."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import comm
from repro.configs import get_config
from repro.core.packing import packed_nbytes
from repro.dist.modes import get_mode
from repro.dist.step import (make_train_step, TrainConfig, _leaf_meta,
                             weight_wire_codec)
from repro.models.model import Model
from repro.train.loop import comm_bytes_per_step

_IS_META = lambda x: type(x).__name__ == "LeafMeta"


def _metas(art):
    return jax.tree.leaves(_leaf_meta(art.layout, art.n_workers),
                           is_leaf=_IS_META)


@pytest.fixture(scope="module")
def model():
    return Model(get_config("yi-6b", smoke=True))


class TestCommAccounting:
    def test_grad_quantized_config(self, model):
        """Channel 1 on (log k_g=4 -> 4-bit packed), channel 2 off."""
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        tc = TrainConfig(grad_k=4, weight_k=None, worker_axes=("data",))
        art = make_train_step(model, mesh, tc)
        comm_b = comm_bytes_per_step(art, tc)
        metas = _metas(art)
        want_a2a = sum(art.n_workers * packed_nbytes(m.c, 4) for m in metas)
        want_bcast = sum(art.n_workers * m.c * 4 for m in metas)
        assert comm_b["update_exchange_bytes"] == want_a2a
        assert comm_b["weight_broadcast_bytes"] == want_bcast
        assert comm_b["total_bytes"] == want_a2a + want_bcast
        # 4-bit codes: the exchange is ~8x smaller than an f32 wire
        f32_wire = sum(art.n_workers * m.c * 4 for m in metas)
        assert want_a2a * 7 < f32_wire

    def test_weight_quantized_config(self, model):
        """Channel 2 on (uniform k_x=7 -> 8-bit packed), channel 1 off;
        leaves under weight_q_min_numel ride the f32 path."""
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        tc = TrainConfig(grad_k=None, weight_k=7, weight_absolute=True,
                         worker_axes=("data",))
        art = make_train_step(model, mesh, tc)
        comm_b = comm_bytes_per_step(art, tc)
        metas = _metas(art)
        want_a2a = sum(art.n_workers * m.c * 4 for m in metas)
        want_bcast = sum(
            art.n_workers * (packed_nbytes(m.c, 8)
                             if m.full_numel >= tc.weight_q_min_numel
                             else m.c * 4)
            for m in metas)
        assert comm_b["update_exchange_bytes"] == want_a2a
        assert comm_b["weight_broadcast_bytes"] == want_bcast
        # both kinds of leaves must actually occur in the smoke model
        assert any(m.full_numel >= tc.weight_q_min_numel for m in metas)
        assert any(m.full_numel < tc.weight_q_min_numel for m in metas)

    def test_baseline_modes_use_their_own_wire(self, model):
        """dp_adam all-reduces f32 rows (no quantized wire); the
        terngrad/ef_sgd baselines ship 2-bit codes - the accounting must
        not charge them the qadam log-grid wire."""
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        for mode, per_leaf in (
                ("dp_adam", lambda m, nw: nw * m.c * 4),
                ("terngrad", lambda m, nw: nw * packed_nbytes(m.c, 2)),
                ("ef_sgd", lambda m, nw: nw * packed_nbytes(m.c, 2))):
            tc = TrainConfig(grad_k=6, weight_k=None, mode=mode,
                             worker_axes=("data",))
            art = make_train_step(model, mesh, tc)
            comm_b = comm_bytes_per_step(art, tc)
            want = sum(per_leaf(m, art.n_workers) for m in _metas(art))
            assert comm_b["update_exchange_bytes"] == want, mode

    @pytest.mark.parametrize("mode,grad_k,weight_k", [
        ("qadam", 6, 7), ("qadam", 4, None), ("qadam", 2, 3),
        ("efadam", 6, 7), ("efadam", 4, 3),
        ("terngrad", None, None), ("ef_sgd", None, None),
        ("dp_adam", None, 7),
    ])
    def test_accounting_equals_measured_payload_bytes(self, model, mode,
                                                      grad_k, weight_k):
        """THE drift guard: for every mode, the loop accounting must
        equal the summed ``.nbytes`` of the actual packed payload arrays
        the codec emits for each leaf's wire geometry."""
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        tc = TrainConfig(grad_k=grad_k, weight_k=weight_k, mode=mode,
                         worker_axes=("data",))
        art = make_train_step(model, mesh, tc)
        comm_b = comm_bytes_per_step(art, tc)
        spec = get_mode(mode)
        key = jax.random.PRNGKey(0)
        measured_a2a = measured_bcast = 0
        for m in _metas(art):
            x = jnp.zeros((m.numel,), jnp.float32)
            wc = spec.wire_codec(tc.grad_k)
            if isinstance(wc, comm.IdentityCodec):
                measured_a2a += art.n_workers * m.c * 4
            elif isinstance(wc, comm.BlockwiseCodec):
                # ef_sgd packs its sign codes row-wise (per-block scales
                # ride a separate gather, excluded like all scales)
                rows = comm.pad_rows(jnp.sign(x).astype(jnp.int8),
                                     art.n_workers)
                measured_a2a += comm.pack_rows(rows, wc.bits).nbytes
            else:
                payload, _ = comm.encode_rows(
                    x, wc, art.n_workers,
                    key=key if wc.stochastic else None)
                # the all_to_all moves exactly this array per device
                measured_a2a += payload.nbytes
            bc = weight_wire_codec(tc, m.full_numel)
            if isinstance(bc, comm.IdentityCodec):
                measured_bcast += art.n_workers * m.c * 4
            else:
                chunk = jnp.zeros((m.c,), jnp.float32)
                p, _ = comm.encode_rows(chunk, bc, 1)
                measured_bcast += art.n_workers * p.nbytes
        assert comm_b["update_exchange_bytes"] == measured_a2a, mode
        assert comm_b["weight_broadcast_bytes"] == measured_bcast, mode
        assert comm_b["total_bytes"] == measured_a2a + measured_bcast

    def test_adaptive_plan_switch_accounting(self, model):
        """Per-leaf bit plans stay byte-exact across a mid-run plan
        switch: for two different ``tc.bit_plan``-s, the accounting
        equals the measured payload ``.nbytes`` at every plan, and the
        totals actually differ (the switch is observable on the wire)."""
        from repro.adapt.controller import (measured_exchange_bytes,
                                            verify_accounting)
        import dataclasses
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        tc = TrainConfig(mode="adaptive", worker_axes=("data",))
        art = make_train_step(model, mesh, tc)
        n = len(_metas(art))
        # no plan yet: the adaptive mode falls back to the fixed log grid
        assert comm_bytes_per_step(art, tc)["update_exchange_bytes"] \
            == measured_exchange_bytes(art, tc)
        plan_a = tuple("log:6" if i % 2 else "blockwise:256"
                       for i in range(n))
        plan_b = tuple("log:2" if i % 3 else "uniform_amax:14:w16"
                       for i in range(n))
        totals = []
        for plan in (plan_a, plan_b):
            tc_p = dataclasses.replace(tc, bit_plan=plan)
            art_p = make_train_step(model, mesh, tc_p)
            figs = verify_accounting(art_p, tc_p)  # accounted == measured
            totals.append(figs["accounted"])
        assert totals[0] != totals[1]

    def test_adaptive_plan_length_validated(self, model):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        tc = TrainConfig(mode="adaptive", worker_axes=("data",),
                         bit_plan=("log:6",))
        with pytest.raises(ValueError, match="bit_plan"):
            make_train_step(model, mesh, tc)

    def test_efadam_matches_qadam_wire(self, model):
        """Two-way compression reuses both channels' codecs: identical
        accounting to qadam at the same (grad_k, weight_k)."""
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        figs = []
        for mode in ("qadam", "efadam"):
            tc = TrainConfig(grad_k=6, weight_k=7, mode=mode,
                             worker_axes=("data",))
            art = make_train_step(model, mesh, tc)
            figs.append(comm_bytes_per_step(art, tc))
        assert figs[0] == figs[1]

    def test_shard_params_counts_shards_not_chunks(self, model):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        tc = TrainConfig(worker_axes=("data",))
        art = make_train_step(model, mesh, tc)
        comm_b = comm_bytes_per_step(art, tc)
        metas = _metas(art)
        assert comm_b["shard_params"] == sum(
            int(np.prod(m.shp)) for m in metas)
        assert comm_b["shard_params"] == sum(m.numel for m in metas)
