"""Property tests (hypothesis) for the dist sharding/chunking invariants
that the exchange and broadcast channels rely on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.dist import sharding as SH


class TestChunkingInvariants:
    @given(st.integers(1, 5000), st.integers(1, 64),
           st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=50, deadline=None)
    def test_flatten_unflatten_roundtrip(self, numel, n_workers, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(numel,)).astype(np.float32))
        rows = SH.flatten_pad(x, n_workers)
        assert rows.shape == (n_workers, SH.chunk_size(numel, n_workers))
        back = SH.unflatten_chunked(rows, (numel,))
        np.testing.assert_array_equal(np.asarray(back), np.asarray(x))

    @given(st.integers(1, 10000), st.integers(1, 64))
    @settings(max_examples=50, deadline=None)
    def test_chunks_cover_and_partition(self, numel, n_workers):
        """Every element lands in exactly one worker chunk (the 'server'
        ownership partition of Algorithm 2)."""
        c = SH.chunk_size(numel, n_workers)
        assert c * n_workers >= numel       # coverage
        assert (c - 1) * n_workers < numel  # minimality of ceil

    @given(st.sampled_from([(64, 32), (128,), (7, 3, 5), (100, 16, 2)]),
           st.sampled_from([2, 4, 8, 16]))
    @settings(max_examples=30, deadline=None)
    def test_shard_dim_rule_consistency(self, shape, axis):
        """local_shard_shape is consistent with the dim chosen by
        shard_dim_for, and replicated leaves keep their shape."""
        dim = SH.shard_dim_for((), shape, axis, stacked=False)
        loc = SH.local_shard_shape(shape, dim, False, axis)
        if dim == SH.REPLICATED:
            assert loc == shape
        else:
            d = dim if dim >= 0 else 0
            assert loc[d] * axis == shape[d]
            assert all(a == b for i, (a, b) in enumerate(zip(loc, shape))
                       if i != d)

    def test_expert_leaves_marked(self):
        import jax.tree_util as jtu
        tree = {"blocks": {"moe": {"w_gate": jnp.zeros((2, 8, 64, 32)),
                                   "shared": {"w_gate": jnp.zeros((64, 32))}}}}
        layout = SH.build_layout(tree, 4)
        assert layout.dims["blocks"]["moe"]["w_gate"] == SH.EXPERT_MARKER
        # shared expert is NOT expert-sharded
        assert layout.dims["blocks"]["moe"]["shared"]["w_gate"] != \
            SH.EXPERT_MARKER
