"""In-process (single device) checks of the repro.dist train step.

The acceptance bar for the dist subsystem: with one worker and no model
sharding, `make_train_step` must reproduce the single-machine Algorithm 1
(`core.qadam`) trajectory. Both sides are compiled as one program each -
eager-vs-jit runs of identical graphs differ by ~1e-8 in gradients, which
flips quantizer codes on grid boundaries; compiled-vs-compiled isolates
the algorithm from the compilation mode (see tests/dist_scripts)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.qadam import QAdamConfig, qadam, apply_updates
from repro.data.pipeline import batch_for_model
from repro.dist import sharding as SH
from repro.dist.step import make_train_step, TrainConfig, _leaf_meta
from repro.models.model import Model

N_STEPS = 24


def _unchunk(state, layout, metas, treedef):
    out = []
    for leaf, meta in zip(treedef.flatten_up_to(state["master"]),
                          treedef.flatten_up_to(metas)):
        out.append(SH.unflatten_chunked(
            jnp.asarray(leaf).reshape(1, -1), meta.shp))
    return jax.tree_util.tree_unflatten(treedef, out)


class TestSingleWorkerEquivalence:
    def test_matches_algorithm1_over_24_steps(self):
        cfg = get_config("yi-6b", smoke=True)
        model = Model(cfg)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        tc = TrainConfig(alpha=1e-2, beta=0.9, theta=0.9, schedule="sqrt",
                         grad_k=4, weight_k=7, weight_absolute=True,
                         worker_axes=("data",))
        art = make_train_step(model, mesh, tc)
        assert art.n_workers == 1
        state = art.init_state(jax.random.PRNGKey(0))
        batch = next(batch_for_model(cfg, 32, 2, seed=5))
        step = jax.jit(art.step_fn)
        losses = []
        for _ in range(N_STEPS):
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))

        params = model.init(jax.random.PRNGKey(0))
        opt = qadam(QAdamConfig(alpha=1e-2, beta=0.9, theta=0.9,
                                schedule="sqrt", grad_q="log:4",
                                weight_q="uniform:7",
                                weight_q_min_numel=2 ** 14))
        ostate = opt.init(params)

        def lfn(p):
            ls, nt = model.loss(p, batch)
            return ls / nt, ls / nt

        @jax.jit
        def ref_step(params, ostate):
            fp = opt.forward_params(params, ostate)
            (lmean, _), grads = jax.value_and_grad(
                lfn, has_aux=True)(fp)
            upd, ostate = opt.update(grads, ostate, params)
            return apply_updates(params, upd), ostate, lmean

        ref_losses = []
        for _ in range(N_STEPS):
            params, ostate, lmean = ref_step(params, ostate)
            ref_losses.append(float(lmean))

        np.testing.assert_allclose(losses, ref_losses, rtol=1e-4,
                                   atol=1e-6)
        metas = _leaf_meta(art.layout, art.n_workers)
        treedef = jax.tree_util.tree_structure(art.layout._leaves)
        rec = _unchunk(state, art.layout, metas, treedef)
        err = max(jax.tree.leaves(jax.tree.map(
            lambda a, b: float(np.max(np.abs(np.asarray(a)
                                             - np.asarray(b)))),
            rec, params)))
        assert err <= 1e-5, err

    def test_state_layout_matches_dryrun_contract(self):
        """The state pytree must be exactly what repro.launch.dryrun
        reconstructs from layout + metas (chunk shapes, dp_adam chunked
        moments vs qadam full-shard moments)."""
        cfg = get_config("yi-6b", smoke=True)
        model = Model(cfg)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        for mode, xdim in (("qadam", "numel"), ("dp_adam", "c")):
            tc = TrainConfig(mode=mode, worker_axes=("data",))
            art = make_train_step(model, mesh, tc)
            state = art.init_state(jax.random.PRNGKey(0))
            metas = _leaf_meta(art.layout, art.n_workers)
            treedef = jax.tree_util.tree_structure(art.layout._leaves)
            for m_leaf, meta in zip(treedef.flatten_up_to(state["m"]),
                                    treedef.flatten_up_to(metas)):
                assert m_leaf.shape == (1, 1, getattr(meta, xdim)), mode
            for ms_leaf, meta in zip(
                    treedef.flatten_up_to(state["master"]),
                    treedef.flatten_up_to(metas)):
                assert ms_leaf.shape == (1, 1, meta.c)
