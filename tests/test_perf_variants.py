"""Equality tests for the §Perf optimization variants: the optimized path
must be numerically identical to the reference implementation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L
from repro.models.config import MoEConfig
from repro.configs import get_config
from repro.models.model import Model


class TestSortDispatch:
    @pytest.mark.parametrize("topk,cf", [(2, 1.25), (1, 1.0), (6, 0.5)])
    def test_sort_equals_einsum(self, topk, cf):
        """Sort-based dispatch == one-hot einsum dispatch, including the
        exact same capacity drops (stable order)."""
        rng = np.random.default_rng(topk * 10 + int(cf * 4))
        B, S, d, E, fe = 2, 16, 32, 8, 16
        x = jnp.asarray(rng.normal(size=(B, S, d)).astype(np.float32))
        params = {
            "router": jnp.asarray(rng.normal(size=(d, E), scale=0.5)
                                  .astype(np.float32)),
            "w_gate": jnp.asarray(rng.normal(size=(E, d, fe), scale=0.1)
                                  .astype(np.float32)),
            "w_up": jnp.asarray(rng.normal(size=(E, d, fe), scale=0.1)
                                .astype(np.float32)),
            "w_down": jnp.asarray(rng.normal(size=(E, fe, d), scale=0.1)
                                  .astype(np.float32)),
        }
        me = MoEConfig(n_experts=E, top_k=topk, capacity_factor=cf,
                       dispatch="einsum")
        ms = dataclasses.replace(me, dispatch="sort")
        y_e, aux_e = L.moe(params, x, me)
        y_s, aux_s = L.moe(params, x, ms)
        np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_e),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(float(aux_s), float(aux_e), rtol=1e-6)

    def test_sort_grads_match(self):
        rng = np.random.default_rng(0)
        B, S, d, E, fe = 2, 8, 16, 4, 8
        x = jnp.asarray(rng.normal(size=(B, S, d)).astype(np.float32))
        params = {
            "router": jnp.asarray(rng.normal(size=(d, E)).astype(np.float32)),
            "w_gate": jnp.asarray(rng.normal(size=(E, d, fe), scale=0.1)
                                  .astype(np.float32)),
            "w_up": jnp.asarray(rng.normal(size=(E, d, fe), scale=0.1)
                                .astype(np.float32)),
            "w_down": jnp.asarray(rng.normal(size=(E, fe, d), scale=0.1)
                                  .astype(np.float32)),
        }

        def loss(p, dispatch):
            me = MoEConfig(n_experts=E, top_k=2, capacity_factor=2.0,
                           dispatch=dispatch)
            y, aux = L.moe(p, x, me)
            return jnp.sum(y ** 2) + aux

        g_e = jax.grad(lambda p: loss(p, "einsum"))(params)
        g_s = jax.grad(lambda p: loss(p, "sort"))(params)
        for k in params:
            np.testing.assert_allclose(np.asarray(g_s[k]), np.asarray(g_e[k]),
                                       rtol=2e-4, atol=1e-5, err_msg=k)

    def test_model_level_sort(self):
        """Full deepseek smoke forward: sort == einsum."""
        cfg = get_config("deepseek-moe-16b", smoke=True)
        cfg_s = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch="sort"))
        m_e, m_s = Model(cfg), Model(cfg_s)
        params = m_e.init(jax.random.PRNGKey(0))
        batch = {"tokens": jnp.arange(32).reshape(2, 16) % cfg.vocab_size,
                 "targets": jnp.arange(32).reshape(2, 16) % cfg.vocab_size,
                 "mask": jnp.ones((2, 16), jnp.float32)}
        l_e, _ = m_e.loss(params, batch)
        l_s, _ = m_s.loss(params, batch)
        np.testing.assert_allclose(float(l_s), float(l_e), rtol=1e-5)


class TestSSDLadderLocal:
    def test_ladder_is_noop_single_device(self):
        """Single device: ladder path == gather path == local scan."""
        import dataclasses as dc
        from repro.models.config import SSMConfig
        cfg = get_config("mamba2-2.7b", smoke=True)
        cfg_l = dc.replace(cfg, ssm=dc.replace(cfg.ssm,
                                               cp_exchange="ladder"))
        m_g, m_l = Model(cfg), Model(cfg_l)
        params = m_g.init(jax.random.PRNGKey(0))
        batch = {"tokens": jnp.arange(32).reshape(2, 16) % cfg.vocab_size,
                 "targets": jnp.arange(32).reshape(2, 16) % cfg.vocab_size,
                 "mask": jnp.ones((2, 16), jnp.float32)}
        l_g, _ = m_g.loss(params, batch)
        l_l, _ = m_l.loss(params, batch)
        np.testing.assert_allclose(float(l_l), float(l_g), rtol=1e-6)
