"""Paged KV cache: gather kernel parity, allocator invariants, and
paged-vs-fixed ServeSession token identity (the tentpole guarantee:
bitwise-identical decode for the same request stream, greedy and keyed
sampling, under mixed lengths, fragmentation, and preemption)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import Model
from repro.serve import (PagePool, Request, ServeSession, cache_nbytes,
                         gather_pages, pages_for, quantize_params)
from repro.serve.paged import _gather_jnp, _gather_pallas


@pytest.fixture(scope="module")
def yi():
    cfg = get_config("yi-6b", smoke=True)
    model = Model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


MIXED = [[5, 6, 7, 8], [9, 10, 11, 12, 13, 14], [3, 14],
         [21, 22, 23, 24, 25], [7, 8, 9], [2, 4, 6, 8, 10, 12, 14, 16]]


def _serve(model, params, reqs, **kw):
    sess = ServeSession(model, params, max_seq=48, **kw)
    hs = [sess.submit(Request(**vars(r))) for r in reqs]
    res = sess.drain()
    return [res[h] for h in hs], sess


def _mixed_reqs(max_new=6, hot_every=2):
    return [Request(prompt=p, max_new_tokens=max_new,
                    temperature=(0.9 if hot_every and i % hot_every else 0.0))
            for i, p in enumerate(MIXED)]


# ---------------------------------------------------------------------------
# gather kernel
# ---------------------------------------------------------------------------

class TestGatherPages:
    def test_pallas_matches_jnp_bitwise(self):
        rng = np.random.default_rng(0)
        pool = jnp.asarray(rng.normal(size=(10, 4, 2, 8)).astype(np.float32))
        ptab = jnp.asarray(rng.integers(0, 10, size=(3, 5), dtype=np.int32))
        ref = _gather_jnp(pool, ptab)
        out = _gather_pallas(pool, ptab, interpret=True)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))

    def test_dispatch_clips_released_sentinel(self):
        pool = jnp.arange(2 * 2 * 1 * 2, dtype=jnp.float32).reshape(2, 2, 1, 2)
        # sentinel id 2 (== num_pages) must clip into the pool, not crash;
        # callers mask those columns out
        ptab = jnp.asarray([[0, 2]], jnp.int32)
        out = gather_pages(pool, ptab)
        assert out.shape == (1, 4, 1, 2)
        np.testing.assert_array_equal(np.asarray(out[0, :2]),
                                      np.asarray(pool[0]))
        np.testing.assert_array_equal(np.asarray(out[0, 2:]),
                                      np.asarray(pool[1]))

    def test_backend_override(self):
        pool = jnp.ones((4, 2, 1, 2), jnp.float32)
        ptab = jnp.zeros((2, 3), jnp.int32)
        a = gather_pages(pool, ptab, backend="jnp")
        b = gather_pages(pool, ptab, backend="pallas")
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------

class TestPagePool:
    def test_alloc_free_roundtrip(self):
        pool = PagePool(8, 4)
        a = pool.alloc(3)
        b = pool.alloc(5)
        assert len(a) == 3 and len(b) == 5 and pool.free_pages == 0
        assert pool.alloc(1) is None          # exhausted: no change
        assert pool.free_pages == 0
        pool.free(a)
        assert pool.free_pages == 3 and pool.used_pages == 5

    def test_distinct_ids(self):
        pool = PagePool(6, 2)
        pages = pool.alloc(6)
        assert sorted(pages) == list(range(6))

    def test_foreign_and_double_free(self):
        pool = PagePool(4, 2)
        pages = pool.alloc(2)
        with pytest.raises(ValueError):
            pool.free([99])
        pool.free(pages)
        with pytest.raises(RuntimeError):
            pool.free(pages + pool.alloc(0 or 2))

    def test_fragmentation_cycles(self):
        """Interleaved alloc/free cycles fragment the id space; the free
        list must stay exact (no leak, no dup) throughout."""
        pool = PagePool(16, 4)
        rng = np.random.default_rng(3)
        held = []
        for _ in range(200):
            if held and rng.random() < 0.5:
                pool.free(held.pop(rng.integers(len(held))))
            else:
                got = pool.alloc(int(rng.integers(1, 5)))
                if got is not None:
                    held.append(got)
            live = [p for h in held for p in h]
            assert len(set(live)) == len(live)
            assert len(live) + pool.free_pages == 16

    def test_pages_for(self):
        assert pages_for(0, 8) == 0
        assert pages_for(1, 8) == 1
        assert pages_for(8, 8) == 1
        assert pages_for(9, 8) == 2


# ---------------------------------------------------------------------------
# session: paged == fixed, token for token
# ---------------------------------------------------------------------------

class TestPagedSessionIdentity:
    def test_mixed_lengths_greedy_and_sampled(self, yi):
        """The tentpole guarantee: same request stream (greedy AND keyed
        sampling), same tokens, fixed lanes vs pages - with more requests
        than slots so the queue and slot-reuse paths both run."""
        cfg, model, params = yi
        a, _ = _serve(model, params, _mixed_reqs(), slots=3, seed=7)
        b, sp = _serve(model, params, _mixed_reqs(), slots=3, seed=7,
                       paged=True, page_size=8)
        assert [r.tokens for r in a] == [r.tokens for r in b]
        assert sp.free_pages == sp.num_pages  # every page reclaimed

    def test_chunked_matches_whole_prefill_greedy(self, yi):
        """Chunked admission vs the legacy whole-prompt prefill on fixed
        lanes: greedy tokens must agree (the bridge that anchors chunked
        admissions to the old admission math)."""
        cfg, model, params = yi
        reqs = _mixed_reqs(hot_every=0)
        a, _ = _serve(model, params, reqs, slots=3, prefill="whole")
        b, _ = _serve(model, params, reqs, slots=3, prefill="chunked")
        assert [r.tokens for r in a] == [r.tokens for r in b]

    def test_admission_order_invariance(self, yi):
        """Greedy results per request must not depend on submission order
        (slots are independent lanes; the scheduler only changes WHEN a
        request runs, never WHAT it computes)."""
        cfg, model, params = yi
        base = {p: None for p in map(tuple, MIXED)}
        for order in (list(range(6)), [3, 0, 5, 1, 4, 2]):
            reqs = [Request(prompt=MIXED[i], max_new_tokens=6)
                    for i in order]
            res, _ = _serve(model, params, reqs, slots=2, seed=0,
                            paged=True, page_size=8)
            for i, r in zip(order, res):
                key = tuple(MIXED[i])
                if base[key] is None:
                    base[key] = r.tokens
                assert r.tokens == base[key]

    def test_fragmented_pool_still_identical(self, yi):
        """Many reuse cycles scramble the free list; a fragmented page
        table must serve the same tokens as a fresh session."""
        cfg, model, params = yi
        sess = ServeSession(model, params, slots=2, max_seq=48, seed=0,
                            paged=True, page_size=8, num_pages=10)
        for cycle in range(4):     # churn: odd lengths force fragmentation
            hs = [sess.submit(Request(prompt=MIXED[(cycle + i) % 6],
                                      max_new_tokens=3 + cycle))
                  for i in range(3)]
            sess.drain()
        hs = [sess.submit(Request(prompt=p, max_new_tokens=6))
              for p in MIXED]
        res = sess.drain()
        fresh, _ = _serve(model, params, _mixed_reqs(hot_every=0),
                          slots=2, seed=0, paged=True, page_size=8)
        assert [res[h].tokens for h in hs] == [r.tokens for r in fresh]
        assert sess.free_pages == 10

    def test_quantized_fused_paged_identity(self, yi):
        """Code-resident packed weights (qx6 and qx2) through the fused
        dequant-matmul: paged == fixed, and fused == unfused, per token."""
        cfg, model, params = yi
        for k_x in (6, 2):
            qp = quantize_params(params, k_x=k_x, min_numel=16, pack=True)
            reqs = _mixed_reqs(max_new=5)
            a, _ = _serve(model, qp, reqs, slots=2, seed=1)
            b, _ = _serve(model, qp, reqs, slots=2, seed=1,
                          paged=True, page_size=8)
            c, _ = _serve(model, qp, reqs, slots=2, seed=1,
                          paged=True, page_size=8, fused_matmul=False)
            assert [r.tokens for r in a] == [r.tokens for r in b], k_x
            assert [r.tokens for r in b] == [r.tokens for r in c], k_x

    def test_cache_nbytes_equal_memory(self, yi):
        """The fleet benchmark's premise: a pool of slots*max_seq/page_size
        pages holds the same cache bytes as the fixed lanes (+ the tables,
        a few hundred int32s)."""
        cfg, model, params = yi
        fx = ServeSession(model, params, slots=4, max_seq=48)
        pg = ServeSession(model, params, slots=4, max_seq=48,
                          paged=True, page_size=8)
        fb = cache_nbytes(fx._state["cache"])
        pb = cache_nbytes(pg._state["cache"])
        assert fb < pb <= fb * 1.01


# ---------------------------------------------------------------------------
# scheduler: concurrency, SLO, preemption
# ---------------------------------------------------------------------------

class TestPagedScheduler:
    def test_concurrency_beyond_fixed_capacity(self, yi):
        """A quarter of the fixed-lane memory still seats every request at
        once: concurrency follows tokens in flight, not slots*max_seq, and
        cache_full never fires while the pool has pages (admission
        validates pages up front)."""
        cfg, model, params = yi
        sess = ServeSession(model, params, slots=8, max_seq=48,
                            paged=True, page_size=8, num_pages=12)
        hs = [sess.submit(Request(prompt=p, max_new_tokens=5))
              for p in MIXED]
        res = sess.drain()
        assert sess.stats["max_inflight"] > 2   # > fixed-equal-memory slots
        assert {res[h].finish_reason for h in hs} == {"length"}

    def test_submit_rejects_oversized_request(self, yi):
        cfg, model, params = yi
        sess = ServeSession(model, params, slots=2, max_seq=48,
                            paged=True, page_size=8, num_pages=3)
        with pytest.raises(ValueError):
            sess.submit(Request(prompt=list(range(1, 30)), max_new_tokens=8))

    def test_slo_priority_order(self, yi):
        """With one slot, a queued interactive request must be admitted
        ahead of batch requests that arrived before it."""
        cfg, model, params = yi
        sess = ServeSession(model, params, slots=1, max_seq=48,
                            preempt_mode="kill")
        running = sess.submit(Request(prompt=[1, 2, 3], max_new_tokens=4,
                                      slo="interactive"))
        b1 = sess.submit(Request(prompt=[4, 5, 6], max_new_tokens=4,
                                 slo="batch"))
        b2 = sess.submit(Request(prompt=[7, 8, 9], max_new_tokens=4,
                                 slo="batch"))
        hi = sess.submit(Request(prompt=[2, 4, 6], max_new_tokens=4,
                                 slo="interactive"))
        assert sess._pending == [hi, b1, b2]
        res = sess.drain()
        assert all(res[h].finish_reason == "length"
                   for h in (running, b1, b2, hi))

    def test_preempt_requeue_token_identity(self, yi):
        """An interactive arrival evicts a running batch request; the
        victim recomputes from its prompt with its original key and must
        produce exactly the tokens of an unpreempted run - and so must
        the interactive request."""
        cfg, model, params = yi
        r_batch = Request(prompt=[5, 6, 7, 8], max_new_tokens=8,
                          temperature=0.7, slo="batch")
        r_inter = Request(prompt=[9, 10, 11], max_new_tokens=6,
                          slo="interactive")
        calm, _ = _serve(model, params, [r_batch, r_inter], slots=4,
                         seed=3, paged=True, page_size=8)
        sess = ServeSession(model, params, slots=1, max_seq=48, seed=3,
                            paged=True, page_size=8, num_pages=12)
        hb = sess.submit(Request(**vars(r_batch)))
        for _ in range(3):
            sess.step()
        hi = sess.submit(Request(**vars(r_inter)))
        res = sess.drain()
        assert sess.stats["preemptions"] == 1
        assert res[hi].tokens == calm[1].tokens
        assert res[hb].tokens == calm[0].tokens
        assert res[hb].finish_reason == "length"

    def test_preempt_kill_surfaces_partial(self, yi):
        cfg, model, params = yi
        sess = ServeSession(model, params, slots=1, max_seq=48, seed=3,
                            paged=True, page_size=8, num_pages=12,
                            preempt_mode="kill")
        hb = sess.submit(Request(prompt=[5, 6, 7, 8], max_new_tokens=8,
                                 slo="batch"))
        for _ in range(3):
            sess.step()
        hi = sess.submit(Request(prompt=[9, 10, 11], max_new_tokens=6,
                                 slo="interactive"))
        res = sess.drain()
        assert res[hb].finish_reason == "preempted"
        assert 0 < len(res[hb].tokens) < 8
        assert res[hi].finish_reason == "length"

    def test_finished_slot_harvested_not_preempted(self, yi):
        """A slot whose request already completed must be collected, not
        'preempted', when a higher class needs the room."""
        cfg, model, params = yi
        sess = ServeSession(model, params, slots=1, max_seq=48,
                            paged=True, page_size=8, num_pages=12,
                            preempt_mode="kill")
        hb = sess.submit(Request(prompt=[5, 6, 7, 8], max_new_tokens=4,
                                 slo="batch"))
        for _ in range(12):
            sess.step()            # finishes well before the arrival
        hi = sess.submit(Request(prompt=[9, 10, 11], max_new_tokens=4,
                                 slo="interactive"))
        res = sess.drain()
        assert sess.stats["preemptions"] == 0
        assert res[hb].finish_reason == "length"
        assert len(res[hb].tokens) == 4
