"""TrainSession: zero per-step host syncs, bit-identical resume for the
dist and single-machine paths, crash-safe versioned checkpoints, and the
eval-history fix."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.configs import get_config
from repro.core.qadam import QAdamConfig, qadam
from repro.data import pipeline as dp
from repro.dist.step import TrainConfig, make_train_step
from repro.launch.mesh import make_local_mesh
from repro.models.model import Model
from repro.train.session import SessionConfig, TrainSession


SEQ, BATCH = 16, 2


@pytest.fixture(scope="module")
def yi():
    cfg = get_config("yi-6b", smoke=True)
    return cfg, Model(cfg)


@pytest.fixture(scope="module")
def qadam_art(yi):
    cfg, model = yi
    mesh = make_local_mesh(data=1, model=1)
    tc = TrainConfig(alpha=1e-2, grad_k=4, weight_k=7,
                     weight_absolute=True, worker_axes=())
    return make_train_step(model, mesh, tc)


def _batches(cfg, seed=0):
    return dp.batch_for_model(cfg, SEQ, BATCH, seed=seed)


def _masters(state):
    return jax.tree.map(np.asarray, state["master"])


def _max_err(a, b):
    return max(jax.tree.leaves(jax.tree.map(
        lambda x, y: float(np.max(np.abs(x - y))), a, b)))


quiet = lambda *_: None


class TestHotLoop:
    def test_steady_state_zero_host_syncs(self, yi, qadam_art, monkeypatch):
        """With logging off, N training steps are N dispatches and ZERO
        device->host transfers - losses stay in the device ring buffer
        until explicitly harvested (mirrors test_serve_session)."""
        cfg, _ = yi
        sess = TrainSession.from_artifacts(
            qadam_art, _batches(cfg), SessionConfig(log_every=0),
            log=quiet)
        sess.run(1)  # compile + warm the prefetcher outside the counter

        gets = {"n": 0}
        real_get = jax.device_get

        def counting_get(x):
            gets["n"] += 1
            return real_get(x)

        monkeypatch.setattr(jax, "device_get", counting_get)
        d0 = sess.stats["dispatches"]
        sess.run(8)
        assert gets["n"] == 0
        assert sess.stats["dispatches"] - d0 == 8
        assert sess.stats["syncs"] == 0
        # one explicit harvest = ONE sync for every resident loss
        out = sess.harvest_losses()
        assert gets["n"] == 1 and sess.stats["syncs"] == 1
        assert [s for s, _ in out][-1] == 9
        assert all(np.isfinite(v) for _, v in out)
        monkeypatch.undo()
        sess.close()

    def test_log_cadence_harvests_per_boundary(self, yi, qadam_art):
        cfg, _ = yi
        sess = TrainSession.from_artifacts(
            qadam_art, _batches(cfg), SessionConfig(log_every=4), log=quiet)
        hist = sess.run(8)
        sess.close()
        assert [h["step"] for h in hist] == [1, 4, 8]
        # syncs scale with log boundaries, not steps
        assert sess.stats["syncs"] == 3 and sess.stats["steps"] == 8

    def test_scan_chunk_matches_per_step(self, yi, qadam_art):
        """Chunked dispatch (lax.scan over stacked batches) reproduces the
        per-step path's history."""
        cfg, _ = yi
        a = TrainSession.from_artifacts(
            qadam_art, _batches(cfg), SessionConfig(log_every=4), log=quiet)
        ha = a.run(8)
        a.close()
        b = TrainSession.from_artifacts(
            qadam_art, _batches(cfg),
            SessionConfig(log_every=4, scan_chunk=4), log=quiet)
        hb = b.run(8)
        b.close()
        la = {h["step"]: h["loss"] for h in ha}
        lb = {h["step"]: h["loss"] for h in hb}
        for s in (4, 8):
            np.testing.assert_allclose(la[s], lb[s], rtol=2e-4)
        assert b.stats["dispatches"] == 2

    def test_tail_chunk_and_repeated_runs(self, yi, qadam_art):
        cfg, _ = yi
        sess = TrainSession.from_artifacts(
            qadam_art, _batches(cfg),
            SessionConfig(log_every=0, scan_chunk=4), log=quiet)
        sess.run(6)    # 4 + tail 2
        sess.run(5)    # 4 + tail 1 (still a stacked scan dispatch)
        sess.close()
        assert sess.step == 11
        assert sess.stats["dispatches"] == 4

    def test_eval_gets_own_history_entry(self, yi, qadam_art):
        """The old loop pinned evals onto the latest *log* entry; evals
        now land at their own step even when cadences are coprime."""
        cfg, _ = yi
        evals = []

        def eval_fn(state):
            evals.append(int(np.asarray(state["count"])))
            return {"acc": evals[-1]}

        sess = TrainSession.from_artifacts(
            qadam_art, _batches(cfg),
            SessionConfig(log_every=2, eval_every=3, eval_fn=eval_fn),
            log=quiet)
        hist = sess.run(6)
        sess.close()
        ev = [(h["step"], h["eval"]["acc"]) for h in hist if "eval" in h]
        assert ev == [(3, 3), (6, 6)]
        assert all("loss" not in h for h in hist if "eval" in h)

    def test_divergence_raises_at_harvest(self, yi):
        cfg, model = yi
        mesh = make_local_mesh(data=1, model=1)
        # absurd LR to force a non-finite loss quickly
        tc = TrainConfig(alpha=1e6, grad_k=None, weight_k=None,
                         worker_axes=())
        art = make_train_step(model, mesh, tc)
        sess = TrainSession.from_artifacts(
            art, _batches(cfg), SessionConfig(log_every=2), log=quiet)
        with pytest.raises(FloatingPointError):
            sess.run(20)
        sess.close()


class TestResume:
    def _dist_resume_case(self, yi, tc, tmp_path, chunk=1):
        cfg, model = yi
        mesh = make_local_mesh(data=1, model=1)
        art = make_train_step(model, mesh, tc)
        sc = lambda **kw: SessionConfig(log_every=0, scan_chunk=chunk, **kw)

        full = TrainSession.from_artifacts(art, _batches(cfg), sc(),
                                           log=quiet)
        full.run(6)
        full.close()
        want = _masters(full.state)

        d = str(tmp_path)
        first = TrainSession.from_artifacts(
            art, _batches(cfg), sc(ckpt_dir=d, ckpt_every=2), log=quiet)
        first.run(2)
        first.close()   # flushes the async writer
        assert store.latest_step(d) == 2

        second = TrainSession.from_artifacts(
            art, _batches(cfg), sc(ckpt_dir=d), log=quiet)
        assert second.resume() == 2
        second.run(4)
        second.close()
        assert _max_err(want, _masters(second.state)) == 0.0

    def test_dist_qadam_bit_identical(self, yi, tmp_path):
        """Train 6 uninterrupted vs 2 + checkpoint + restore + 4: final
        master weights agree BIT-FOR-BIT (quantized wire, EF, Q_x on)."""
        self._dist_resume_case(yi, TrainConfig(
            alpha=1e-2, grad_k=4, weight_k=7, weight_absolute=True,
            worker_axes=()), tmp_path)

    def test_dist_dp_adam_bit_identical(self, yi, tmp_path):
        self._dist_resume_case(yi, TrainConfig(
            alpha=1e-2, mode="dp_adam", grad_k=None, weight_k=None,
            worker_axes=()), tmp_path, chunk=2)

    def test_single_machine_bit_identical(self, yi, tmp_path):
        """Same contract for the single-machine Algorithm-1 path
        (QAdamState incl. its PRNG key round-trips the store)."""
        cfg, model = yi
        params = model.init(jax.random.PRNGKey(0))
        opt = qadam(QAdamConfig(alpha=1e-2, grad_q="log:4",
                                weight_q="uniform:7",
                                weight_q_min_numel=2 ** 14))

        def lfn(p, batch):
            ls, nt = model.loss(p, batch)
            return ls / nt

        full = TrainSession.from_optimizer(
            opt, lfn, params, _batches(cfg), SessionConfig(log_every=0),
            log=quiet)
        full.run(6)
        full.close()
        want = jax.tree.map(np.asarray, full.state["params"])

        d = str(tmp_path)
        first = TrainSession.from_optimizer(
            opt, lfn, params, _batches(cfg),
            SessionConfig(log_every=0, ckpt_dir=d, ckpt_async=False),
            log=quiet)
        first.run(3)
        first.checkpoint()
        first.close()

        second = TrainSession.from_optimizer(
            opt, lfn, params, _batches(cfg),
            SessionConfig(log_every=0, ckpt_dir=d), log=quiet)
        assert second.resume() == 3
        second.run(3)
        second.close()
        got = jax.tree.map(np.asarray, second.state["params"])
        assert _max_err(want, got) == 0.0

    def test_resume_without_checkpoint_is_noop(self, yi, qadam_art,
                                               tmp_path):
        cfg, _ = yi
        sess = TrainSession.from_artifacts(
            qadam_art, _batches(cfg),
            SessionConfig(ckpt_dir=str(tmp_path)), log=quiet)
        assert sess.resume() == 0
        sess.close()


class TestCheckpointStore:
    def test_versioned_subdirs_and_pruning(self, tmp_path):
        tree = {"w": jnp.arange(8, dtype=jnp.float32)}
        for s in (2, 4, 6, 8):
            store.save(str(tmp_path), {"w": tree["w"] + s}, step=s, keep=2)
        names = sorted(os.listdir(tmp_path))
        assert names == ["step_00000006", "step_00000008"]
        assert store.latest_step(str(tmp_path)) == 8
        out = store.restore(str(tmp_path), tree, step=6)
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.arange(8, dtype=np.float32) + 6)

    def test_crash_mid_save_keeps_previous(self, tmp_path, monkeypatch):
        """A crash while writing step 2 leaves step 1 intact and
        restorable - the manifest only becomes visible via the atomic
        rename after the payload is fully on disk."""
        tree = {"w": jnp.ones((4,), jnp.float32)}
        store.save(str(tmp_path), tree, step=1, extra={"batches_consumed": 1})

        def boom(*a, **k):
            raise OSError("disk full")

        monkeypatch.setattr(np, "savez", boom)
        with pytest.raises(OSError):
            store.save(str(tmp_path), tree, step=2)
        monkeypatch.undo()
        assert store.latest_step(str(tmp_path)) == 1
        assert not any(n.startswith("step_00000002")
                       for n in os.listdir(tmp_path))
        out = store.restore(str(tmp_path), tree)
        np.testing.assert_array_equal(np.asarray(out["w"]), np.ones(4))
        assert store.read_extra(str(tmp_path)) == {"batches_consumed": 1}

    def test_partial_dir_ignored_by_latest(self, tmp_path):
        tree = {"w": jnp.ones((2,), jnp.float32)}
        store.save(str(tmp_path), tree, step=3)
        os.makedirs(tmp_path / "step_00000009")   # no manifest: partial
        assert store.latest_step(str(tmp_path)) == 3
        out = store.restore(str(tmp_path), tree)  # resolves to step 3
        np.testing.assert_array_equal(np.asarray(out["w"]), np.ones(2))

    def test_tail_misaligned_checkpoint_labels_true_step(
            self, yi, qadam_art, tmp_path):
        """run() tails can desync dispatches from the ckpt cadence; a
        boundary crossed mid-dispatch must label the checkpoint with the
        state's TRUE step (else resume() silently repeats steps)."""
        cfg, _ = yi
        d = str(tmp_path)
        sess = TrainSession.from_artifacts(
            qadam_art, _batches(cfg),
            SessionConfig(log_every=0, scan_chunk=4, ckpt_every=4,
                          ckpt_dir=d, ckpt_keep=10), log=quiet)
        sess.run(6)   # dispatches 1-4 (ckpt @4), 5-6
        sess.run(6)   # dispatches 7-10 (boundary 8 crossed), 11-12 (@12)
        sess.close()
        steps = [int(n.split("_")[1]) for n in sorted(os.listdir(d))]
        assert steps == [4, 10, 12]
        for s in steps:
            tree = store.restore(d, sess.state, step=s)
            assert int(np.asarray(tree["count"])) == s
            assert store.read_extra(d, step=s)["batches_consumed"] == s

    def test_async_writer_flush(self, yi, qadam_art, tmp_path):
        cfg, _ = yi
        sess = TrainSession.from_artifacts(
            qadam_art, _batches(cfg),
            SessionConfig(log_every=0, ckpt_dir=str(tmp_path),
                          ckpt_every=2, ckpt_keep=1), log=quiet)
        sess.run(4)
        sess.wait_for_checkpoints()
        assert store.latest_step(str(tmp_path)) == 4
        assert sorted(os.listdir(tmp_path)) == ["step_00000004"]  # pruned
        sess.close()


class TestDataPipeline:
    def test_lm_batches_yield_host_numpy(self):
        cfg = dp.LMDataConfig(vocab_size=64, seq_len=16, global_batch=2)
        b = next(dp.lm_batches(cfg))
        assert all(isinstance(v, np.ndarray) for v in b.values())
        b2 = next(dp.batch_for_model(get_config("yi-6b", smoke=True),
                                     16, 2))
        assert all(isinstance(v, np.ndarray) for v in b2.values())

    def test_classification_batch_larger_than_dataset(self):
        x, y, *_ = dp.classification_dataset(dp.ClsDataConfig(
            n_train=16, n_test=4))
        with pytest.warns(UserWarning, match="replacement"):
            it = dp.classification_batches(x, y, 32)
            bx, by = next(it)
        assert bx.shape[0] == 32
        # small batches keep the no-replacement draw (and stay silent)
        bx2, _ = next(dp.classification_batches(x, y, 8))
        assert bx2.shape[0] == 8


class TestLoopShim:
    def test_train_shim_returns_state_history(self, yi, qadam_art):
        from repro.train.loop import LoopConfig, train
        cfg, _ = yi
        state, hist = train(qadam_art, qadam_art.config, _batches(cfg),
                            LoopConfig(steps=4, log_every=2), log=quiet)
        assert [h["step"] for h in hist] == [1, 2, 4]
        assert "master" in state
