"""Backend parity for the repro.opt engine: for every optimizer mode's
update core and every quantizer grid, the pallas backend must emit codes,
scales, and EF residuals BIT-IDENTICAL to the jnp backend (the kernels'
bodies call the same ``repro.opt.grids`` functions, so this is a contract,
not a tolerance)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.opt import engine, grids

SHAPES = [(7,), (1000,), (33, 77), (256, 128), (32768,), (40000,)]


def _rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape, scale=scale)
                       .astype(np.float32))


def _both(fn):
    return fn(backend="jnp"), fn(backend="pallas")


def _assert_bitwise(a, b, msg=""):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                  err_msg=msg)


class TestLogGridParity:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("k_g", [1, 4, 6])
    def test_encode(self, shape, k_g):
        x = _rand(shape, seed=k_g + len(shape))
        (cj, sj), (cp, sp) = _both(
            lambda backend: engine.quantize_log(x, k_g, backend=backend))
        _assert_bitwise(cj, cp, "codes")
        _assert_bitwise(sj, sp, "scale")

    @pytest.mark.parametrize("k_g", [1, 6])
    def test_decode(self, k_g):
        x = _rand((5000,), seed=k_g)
        codes, scale = engine.quantize_log(x, k_g, backend="jnp")
        dj, dp = _both(lambda backend: engine.dequantize_log(
            codes, scale, k_g, backend=backend))
        _assert_bitwise(dj, dp)


class TestUniformGridParity:
    @pytest.mark.parametrize("shape", SHAPES[:4])
    @pytest.mark.parametrize("k_x", [3, 6, 7])
    @pytest.mark.parametrize("absolute", [True, False])
    def test_encode(self, shape, k_x, absolute):
        x = _rand(shape, seed=k_x, scale=0.3)
        (cj, sj), (cp, sp) = _both(lambda backend: engine.quantize_uniform(
            x, k_x, absolute=absolute, backend=backend))
        assert cj.dtype == cp.dtype == grids.uniform_code_dtype(k_x)
        _assert_bitwise(cj, cp, "codes")
        _assert_bitwise(sj, sp, "scale")

    def test_k7_int16_roundtrip(self):
        """k_x > 6 codes overflow int8; both backends must carry int16 and
        reproduce amax exactly (code +/- 2^k_x) - previously untested."""
        x = jnp.asarray([0.5, -0.5, 0.25, 0.0, 0.4999], jnp.float32)
        for backend in ("jnp", "pallas"):
            codes, scale = engine.quantize_uniform(x, 7, absolute=True,
                                                   backend=backend)
            assert codes.dtype == jnp.int16
            assert int(jnp.max(jnp.abs(codes))) == 128, backend
            deq = engine.dequantize_uniform(codes, scale, 7,
                                            backend=backend)
            np.testing.assert_allclose(np.asarray(deq)[:3],
                                       [0.5, -0.5, 0.25], atol=1e-7)


class TestTernaryGridParity:
    @pytest.mark.parametrize("shape", SHAPES[:5])
    def test_encode(self, shape):
        """Same key => same stochastic draws on both backends."""
        x = _rand(shape, seed=11)
        key = jax.random.PRNGKey(len(shape))
        (cj, sj), (cp, sp) = _both(lambda backend: engine.quantize_ternary(
            x, key, backend=backend))
        _assert_bitwise(cj, cp, "codes")
        _assert_bitwise(sj, sp, "scale")
        assert set(np.unique(np.asarray(cj))) <= {-1, 0, 1}


class TestBlockwiseGridParity:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("block", [64, 256])
    def test_encode(self, shape, block):
        x = _rand(shape, seed=block)
        (cj, sj), (cp, sp) = _both(lambda backend: engine.quantize_blockwise(
            x, block, backend=backend))
        _assert_bitwise(cj, cp, "codes")
        _assert_bitwise(sj, sp, "scales")
        # tail block scale includes the zero padding (canonical semantics)
        numel = int(np.prod(shape))
        assert cj.shape == (-(-numel // block), block)


class TestModeUpdateParity:
    """The per-mode update cores (what repro.dist.modes and
    repro.core.qadam actually call), jnp vs pallas."""

    @pytest.mark.parametrize("shape", [(100,), (256, 128), (5, 333),
                                       (40000,)])
    @pytest.mark.parametrize("k_g", [1, 4, 6])
    def test_qadam_fused_step(self, shape, k_g):
        seed = abs(hash((shape, k_g))) % 1000
        g = _rand(shape, seed=seed)
        m = _rand(shape, seed=seed + 1, scale=0.1)
        v = jnp.abs(_rand(shape, seed=seed + 2, scale=0.01))
        e = _rand(shape, seed=seed + 3, scale=1e-3)
        oj, op = _both(lambda backend: engine.adam_ef_step(
            g, m, v, e, 1e-3, 0.99, 0.9, 1e-5, k_g=k_g, backend=backend))
        for name, a, b in zip(["m", "v", "codes", "scale", "e"], oj, op):
            _assert_bitwise(a, b, name)

    def test_qadam_single_machine_update(self):
        g = _rand((4096,), seed=5)
        m = jnp.zeros_like(g)
        oj, op = _both(lambda backend: engine.adam_ef_update(
            g, m, m, m, 1e-2, 0.99, 0.5, 1e-5, k_g=4, backend=backend))
        for name, a, b in zip(["delta", "m", "v", "e"], oj, op):
            _assert_bitwise(a, b, name)

    def test_qadam_no_error_feedback(self):
        g = _rand((1000,), seed=6)
        z = jnp.zeros_like(g)
        for backend in ("jnp", "pallas"):
            _, _, _, e2 = engine.adam_ef_update(
                g, z, z, z, 1e-2, 0.99, 0.5, 1e-5, k_g=4,
                error_feedback=False, backend=backend)
            assert float(jnp.max(jnp.abs(e2))) == 0.0

    def test_dp_adam_moments(self):
        """dp_adam routes through adam_ef_moments with a zero residual."""
        g = _rand((2048,), seed=7)
        m = _rand((2048,), seed=8, scale=0.1)
        v = jnp.abs(_rand((2048,), seed=9, scale=0.01))
        z = jnp.zeros_like(g)
        oj, op = _both(lambda backend: engine.adam_ef_moments(
            g, m, v, z, 1e-3, 0.99, 0.9, 1e-5, backend=backend))
        for name, a, b in zip(["m", "v", "de"], oj, op):
            _assert_bitwise(a, b, name)

    def test_ef_sgd_blockwise(self):
        """ef_sgd's wire: blockwise sign codes of Delta+e."""
        de = _rand((5000,), seed=10, scale=1e-2)
        (cj, sj), (cp, sp) = _both(lambda backend: engine.quantize_blockwise(
            de, 256, backend=backend))
        _assert_bitwise(cj, cp)
        _assert_bitwise(sj, sp)
        # EF residual derived from the canonical dequantize is identical
        ej = de - grids.blockwise_dequantize(cj, sj).reshape(-1)[:5000]
        ep = de - grids.blockwise_dequantize(cp, sp).reshape(-1)[:5000]
        _assert_bitwise(ej, ep)

    def test_terngrad_update(self):
        g = _rand((3000,), seed=12)
        key = jax.random.PRNGKey(42)
        (cj, sj), (cp, sp) = _both(lambda backend: engine.quantize_ternary(
            g, key, backend=backend))
        _assert_bitwise(cj, cp)
        _assert_bitwise(grids.ternary_dequantize(cj, sj),
                        grids.ternary_dequantize(cp, sp))


class TestSingleMachineEngineRouting:
    def test_qadam_backends_trajectories_identical(self):
        """Acceptance: the single-machine qadam() optimizer produces
        bit-identical parameters under backend='jnp' and 'pallas'."""
        from repro.core.qadam import QAdamConfig, qadam, apply_updates
        rng = np.random.default_rng(3)
        params0 = {"w": jnp.asarray(rng.normal(size=(64, 32), scale=0.1)
                                    .astype(np.float32)),
                   "b": jnp.asarray(rng.normal(size=(32,), scale=0.1)
                                    .astype(np.float32))}
        grads = [{"w": jnp.asarray(rng.normal(size=(64, 32))
                                   .astype(np.float32)),
                  "b": jnp.asarray(rng.normal(size=(32,))
                                   .astype(np.float32))}
                 for _ in range(5)]
        finals = {}
        for backend in ("jnp", "pallas"):
            cfg = QAdamConfig(alpha=1e-2, grad_q="log:4", schedule="sqrt",
                              backend=backend)
            opt = qadam(cfg)
            params, state = params0, opt.init(params0)
            for g in grads:
                upd, state = opt.update(g, state, params)
                params = apply_updates(params, upd)
            finals[backend] = (params, state)
        for leaf in ("w", "b"):
            _assert_bitwise(finals["jnp"][0][leaf],
                            finals["pallas"][0][leaf], leaf)
            _assert_bitwise(finals["jnp"][1].e[leaf],
                            finals["pallas"][1].e[leaf], f"e[{leaf}]")

    def test_resolve_backend(self):
        assert engine.resolve_backend("jnp") == "jnp"
        assert engine.resolve_backend("pallas", 1) == "pallas"
        with pytest.raises(ValueError):
            engine.resolve_backend("cuda")
        # auto off-TPU is jnp (this CI runs on CPU)
        if jax.default_backend() != "tpu":
            assert engine.resolve_backend(None, 10 ** 9) == "jnp"
