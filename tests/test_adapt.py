"""repro.adapt: allocator invariants, stats EMA, and the adaptive
controller end to end (device stats ring -> replan -> codec swap).

The allocator properties (budget respected, monotone in budget, legal
lane widths only) run twice: a deterministic seeded sweep that always
executes, and a hypothesis fuzz that engages wherever hypothesis is
installed (requirements-dev.txt; CI runs it).
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.adapt import allocate as A
from repro.adapt import stats as S
from repro.comm import bits as B


def _rand_groups(rng, n):
    return [A.Group(name=f"g{i}",
                    numel=int(rng.integers(1, 5000)),
                    c=int(rng.integers(1, 5000)),
                    amax=float(rng.uniform(1e-6, 10.0)),
                    meansq=float(rng.uniform(1e-12, 1.0)))
            for i in range(n)]


def _check_alloc(groups, budget, n_workers):
    widths = A.allocate(groups, budget, n_workers)
    assert len(widths) == len(groups)
    assert all(w in A.WIDTHS for w in widths)
    cost = A.plan_cost(groups, widths, n_workers)
    floor = sum(A._hull_chain(g, n_workers)[0][0] for g in groups)
    # budget respected whenever it is satisfiable at all
    assert cost <= max(budget, floor)
    return widths, cost


class TestAllocator:
    def test_width_specs_cover_supported_lanes(self):
        assert set(A.WIDTH_SPECS) == set(B.SUPPORTED_BITS)
        from repro import comm
        for w, spec in A.WIDTH_SPECS.items():
            assert comm.get_codec(spec).bits == w, spec

    def test_distortion_decreases_with_width(self):
        for amax, meansq in ((1.0, 0.1), (3.0, 0.5), (1e-3, 1e-7)):
            ds = [A.expected_distortion(w, amax, meansq)
                  for w in (3, 4, 6, 8)]
            assert all(a >= b for a, b in zip(ds, ds[1:])), ds

    def test_rich_budget_gives_widest_lanes(self):
        groups = _rand_groups(np.random.default_rng(0), 6)
        widths = A.allocate(groups, 10 ** 12, n_workers=4)
        # unconstrained: every group sits at its hull's best vertex
        for g, w in zip(groups, widths):
            assert w == A._hull_chain(g, 4)[-1][2]

    def test_deterministic(self):
        groups = _rand_groups(np.random.default_rng(1), 8)
        a = A.allocate(groups, 10_000, 4)
        assert a == A.allocate(groups, 10_000, 4)

    def test_seeded_sweep_budget_and_monotone(self):
        """Always-on stand-in for the hypothesis fuzz."""
        rng = np.random.default_rng(42)
        for trial in range(25):
            groups = _rand_groups(rng, int(rng.integers(1, 10)))
            n_workers = int(rng.integers(1, 9))
            budgets = sorted(int(rng.integers(0, 200_000))
                             for _ in range(4))
            prev = None
            for budget in budgets:
                widths, _ = _check_alloc(groups, budget, n_workers)
                if prev is not None:
                    # more budget never narrows any lane
                    assert all(w2 >= w1 for w1, w2 in zip(prev, widths)), \
                        (prev, widths, budget)
                prev = widths

    def test_specs_match_widths(self):
        groups = _rand_groups(np.random.default_rng(3), 5)
        widths = A.allocate(groups, 50_000, 2)
        specs = A.allocate_specs(groups, 50_000, 2)
        assert specs == tuple(A.WIDTH_SPECS[w] for w in widths)

    def test_empty_groups(self):
        assert A.allocate([], 100, 2) == ()


# hypothesis fuzz: runs wherever the package is installed
# (requirements-dev.txt -> CI); the seeded sweep above always runs.
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    st = None

if st is not None:
    group_st = st.builds(
        A.Group,
        name=st.just("g"),
        numel=st.integers(1, 100_000),
        c=st.integers(1, 100_000),
        amax=st.floats(1e-9, 100.0, allow_nan=False,
                       allow_infinity=False),
        meansq=st.floats(1e-15, 10.0, allow_nan=False,
                         allow_infinity=False))

    class TestAllocatorFuzz:
        @settings(max_examples=60, deadline=None)
        @given(groups=st.lists(group_st, min_size=1, max_size=8),
               budget=st.integers(0, 10 ** 7),
               n_workers=st.integers(1, 16))
        def test_budget_respected_and_legal(self, groups, budget,
                                            n_workers):
            _check_alloc(groups, budget, n_workers)

        @settings(max_examples=60, deadline=None)
        @given(groups=st.lists(group_st, min_size=1, max_size=6),
               b1=st.integers(0, 10 ** 6), extra=st.integers(0, 10 ** 6),
               n_workers=st.integers(1, 8))
        def test_monotone_in_budget(self, groups, b1, extra, n_workers):
            w1 = A.allocate(groups, b1, n_workers)
            w2 = A.allocate(groups, b1 + extra, n_workers)
            assert all(a <= b for a, b in zip(w1, w2))


class TestStatsEMA:
    def test_debias_single_update(self):
        ema = S.StatsEMA(2, decay=0.9)
        rows = np.array([[1.0, 0.5, 0.25], [2.0, 1.0, 0.5]])
        ema.update(rows)
        np.testing.assert_allclose(ema.snapshot(), rows)

    def test_peak_hold_amax(self):
        ema = S.StatsEMA(1, decay=0.5)
        ema.update(np.array([[8.0, 1.0, 1.0]]))
        ema.update(np.array([[1.0, 1.0, 1.0]]))
        # one small observation must not collapse the held peak
        assert ema.amax[0] >= 4.0
        assert ema.snapshot()[0, 0] == ema.amax[0]

    def test_shape_validated(self):
        ema = S.StatsEMA(3)
        with pytest.raises(ValueError):
            ema.update(np.zeros((2, S.N_FIELDS)))

    def test_local_and_reduce_stats(self):
        de = jnp.array([1.0, -3.0, 0.5])
        g = jnp.array([2.0, 2.0, 2.0])
        row = S.local_stats(de, g)
        np.testing.assert_allclose(
            np.asarray(row), [3.0, np.mean([1, 9, 0.25]), 4.0], rtol=1e-6)


class TestController:
    @pytest.fixture(scope="class")
    def setup(self):
        from repro.configs import get_config
        from repro.models.model import Model
        from repro.dist.step import TrainConfig
        model = Model(get_config("yi-6b", smoke=True))
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        tc = TrainConfig(worker_axes=("data",), mode="adaptive")
        return model, mesh, tc

    def _batches(self, model):
        k = jax.random.PRNGKey(0)
        v = model.cfg.vocab_size
        while True:
            k, s = jax.random.split(k)
            tok = jax.random.randint(s, (2, 16), 0, v)
            yield {"tokens": tok, "targets": tok}

    def test_controller_replans_and_accounts(self, setup):
        from repro.adapt.controller import AdaptConfig, AdaptiveController
        model, mesh, tc = setup
        acfg = AdaptConfig(replan_every=2)
        ctl = AdaptiveController(model, mesh, tc, self._batches(model),
                                 acfg, key=jax.random.PRNGKey(0),
                                 log=lambda *_: None, verify=True)
        try:
            ctl.run(6)
            # stats ring discipline: one harvest sync per replan window
            assert ctl.stats["syncs"] == math.ceil(6 / 2)
            assert ctl.replans >= 1
            # every recorded plan passed accounted == measured (verify=True)
            assert all("verify" in e for e in ctl.plan_log)
            # the adaptive plan actually shrinks the wire vs the log grid
            first = ctl.plan_log[0]["comm"]["update_exchange_bytes"]
            last = ctl.plan_log[-1]["comm"]["update_exchange_bytes"]
            assert last < first
            losses = ctl.session.harvest_losses()
            assert losses and all(np.isfinite(v) for _, v in losses)
        finally:
            ctl.close()

    def test_swap_preserves_state_bitwise(self, setup):
        """A replan changes only the wire: state before the swap equals
        state after (the swap itself moves no buffers)."""
        from repro.adapt.controller import AdaptConfig, AdaptiveController
        model, mesh, tc = setup
        ctl = AdaptiveController(model, mesh, tc, self._batches(model),
                                 AdaptConfig(replan_every=2),
                                 key=jax.random.PRNGKey(1),
                                 log=lambda *_: None)
        try:
            ctl.session.run(2)
            for _, rows in ctl.session.harvest_stats():
                ctl.ema.update(rows)
            before = jax.tree.map(np.asarray, ctl.state)
            assert ctl.replan()
            after = jax.tree.map(np.asarray, ctl.state)
            jax.tree.map(np.testing.assert_array_equal, before, after)
        finally:
            ctl.close()

    def test_plan_for_model_uniform_prior(self, setup):
        from repro.adapt.controller import plan_for_model
        model, mesh, tc = setup
        tc2, art2, rep = plan_for_model(model, mesh, tc, budget_ratio=0.6)
        assert tc2.bit_plan is not None
        assert len(tc2.bit_plan) == len(rep["rows"])
        assert rep["plan_bytes"] <= rep["budget_bytes"]
        assert rep["budget_bytes"] == int(0.6 * rep["baseline_bytes"])
        from repro.train.loop import comm_bytes_per_step
        assert comm_bytes_per_step(art2, tc2)["update_exchange_bytes"] \
            == rep["plan_bytes"]
