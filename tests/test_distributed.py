"""Multi-device (8 simulated CPU devices) integration tests.

Each case runs in a subprocess because XLA fixes the device count at first
jax initialization (smoke tests in this process must see 1 device).
"""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

SCRIPTS = os.path.join(os.path.dirname(__file__), "dist_scripts")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script, *args, timeout=560, extra_env=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC)
    env.update(extra_env or {})
    p = subprocess.run(
        [sys.executable, os.path.join(SCRIPTS, script), *args],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert p.returncode == 0, f"{script} {args}:\n{p.stdout}\n{p.stderr}"
    assert "OK" in p.stdout, p.stdout


class TestAlgorithmEquivalence:
    def test_multiworker_equals_single_machine(self):
        """Algorithms 2+3 with identical workers == Algorithm 1 (quantized,
        EF on, weight quantization on): the core distributed-correctness
        claim of the reproduction."""
        _run("train_equiv_single.py")


@pytest.mark.parametrize("arch", [
    "yi-6b",            # dense GQA (KV all_gather)
    "mamba2-2.7b",      # SSD chunk-state passing across devices
    "hymba-1.5b",       # hybrid + meta tokens + conv halo
    "deepseek-moe-16b", # expert-parallel all_to_all
    "whisper-small",    # enc-dec, cross attention
    "gemma3-4b",        # local:global pattern + qk-norm
])
class TestContextParallel:
    def test_cp_equivalence(self, arch):
        """(pod,data,model) sharded training == unsharded training."""
        _run("cp_equiv.py", arch)


@pytest.mark.parametrize("arch", [
    "yi-6b",          # dense GQA
    "mamba2-2.7b",    # recurrent state decode
    "hymba-1.5b",     # hybrid + meta-token KV prefix
    "gemma2-2b",      # sliding-window masks over a sharded cache
    "whisper-small",  # enc-dec: sharded cross-attention cache
])
class TestShardedServe:
    def test_serve_equivalence(self, arch):
        """Sequence-sharded KV-cache decode == single-device decode."""
        _run("serve_equiv.py", arch)


class TestPerfVariantsSharded:
    def test_ssd_ladder_cp_equivalence(self):
        """ppermute prefix-ladder state exchange == gather under real CP."""
        _run("cp_equiv.py", "mamba2-2.7b",
             extra_env={"REPRO_SSD_EXCHANGE": "ladder"})

    def test_moe_sort_cp_equivalence(self):
        """sort-based dispatch == einsum dispatch under EP all_to_all."""
        _run("cp_equiv.py", "deepseek-moe-16b",
             extra_env={"REPRO_MOE_DISPATCH": "sort"})


class TestBaselineOptimizerModes:
    def test_distributed_terngrad_and_ef_sgd(self):
        """The paper's comparison baselines as distributed optimizers."""
        _run("opt_modes.py")


class TestDryRunReduced:
    def test_dryrun_smoke(self):
        """The dry-run pipeline itself (reduced: 8 devices, smoke configs)."""
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.abspath(SRC)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        p = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", "yi-6b",
             "--shape", "train_4k", "--smoke", "--mesh", "single"],
            capture_output=True, text=True, timeout=560, env=env,
            cwd=os.path.dirname(SCRIPTS))
        assert p.returncode == 0, p.stdout + p.stderr
        assert "[OK]" in p.stdout, p.stdout + p.stderr
