"""Wire-format tests: bit-packing round-trips and the guarantee that the
distributed channels ship *packed uint8* payloads of exactly the
advertised size."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.packing import pack_codes, unpack_codes, packed_nbytes
from repro.dist import collectives as C
from repro.dist import sharding as SH
from repro.dist.modes import get_mode


def _codes(numel, bits, seed=0):
    rng = np.random.default_rng(seed)
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    return jnp.asarray(rng.integers(lo, hi + 1, size=(numel,)), jnp.int8)


class TestPackRoundtrip:
    @pytest.mark.parametrize("bits", [2, 4, 8])
    @pytest.mark.parametrize("numel", [1, 3, 7, 64, 129, 1000])
    def test_roundtrip(self, bits, numel):
        """unpack(pack(c, b), b, n) == c, including non-divisible numel
        (the pad codes must not leak back)."""
        c = _codes(numel, bits, seed=numel * bits)
        p = pack_codes(c, bits)
        assert p.dtype == jnp.uint8
        assert p.shape == (packed_nbytes(numel, bits),)
        back = unpack_codes(p, bits, numel)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(c))

    @pytest.mark.parametrize("bits", [2, 4, 8])
    def test_row_packing_payload_size(self, bits):
        """Per-worker-row packing: payload is (n_workers,
        packed_nbytes(c, bits)) uint8 - the exact array the all_to_all
        moves."""
        n_workers, numel = 4, 1003
        c = SH.chunk_size(numel, n_workers)
        rows = SH.flatten_pad(_codes(numel, bits), n_workers)
        packed = C.pack_rows(rows, bits)
        assert packed.dtype == jnp.uint8
        assert packed.shape == (n_workers, packed_nbytes(c, bits))
        back = C.unpack_rows(packed, bits, c)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(rows))

    def test_log_wire_bits(self):
        assert C.wire_bits_for_log(0) == 2
        assert C.wire_bits_for_log(4) == 4
        assert C.wire_bits_for_log(6) == 4
        assert C.wire_bits_for_log(7) == 8

    @pytest.mark.parametrize("grad_k,bits", [(4, 4), (6, 4), (7, 8)])
    def test_accounting_matches_packed_nbytes(self, grad_k, bits):
        n_workers, numel = 8, 5000
        c = SH.chunk_size(numel, n_workers)
        qadam = get_mode("qadam")
        assert qadam.wire_nbytes(c, n_workers, grad_k) == \
            n_workers * packed_nbytes(c, bits)
        assert qadam.wire_nbytes(c, n_workers, None) == \
            n_workers * c * 4
        assert C.weight_broadcast_nbytes(c, n_workers, numel, 7) == \
            n_workers * packed_nbytes(c, 8)


class TestChannelsShipPackedUint8:
    """Drive the actual collective channels under shard_map and assert the
    wire arrays are packed uint8 of the advertised size."""

    def _mesh(self):
        return jax.make_mesh((1,), ("data",))

    @pytest.mark.parametrize("k_g", [4, 6])
    def test_update_exchange(self, k_g):
        mesh = self._mesh()
        numel, n_workers = 777, 1
        bits = C.wire_bits_for_log(k_g)
        codes = _codes(numel, bits, seed=k_g)

        def f(cd):
            rows, payload = C.exchange_packed(cd, bits, n_workers,
                                              ("data",), (1,))
            return rows, payload

        rows, payload = jax.jit(shard_map(
            f, mesh=mesh, in_specs=P(None), out_specs=(P(), P()),
            check_rep=False))(codes)
        c = SH.chunk_size(numel, n_workers)
        assert payload.dtype == jnp.uint8
        assert payload.shape == (n_workers, packed_nbytes(c, bits))
        assert payload.nbytes == get_mode("qadam").wire_nbytes(c, n_workers,
                                                               k_g)
        np.testing.assert_array_equal(
            np.asarray(rows).reshape(-1)[:numel], np.asarray(codes))

    def test_weight_broadcast(self):
        mesh = self._mesh()
        chunk = jnp.asarray(
            np.random.default_rng(3).normal(size=(513,)).astype(np.float32)
            * 0.05)

        def f(x):
            codes = C.uniform_wire_codes(x, jnp.float32(0.5), 7)
            return C.broadcast_packed(codes, ("data",)), codes

        rows, codes = jax.jit(shard_map(
            f, mesh=mesh, in_specs=P(None), out_specs=(P(), P()),
            check_rep=False))(chunk)
        assert rows.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(rows[0]),
                                      np.asarray(codes))
