"""Wire-format tests: bit-packing round-trips and the guarantee that the
distributed channels ship *packed uint8* payloads of exactly the
codec-advertised size."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro import comm
from repro.core.packing import pack_codes, unpack_codes, packed_nbytes
from repro.dist import collectives as C
from repro.dist import sharding as SH
from repro.dist.modes import get_mode


def _codes(numel, bits, seed=0):
    rng = np.random.default_rng(seed)
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    dtype = np.int16 if bits == 16 else np.int8
    return jnp.asarray(rng.integers(lo, hi + 1, size=(numel,)).astype(dtype))


class TestPackRoundtrip:
    @pytest.mark.parametrize("bits", list(comm.SUPPORTED_BITS))
    @pytest.mark.parametrize("numel", [1, 3, 7, 64, 129, 1000])
    def test_roundtrip(self, bits, numel):
        """unpack(pack(c, b), b, n) == c, including non-divisible numel
        (the pad codes must not leak back) and the odd 3/6-bit widths
        that pack across byte boundaries."""
        c = _codes(numel, bits, seed=numel * bits)
        p = pack_codes(c, bits)
        assert p.dtype == jnp.uint8
        assert p.shape == (packed_nbytes(numel, bits),)
        back = unpack_codes(p, bits, numel)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(c))

    @pytest.mark.parametrize("bits", [2, 3, 4, 6, 8])
    def test_row_packing_payload_size(self, bits):
        """Per-worker-row packing: payload is (n_workers,
        packed_nbytes(c, bits)) uint8 - the exact array the all_to_all
        moves."""
        n_workers, numel = 4, 1003
        c = SH.chunk_size(numel, n_workers)
        rows = SH.flatten_pad(_codes(numel, bits), n_workers)
        packed = C.pack_rows(rows, bits)
        assert packed.dtype == jnp.uint8
        assert packed.shape == (n_workers, packed_nbytes(c, bits))
        back = C.unpack_rows(packed, bits, c)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(rows))

    def test_log_wire_bits(self):
        """Codec-derived lane widths; the 3- and 6-bit lanes pack small
        and large log grids tighter than the old {2,4,8}-only packer."""
        assert C.wire_bits_for_log(0) == 2
        assert C.wire_bits_for_log(1) == 3
        assert C.wire_bits_for_log(4) == 4
        assert C.wire_bits_for_log(6) == 4
        assert C.wire_bits_for_log(7) == 6

    @pytest.mark.parametrize("grad_k,bits", [(2, 3), (4, 4), (6, 4), (7, 6)])
    def test_accounting_matches_packed_nbytes(self, grad_k, bits):
        n_workers, numel = 8, 5000
        c = SH.chunk_size(numel, n_workers)
        qadam = get_mode("qadam")
        assert comm.LogCodec(k_g=grad_k).bits == bits
        assert qadam.wire_nbytes(c, n_workers, grad_k) == \
            n_workers * packed_nbytes(c, bits)
        assert qadam.wire_nbytes(c, n_workers, None) == \
            n_workers * c * 4
        assert comm.uniform_wire_codec(7).payload_nbytes(c) == \
            packed_nbytes(c, 8)


class TestChannelsShipPackedUint8:
    """Drive the actual collective channels under shard_map and assert the
    wire arrays are codec payload rows of exactly the advertised size."""

    def _mesh(self):
        return jax.make_mesh((1,), ("data",))

    @pytest.mark.parametrize("k_g", [4, 6])
    def test_update_exchange(self, k_g):
        mesh = self._mesh()
        numel, n_workers = 777, 1
        codec = comm.LogCodec(k_g=k_g)
        x = jnp.asarray(
            np.random.default_rng(k_g).normal(size=(numel,))
            .astype(np.float32))

        def f(v):
            payload, scale = comm.encode_rows(v, codec, n_workers)
            rows = C.exchange_decode(payload, scale, codec, numel,
                                     ("data",), (1,))
            return rows, payload, scale

        rows, payload, scale = jax.jit(shard_map(
            f, mesh=mesh, in_specs=P(None), out_specs=(P(), P(), P()),
            check_rep=False))(x)
        c = SH.chunk_size(numel, n_workers)
        assert payload.dtype == jnp.uint8
        assert payload.shape == (n_workers, codec.payload_nbytes(c))
        assert payload.nbytes == get_mode("qadam").wire_nbytes(c, n_workers,
                                                               k_g)
        # the channel round-trips the codec's own quantize->dequantize
        expect = codec.dequantize(codec.quantize(x, scale), scale)
        np.testing.assert_array_equal(
            np.asarray(rows).reshape(-1)[:numel], np.asarray(expect))

    def test_weight_broadcast(self):
        mesh = self._mesh()
        codec = comm.uniform_wire_codec(7)
        chunk = jnp.asarray(
            np.random.default_rng(3).normal(size=(513,)).astype(np.float32)
            * 0.05)

        def f(x):
            scale = codec.compute_scale(x)
            payload, _ = comm.encode_rows_ef(x, scale, codec, 1)
            rows = C.broadcast_decode(payload[0], scale, codec,
                                      x.shape[0], ("data",))
            return rows, payload

        rows, payload = jax.jit(shard_map(
            f, mesh=mesh, in_specs=P(None), out_specs=(P(), P()),
            check_rep=False))(chunk)
        assert payload.dtype == jnp.uint8
        assert payload.nbytes == codec.payload_nbytes(chunk.shape[0])
        expect = codec.dequantize(
            codec.quantize(chunk, jnp.float32(0.5)), jnp.float32(0.5))
        np.testing.assert_array_equal(np.asarray(rows[0]),
                                      np.asarray(expect))
