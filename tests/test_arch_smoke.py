"""Per-architecture smoke tests: reduced config (2 layers, d_model<=512,
<=4 experts), one forward/train step + one decode step on CPU; asserts
output shapes and absence of NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.model import Model
from repro.core.qadam import QAdamConfig, qadam, apply_updates

B, S = 2, 16


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    b = {}
    if cfg.input_mode == "embeddings":
        b["embeds"] = jax.random.normal(ks[0], (B, S, cfg.d_model),
                                        jnp.float32)
    else:
        b["tokens"] = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
    if cfg.input_mode == "audio+tokens":
        b["audio"] = jax.random.normal(ks[2], (B, cfg.encoder_seq,
                                               cfg.d_model), jnp.float32)
    b["targets"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    b["mask"] = jnp.ones((B, S), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_and_train_step(self, arch):
        cfg = get_config(arch, smoke=True)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = _batch(cfg, jax.random.PRNGKey(1))

        def loss_fn(p):
            ls, n = model.loss(p, batch)
            return ls / n

        loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
        assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
        leaves = jax.tree.leaves(grads)
        assert all(np.all(np.isfinite(np.asarray(l))) for l in leaves), arch
        # plausible LM init loss ~ log(V)
        assert float(loss) < 2 * np.log(cfg.vocab_size) + 5

        # one QAdam step end to end
        opt = qadam(QAdamConfig(alpha=1e-3, grad_q="log:6",
                                weight_q="uniform_amax:7"))
        state = opt.init(params)
        fp = opt.forward_params(params, state)
        _, grads2 = jax.value_and_grad(loss_fn)(fp)
        upd, state = opt.update(grads2, state, params)
        params2 = apply_updates(params, upd)
        l2, _ = model.loss(params2, batch)
        assert np.isfinite(float(l2)), arch
        # params actually moved
        moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                             params, params2)
        assert max(jax.tree.leaves(moved)) > 0, arch

    def test_decode_step(self, arch):
        cfg = get_config(arch, smoke=True)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        cache = model.init_cache(B, max_seq_local=S,
                                 encoder_seq_local=cfg.encoder_seq or 0)
        if cfg.arch_type == "encdec":
            audio = jax.random.normal(jax.random.PRNGKey(2),
                                      (B, cfg.encoder_seq, cfg.d_model),
                                      jnp.float32)
            cache = model.prefill_encoder(params, audio, cache)
        if cfg.input_mode == "embeddings":
            inputs = {"embeds": jax.random.normal(
                jax.random.PRNGKey(3), (B, 1, cfg.d_model), jnp.float32)}
        else:
            inputs = {"token": jnp.array([[1], [2]], jnp.int32)}

        step = jax.jit(lambda p, i, c, pos: model.decode_step(p, i, c, pos))
        logits, cache = step(params, inputs, cache, jnp.int32(0))
        assert logits.shape == (B, cfg.vocab_size), arch
        assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch
        logits2, cache = step(params, inputs, cache, jnp.int32(1))
        assert np.all(np.isfinite(np.asarray(logits2, np.float32))), arch
        # cache must have changed
        if "k" in cache:
            assert float(jnp.max(jnp.abs(cache["k"]))) > 0, arch
        else:
            assert float(jnp.max(jnp.abs(cache["ssm"]))) > 0, arch

    def test_decode_matches_forward(self, arch):
        """Greedy-decode logits at position t == forward logits at t."""
        cfg = get_config(arch, smoke=True)
        if cfg.input_mode == "embeddings":
            pytest.skip("embeddings-input: covered via forward test")
        if cfg.moe is not None:
            # capacity drops are a train-time-only effect; make the test
            # drop-free so routing equivalence is exact
            import dataclasses
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = _batch(cfg, jax.random.PRNGKey(1))
        if cfg.arch_type == "encdec":
            fwd_logits, _ = model.forward(params, batch)
        else:
            fwd_logits, _ = model.forward(params, batch)
        cache = model.init_cache(B, max_seq_local=S,
                                 encoder_seq_local=cfg.encoder_seq or 0)
        if cfg.arch_type == "encdec":
            cache = model.prefill_encoder(params, batch["audio"], cache)
        toks = batch["tokens"]
        step = jax.jit(lambda p, i, c, pos: model.decode_step(p, i, c, pos))
        for t in range(4):
            logits_t, cache = step(params, {"token": toks[:, t:t + 1]},
                                   cache, jnp.int32(t))
            np.testing.assert_allclose(
                np.asarray(logits_t, np.float32),
                np.asarray(fwd_logits[:, t], np.float32),
                rtol=2e-2, atol=2e-3,
                err_msg=f"{arch} t={t}")
