"""Unit + property tests for the paper's quantizers and bit packing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import quantizers as Q
from repro.core.packing import pack_codes, unpack_codes, packed_nbytes


def _rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape, scale=scale).astype(np.float32))


class TestLogGrid:
    def test_exact_levels_roundtrip(self):
        # grid points must be reproduced exactly
        k = 4
        q = Q.LogGradQuantizer(k_g=k)
        levels = np.array([2.0 ** -e for e in range(k + 1)])
        x = jnp.asarray(np.concatenate([levels, -levels, [0.0]]).astype(np.float32))
        np.testing.assert_allclose(np.asarray(q(x)), np.asarray(x), rtol=1e-6)

    def test_nearest_in_linear_space(self):
        q = Q.LogGradQuantizer(k_g=4)
        # 0.8 with amax 1.0: nearest of {1.0, 0.5} in linear space is 1.0
        x = jnp.asarray([1.0, 0.8, 0.7, 0.3, 0.76, 0.74])
        out = np.asarray(q(x))
        np.testing.assert_allclose(out, [1.0, 1.0, 0.5, 0.25, 1.0, 0.5], rtol=1e-6)

    def test_zero_threshold(self):
        q = Q.LogGradQuantizer(k_g=2)  # min level 0.25
        x = jnp.asarray([1.0, 0.13, 0.12, 0.0])
        out = np.asarray(q(x))
        np.testing.assert_allclose(out, [1.0, 0.25, 0.0, 0.0], rtol=1e-6)

    @given(st.integers(1, 7), st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=30, deadline=None)
    def test_contraction_property(self, k_g, seed):
        # Assumption 2: ||g - Q(g)|| <= (1 - delta) ||g|| with delta > 0
        g = _rand((257,), seed=seed)
        q = Q.LogGradQuantizer(k_g=k_g)
        err = np.linalg.norm(np.asarray(g - q(g)))
        nrm = np.linalg.norm(np.asarray(g))
        assert err < nrm  # strict contraction for nonzero g

    def test_codes_fit_bits(self):
        for k in (2, 4, 6):
            q = Q.LogGradQuantizer(k_g=k)
            qt = q.encode(_rand((1000,), seed=1))
            assert int(jnp.max(jnp.abs(qt.codes))) <= 2 ** (Q.log_bits(k) - 1) - 1

    def test_scale_invariance(self):
        q = Q.LogGradQuantizer(k_g=5)
        g = _rand((128,), seed=3)
        np.testing.assert_allclose(np.asarray(q(g * 1000.0)),
                                   np.asarray(q(g)) * 1000.0, rtol=1e-4)


class TestUniform:
    def test_grid_points_exact(self):
        k = 3
        q = Q.UniformWeightQuantizer(k_x=k)
        grid = np.arange(-8, 9) / 8.0 * 0.5  # the paper's X scaled by 0.5
        x = jnp.asarray(grid.astype(np.float32))
        np.testing.assert_allclose(np.asarray(q(x)), np.asarray(x), atol=1e-7)

    @given(st.integers(1, 6), st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=30, deadline=None)
    def test_additive_bound(self, k_x, seed):
        # Assumption 3: per-coordinate error <= half grid spacing (in-range x)
        x = _rand((300,), seed=seed, scale=0.15)
        x = jnp.clip(x, -0.5, 0.5)
        q = Q.UniformWeightQuantizer(k_x=k_x)
        err = np.max(np.abs(np.asarray(x - q(x))))
        assert err <= 0.5 / 2 ** k_x / 2 + 1e-7

    def test_amax_mode(self):
        q = Q.UniformWeightQuantizer(k_x=4, absolute=False)
        x = _rand((64,), seed=7, scale=10.0)
        rel = np.max(np.abs(np.asarray(x - q(x)))) / np.max(np.abs(np.asarray(x)))
        assert rel <= 0.5 / 2 ** 4 + 1e-6


class TestTernGrad:
    def test_unbiased(self):
        g = _rand((64,), seed=5)
        q = Q.TernGradQuantizer()
        keys = jax.random.split(jax.random.PRNGKey(0), 3000)
        samples = jax.vmap(lambda k: q(g, key=k))(keys)
        mean = np.asarray(jnp.mean(samples, axis=0))
        np.testing.assert_allclose(mean, np.asarray(g), atol=0.08)

    def test_levels(self):
        g = _rand((512,), seed=6)
        q = Q.TernGradQuantizer()
        out = np.asarray(q(g, key=jax.random.PRNGKey(1)))
        amax = float(jnp.max(jnp.abs(g)))
        assert set(np.round(np.unique(out) / amax).astype(int)) <= {-1, 0, 1}


class TestBlockwise:
    def test_block_scale(self):
        g = _rand((512,), seed=8)
        q = Q.BlockwiseQuantizer(block=128)
        out = np.asarray(q(g))
        g_np = np.asarray(g).reshape(4, 128)
        expect = np.sign(g_np) * np.mean(np.abs(g_np), axis=1, keepdims=True)
        np.testing.assert_allclose(out, expect.reshape(-1), rtol=1e-6)

    def test_nonmultiple_shape(self):
        g = _rand((130, 3), seed=9)
        q = Q.BlockwiseQuantizer(block=256)
        assert q(g).shape == (130, 3)


class TestPacking:
    @given(st.sampled_from([2, 4, 8]), st.integers(1, 999),
           st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=40, deadline=None)
    def test_roundtrip(self, bits, numel, seed):
        rng = np.random.default_rng(seed)
        lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
        codes = jnp.asarray(rng.integers(lo, hi + 1, size=numel).astype(np.int8))
        packed = pack_codes(codes, bits)
        assert packed.dtype == jnp.uint8
        assert packed.shape[0] == packed_nbytes(numel, bits) or bits == 8
        out = unpack_codes(packed, bits, numel)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))

    def test_wire_size_reduction(self):
        # 4-bit packing halves the int8 payload; this is the paper's "Comm"
        codes = jnp.zeros((1000,), jnp.int8)
        assert pack_codes(codes, 4).size == 500
        assert pack_codes(codes, 2).size == 250


class TestSpecParsing:
    @pytest.mark.parametrize("spec,cls", [
        ("none", Q.IdentityQuantizer), ("log:4", Q.LogGradQuantizer),
        ("uniform:5", Q.UniformWeightQuantizer), ("terngrad", Q.TernGradQuantizer),
        ("blockwise:64", Q.BlockwiseQuantizer)])
    def test_parse(self, spec, cls):
        assert isinstance(Q.get_quantizer(spec), cls)

    def test_qtensor_wire_bytes(self):
        q = Q.LogGradQuantizer(k_g=6)
        qt = q.encode(_rand((1024,)))
        assert qt.nbytes_wire == 1024 * 4 // 8 + 4
