"""Property tests for the ``repro.comm`` codec stack.

Three contracts, every codec, every supported lane width (including the
odd 3/6-bit widths that pack across byte boundaries and the int16
k_x=7 uniform path):

  1. encode -> decode round-trips the quantizer's own Q(.) exactly;
  2. the fused Pallas kernels are BITWISE identical to the jnp
     reference backend (payloads, scales, decoded values, EF residuals);
  3. ``wire_nbytes``/``payload_nbytes`` equal the actual buffer bytes.

The deterministic sweeps below always run; the randomized ``TestFuzz``
section additionally property-tests the same contracts when hypothesis
is installed (requirements-dev.txt).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:           # fuzz section skips; sweeps still run
    HAVE_HYPOTHESIS = False

from repro import comm
from repro.comm import bits as B
from repro.opt import grids

# every codec family at every lane width the registry can emit:
# log 2/3/4/6-bit, uniform 3/4/6/8/16-bit (16 = the int16 k_x=7 path),
# clipped wire lanes, ternary/blockwise 2-bit, identity 32-bit.
ALL_SPECS = [
    "log:0", "log:1", "log:2", "log:4", "log:6", "log:7",
    "uniform:1", "uniform:2", "uniform:3", "uniform:6", "uniform:7",
    "uniform_amax:5", "uniform:7:wire", "uniform:3:wire",
    "uniform_amax:7:w8",
    "terngrad", "blockwise:64", "blockwise:256", "identity",
]

EXPECTED_BITS = {
    "log:0": 2, "log:1": 3, "log:2": 3, "log:4": 4, "log:6": 4,
    "log:7": 6,
    "uniform:1": 3, "uniform:2": 4, "uniform:3": 6, "uniform:6": 8,
    "uniform:7": 16, "uniform_amax:5": 8, "uniform:7:wire": 8,
    "uniform:3:wire": 4, "uniform_amax:7:w8": 8,
    "terngrad": 2, "blockwise:64": 2, "blockwise:256": 2,
    "identity": 32,
}


def _x(numel, seed, scale=0.2):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=numel, scale=scale)
                       .astype(np.float32))


def _reference_Q(cd, x, wb, key):
    """The codec's own quantize->dequantize at wb's scale."""
    if isinstance(cd, comm.BlockwiseCodec):
        x2d, _ = cd._blocks(x)
        codes, scales = grids.blockwise_quantize(x2d)
        return grids.blockwise_dequantize(
            codes, scales).reshape(-1)[:x.shape[0]]
    u = jax.random.uniform(key, x.shape) if cd.stochastic else None
    return cd.dequantize(cd.quantize(x, wb.scale, u=u), wb.scale)


class TestLaneWidths:
    @pytest.mark.parametrize("spec", ALL_SPECS)
    def test_registry_bits(self, spec):
        assert comm.get_codec(spec).bits == EXPECTED_BITS[spec]

    def test_all_supported_widths_covered(self):
        widths = {comm.get_codec(s).bits for s in ALL_SPECS}
        assert set(B.SUPPORTED_BITS) <= widths


class TestRoundtrip:
    @pytest.mark.parametrize("spec", ALL_SPECS)
    @pytest.mark.parametrize("numel", [1, 37, 1000, 2049])
    def test_encode_decode_is_Q(self, spec, numel):
        """decode(encode(x)) == the codec's own quantize->dequantize
        (exactly - packing must be lossless on codes)."""
        cd = comm.get_codec(spec)
        x = _x(numel, seed=numel * 7 + len(spec))
        key = jax.random.PRNGKey(numel)
        wb = cd.encode(x, key=key, backend="jnp")
        out = wb.decode(backend="jnp")
        if spec == "identity":
            np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
            return
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(_reference_Q(cd, x, wb, key)))

    @pytest.mark.parametrize("bits", list(B.SUPPORTED_BITS))
    @pytest.mark.parametrize("numel", [1, 3, 7, 64, 129, 999])
    def test_lane_pack_roundtrip(self, bits, numel):
        rng = np.random.default_rng(numel * bits)
        lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
        dt = np.int16 if bits == 16 else np.int8
        codes = jnp.asarray(rng.integers(lo, hi + 1, size=numel).astype(dt))
        p = B.pack_flat(codes, bits)
        assert p.dtype == jnp.uint8
        assert p.shape == (B.payload_nbytes(numel, bits),)
        np.testing.assert_array_equal(
            np.asarray(B.unpack_flat(p, bits, numel)), np.asarray(codes))


class TestBackendParity:
    """jnp-vs-Pallas BITWISE parity (interpret mode off TPU): the fused
    kernels call the same grids/bits functions on their VMEM tiles."""

    @pytest.mark.parametrize("spec", ALL_SPECS)
    @pytest.mark.parametrize("numel", [64, 1000])
    def test_encode_decode_parity(self, spec, numel):
        cd = comm.get_codec(spec)
        x = _x(numel, seed=numel)
        key = jax.random.PRNGKey(7)
        wj = cd.encode(x, key=key, backend="jnp")
        wp = cd.encode(x, key=key, backend="pallas")
        np.testing.assert_array_equal(np.asarray(wj.payload),
                                      np.asarray(wp.payload))
        np.testing.assert_array_equal(np.asarray(wj.scale),
                                      np.asarray(wp.scale))
        np.testing.assert_array_equal(
            np.asarray(wj.decode(backend="jnp")),
            np.asarray(wp.decode(backend="pallas")))

    @pytest.mark.parametrize("spec", ["log:6", "log:7", "uniform:7:wire",
                                      "terngrad", "blockwise:256"])
    def test_encode_parity_multitile(self, spec):
        """> one (ENC_ROWS, lanes) tile: the two-phase amax accumulator
        must fold partials across grid steps exactly."""
        self.test_encode_decode_parity(spec, 33000)

    @pytest.mark.parametrize("spec", ["log:2", "log:4", "log:6", "log:7",
                                      "uniform:7:wire", "uniform:3",
                                      "terngrad"])
    @pytest.mark.parametrize("n_rows", [1, 4, 8])
    def test_rows_parity(self, spec, n_rows):
        cd = comm.get_codec(spec)
        numel = 5003
        x = _x(numel, seed=n_rows)
        key = jax.random.PRNGKey(n_rows)
        pj, sj = comm.encode_rows(x, cd, n_rows, key=key, backend="jnp")
        pp, sp = comm.encode_rows(x, cd, n_rows, key=key, backend="pallas")
        np.testing.assert_array_equal(np.asarray(pj), np.asarray(pp))
        np.testing.assert_array_equal(np.asarray(sj), np.asarray(sp))
        c = -(-numel // n_rows)
        assert pj.shape == (n_rows, cd.payload_nbytes(c))
        scales = jnp.full((n_rows,), sj)
        dj = comm.decode_rows(pj, scales, cd, c, backend="jnp")
        dp = comm.decode_rows(pj, scales, cd, c, backend="pallas")
        np.testing.assert_array_equal(np.asarray(dj), np.asarray(dp))

    @pytest.mark.parametrize("spec", ["log:4", "log:6", "log:7",
                                      "uniform:7:wire", "uniform:3"])
    def test_ef_rows_parity(self, spec):
        """The fused quantize+pack+residual kernel: payloads AND the EF
        residual e' = x - deq(codes) match bitwise."""
        cd = comm.get_codec(spec)
        x = _x(4097, seed=11, scale=0.1)
        scale = grids.amax_scale(x)
        pj, ej = comm.encode_rows_ef(x, scale, cd, 4, backend="jnp")
        pp, ep = comm.encode_rows_ef(x, scale, cd, 4, backend="pallas")
        np.testing.assert_array_equal(np.asarray(pj), np.asarray(pp))
        np.testing.assert_array_equal(np.asarray(ej), np.asarray(ep))
        # e' = x - deq(codes): compare against an eager recomputation to
        # 1 ulp - eager vs compiled differ by FMA contraction, which is
        # a compilation-mode artifact, not a codec property (the bitwise
        # contract is the jnp-vs-pallas parity above, where both sides
        # are compiled)
        codes = cd.quantize(x, scale)
        np.testing.assert_allclose(
            np.asarray(ej), np.asarray(x - cd.dequantize(codes, scale)),
            rtol=0, atol=1e-7)


class TestByteAccounting:
    @pytest.mark.parametrize("spec", ALL_SPECS)
    @pytest.mark.parametrize("numel", [1, 5, 100, 4097])
    def test_wire_nbytes_equals_buffer_bytes(self, spec, numel):
        """The registry's exact accounting == the measured buffer: no
        hand-rolled byte formulas can drift from the real payload."""
        cd = comm.get_codec(spec)
        x = _x(numel, seed=numel)
        wb = cd.encode(x, key=jax.random.PRNGKey(0))
        assert wb.payload.nbytes == cd.payload_nbytes(numel), spec
        assert wb.nbytes == cd.wire_nbytes(numel), spec

    @pytest.mark.parametrize("numel", [1, 1000, 4097])
    @pytest.mark.parametrize("n_rows", [1, 4, 8])
    def test_rows_nbytes(self, numel, n_rows):
        cd = comm.get_codec("log:6")
        x = _x(numel, seed=numel)
        payload, _ = comm.encode_rows(x, cd, n_rows)
        c = -(-numel // n_rows)
        assert payload.nbytes == n_rows * cd.payload_nbytes(c)


class TestLogDequantLUT:
    """The SMEM dequant table that replaced the fused decoder's
    per-element exp2 (the PR-5 0.23x regression). The table is built by
    evaluating ``grids.log_dequantize`` itself - XLA lowers exp2 as
    exp(x*ln2), inexact for large integral exponents, so any
    independently built table would diverge from the oracle by an ulp.
    Every contract here is BITWISE."""

    LOG_SPECS = [s for s in ALL_SPECS if s.startswith("log")]

    @pytest.mark.parametrize("spec", LOG_SPECS)
    def test_table_matches_oracle(self, spec):
        """LUT[c + n/2] == log_dequantize(c, 1.0, k_g) for every code
        the lane can carry, in and out of the nominal range - covers
        the odd 3/6-bit lane widths (log:1/log:2, log:7)."""
        cd = comm.get_codec(spec)
        lut = grids.log_dequant_table(cd.k, cd.bits)
        n = 1 << cd.bits
        assert lut.shape == (n,)
        codes = jnp.arange(-(n // 2), n // 2, dtype=jnp.int32)
        oracle = grids.log_dequantize(codes, jnp.float32(1.0), cd.k)
        assert (np.asarray(oracle, np.float32).tobytes()
                == np.asarray(lut, np.float32).tobytes())

    @pytest.mark.parametrize("spec", LOG_SPECS)
    @pytest.mark.parametrize("scale", [0.5, 1.0, 3.724])
    def test_lut_dequantize_matches_oracle(self, spec, scale):
        cd = comm.get_codec(spec)
        n = 1 << cd.bits
        codes = jnp.arange(-(n // 2), n // 2, dtype=jnp.int8)
        s = jnp.float32(scale)
        via_lut = grids.log_dequantize_lut(
            codes, s, grids.log_dequant_table(cd.k, cd.bits))
        oracle = grids.log_dequantize(codes, s, cd.k)
        assert (np.asarray(via_lut, np.float32).tobytes()
                == np.asarray(oracle, np.float32).tobytes())

    @pytest.mark.parametrize("spec", LOG_SPECS)
    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_fused_decode_matches_legacy_chain(self, spec, backend):
        """decode(encode(x)) through the LUT'd fused path (both
        backends) == the legacy unpack-then-log_dequantize chain."""
        cd = comm.get_codec(spec)
        x = _x(4096, seed=11)
        wb = cd.encode(x, key=jax.random.PRNGKey(0), backend="jnp")
        fused = wb.decode(backend=backend)
        codes = B.unpack_flat(wb.payload, cd.bits, x.shape[0])
        legacy = grids.log_dequantize(codes, wb.scale, cd.k)
        assert (np.asarray(fused, np.float32).tobytes()
                == np.asarray(legacy, np.float32).tobytes())


class TestEncRowsOverride:
    def test_set_enc_rows_parity(self):
        """A per-backend tile-width override changes tiling only: wire
        payloads and decodes stay bitwise identical to the default."""
        from repro.comm import kernels as K
        cd = comm.get_codec("log:6")
        x = _x(K.ENC_ROWS * K.LANES * 2 + 130, seed=5)
        base = cd.encode(x, backend="pallas")
        try:
            K.set_enc_rows(K.ENC_ROWS * 2)
            assert K.enc_rows() == K.ENC_ROWS * 2
            wb = cd.encode(x, backend="pallas")
            np.testing.assert_array_equal(np.asarray(base.payload),
                                          np.asarray(wb.payload))
            np.testing.assert_array_equal(
                np.asarray(base.decode(backend="pallas")),
                np.asarray(wb.decode(backend="pallas")))
        finally:
            K.set_enc_rows(None)
        assert K.enc_rows() == K.ENC_ROWS

    def test_set_enc_rows_validates(self):
        from repro.comm import kernels as K
        with pytest.raises(ValueError):
            K.set_enc_rows(12)   # not a multiple of the f32 sublane


class TestWireBufferPytree:
    def test_jit_through(self):
        """WireBuffer crosses jit boundaries as a pytree (static spec)."""
        cd = comm.get_codec("log:6")
        x = _x(500, seed=1)

        @jax.jit
        def f(v):
            wb = cd._encode_impl(v, key=None, backend="jnp")
            return wb, wb.decode(backend="jnp")

        wb, out = f(x)
        assert wb.spec == "log:6" and wb.shape == (500,)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(cd.encode(x).decode()))


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
class TestFuzz:
    """Randomized versions of the contracts above (CI runs these with
    requirements-dev.txt installed)."""

    if HAVE_HYPOTHESIS:
        @pytest.mark.parametrize("spec", ALL_SPECS)
        @given(numel=st.integers(1, 3000), seed=st.integers(0, 2 ** 31 - 1))
        @settings(max_examples=10, deadline=None)
        def test_roundtrip_and_bytes(self, spec, numel, seed):
            cd = comm.get_codec(spec)
            x = _x(numel, seed)
            key = jax.random.PRNGKey(seed)
            wb = cd.encode(x, key=key, backend="jnp")
            assert wb.payload.nbytes == cd.payload_nbytes(numel)
            assert wb.nbytes == cd.wire_nbytes(numel)
            out = wb.decode(backend="jnp")
            if spec == "identity":
                np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
            else:
                np.testing.assert_array_equal(
                    np.asarray(out), np.asarray(_reference_Q(cd, x, wb, key)))

        @given(bits=st.sampled_from(list(B.SUPPORTED_BITS)),
               numel=st.integers(1, 999), seed=st.integers(0, 2 ** 31 - 1))
        @settings(max_examples=40, deadline=None)
        def test_lane_pack_roundtrip(self, bits, numel, seed):
            rng = np.random.default_rng(seed)
            lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
            dt = np.int16 if bits == 16 else np.int8
            codes = jnp.asarray(rng.integers(lo, hi + 1,
                                             size=numel).astype(dt))
            p = B.pack_flat(codes, bits)
            np.testing.assert_array_equal(
                np.asarray(B.unpack_flat(p, bits, numel)),
                np.asarray(codes))

        @given(spec=st.sampled_from(["log:4", "log:7", "uniform:7:wire",
                                     "uniform:3", "terngrad"]),
               numel=st.integers(1, 4000), n_rows=st.sampled_from([1, 4, 8]),
               seed=st.integers(0, 2 ** 31 - 1))
        @settings(max_examples=15, deadline=None)
        def test_rows_backend_parity(self, spec, numel, n_rows, seed):
            cd = comm.get_codec(spec)
            x = _x(numel, seed)
            key = jax.random.PRNGKey(seed)
            pj, sj = comm.encode_rows(x, cd, n_rows, key=key, backend="jnp")
            pp, sp = comm.encode_rows(x, cd, n_rows, key=key,
                                      backend="pallas")
            np.testing.assert_array_equal(np.asarray(pj), np.asarray(pp))
            np.testing.assert_array_equal(np.asarray(sj), np.asarray(sp))
