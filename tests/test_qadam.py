"""Tests for Algorithm 1 (single-machine Quantized Generic Adam)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qadam import (QAdamConfig, qadam, apply_updates, ef_sgdm,
                              terngrad_sgd, wquan)


def _problem(d=20, seed=0):
    """Simple smooth nonconvex problem: rosenbrock-ish quadratic + cosine."""
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.normal(size=(d, d)).astype(np.float32)) / np.sqrt(d)

    def f(x):
        y = A @ x
        return 0.5 * jnp.sum(y * y) + 0.1 * jnp.sum(jnp.cos(3 * x))

    x0 = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    return f, {"x": x0}


def _run(opt, params, f, steps, key=None, noise=0.0):
    state = opt.init(params)
    gfun = jax.jit(jax.grad(lambda p: f(p["x"])))
    key = key or jax.random.PRNGKey(42)
    for _ in range(steps):
        fp = opt.forward_params(params, state)
        g = gfun(fp)
        if noise:
            key, sub = jax.random.split(key)
            g = jax.tree.map(
                lambda v: v + noise * jax.random.normal(sub, v.shape), g)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    return params, state


class TestAdamEquivalence:
    def test_identity_quantizers_match_generic_adam(self):
        """Q_g = Q_x = id  =>  Algorithm 1 is exactly generic Adam."""
        f, p0 = _problem()
        cfg = QAdamConfig(alpha=1e-2, grad_q=None, weight_q=None, schedule="sqrt")
        opt = qadam(cfg)
        pa, _ = _run(opt, p0, f, 25)

        # hand-rolled generic Adam reference
        x = p0["x"]
        m = jnp.zeros_like(x)
        v = jnp.zeros_like(x)
        g = jax.grad(f)
        for t in range(1, 26):
            gt = g(x)
            th = 1.0 - cfg.theta / t
            v = th * v + (1 - th) * gt * gt
            m = cfg.beta * m + (1 - cfg.beta) * gt
            x = x - (cfg.alpha / np.sqrt(t)) * m / jnp.sqrt(v + cfg.eps)
        np.testing.assert_allclose(np.asarray(pa["x"]), np.asarray(x),
                                   rtol=1e-5, atol=1e-6)

    def test_error_feedback_invariant(self):
        """x~_t = x_t - e_t satisfies x~_{t+1} = x~_t + Delta_t (Notation 1)."""
        f, p0 = _problem(seed=3)
        cfg = QAdamConfig(alpha=1e-2, grad_q="log:3")
        opt = qadam(cfg)
        state = opt.init(p0)
        params = p0
        g = jax.grad(lambda p: f(p["x"]))
        for t in range(1, 11):
            grads = g(params)
            # recompute Delta_t = alpha_t m_t/sqrt(v_t+eps) (pre-EF, pre-Q)
            th = 1.0 - cfg.theta / t
            v_new = th * state.v["x"] + (1 - th) * grads["x"] ** 2
            m_new = cfg.beta * state.m["x"] + (1 - cfg.beta) * grads["x"]
            delta = cfg.alpha * m_new / jnp.sqrt(v_new + cfg.eps)
            tilde_before = params["x"] - state.e["x"]
            upd, state = opt.update(grads, state, params)
            params = apply_updates(params, upd)
            tilde_after = params["x"] - state.e["x"]
            np.testing.assert_allclose(np.asarray(tilde_after),
                                       np.asarray(tilde_before - delta),
                                       rtol=1e-4, atol=1e-6)


class TestConvergence:
    def test_qadam_converges_to_stationarity(self):
        """Theorem 3.1: gradient-quantized QAdam-EF reaches the same
        stationarity as unquantized generic Adam (same constants order)."""
        f, p0 = _problem(d=30, seed=1)
        g0 = float(jnp.linalg.norm(jax.grad(f)(p0["x"])))
        p_q, _ = _run(qadam(QAdamConfig(alpha=3e-2, grad_q="log:4",
                                        schedule="sqrt")), p0, f, 600)
        p_fp, _ = _run(qadam(QAdamConfig(alpha=3e-2, grad_q=None,
                                         schedule="sqrt")), p0, f, 600)
        gq = float(jnp.linalg.norm(jax.grad(f)(p_q["x"])))
        gfp = float(jnp.linalg.norm(jax.grad(f)(p_fp["x"])))
        assert gq < 0.25 * g0, (gq, g0)          # made real progress
        assert gq < 1.5 * gfp + 1e-3, (gq, gfp)  # matches full precision

    def test_ef_beats_no_ef_with_coarse_quantizer(self):
        """The paper's core claim: a *biased* quantizer needs error feedback.
        Sign/blockwise compression (the most biased channel we ship) without
        EF stalls at a visibly worse level."""
        f, p0 = _problem(d=30, seed=2)
        base = dict(alpha=2e-2, grad_q="blockwise:1024", schedule="constant")
        p_ef, _ = _run(qadam(QAdamConfig(error_feedback=True, **base)), p0, f, 500)
        p_no, _ = _run(qadam(QAdamConfig(error_feedback=False, **base)), p0, f, 500)
        l_ef, l_no = float(f(p_ef["x"])), float(f(p_no["x"]))
        assert l_ef < l_no - 1.0, (l_ef, l_no)

    def test_weight_quantization_converges_to_ball(self):
        """Theorem 3.2: with Q_x only, converge to a delta_x-ball around a
        stationary point; finer grids (bigger k_x) shrink the ball.
        The paper's absolute grid covers [-0.5, 0.5], so the problem is
        built with its minimizer inside that box."""
        rng = np.random.default_rng(4)
        d = 20
        A = jnp.asarray(rng.normal(size=(d, d)).astype(np.float32) / np.sqrt(d))
        xstar = jnp.asarray(rng.uniform(-0.3, 0.3, size=d).astype(np.float32))

        def f(x):
            y = A @ (x - xstar)
            return 0.5 * jnp.sum(y * y) + 0.01 * jnp.sum(jnp.cos(8 * x))

        p0 = {"x": jnp.asarray(rng.uniform(-0.45, 0.45, size=d).astype(np.float32))}
        g0 = float(jnp.linalg.norm(jax.grad(f)(p0["x"])))
        final = {}
        for k_x in (3, 7):
            cfg = QAdamConfig(alpha=1e-2, grad_q=None,
                              weight_q=f"uniform:{k_x}", schedule="sqrt")
            opt = qadam(cfg)
            p, st = _run(opt, p0, f, 600)
            qp = opt.forward_params(p, st)
            final[k_x] = float(jnp.linalg.norm(jax.grad(f)(qp["x"])))
        assert final[7] < 0.3 * g0, (final, g0)   # inside a small ball
        assert final[7] <= final[3] + 0.05, final  # finer grid: no bigger ball

    def test_baselines_run(self):
        f, p0 = _problem(d=10, seed=5)
        for opt in (ef_sgdm(alpha=1e-2), terngrad_sgd(alpha=1e-2)):
            p, _ = _run(opt, p0, f, 50)
            assert np.all(np.isfinite(np.asarray(p["x"])))

    def test_wquan_helper(self):
        _, p0 = _problem(d=10)
        q = wquan(p0, k_x=5)
        assert q["x"].shape == p0["x"].shape
        grid = 0.5 / 2 ** 5
        ratio = np.asarray(jnp.clip(q["x"], -0.5, 0.5)) / grid
        np.testing.assert_allclose(ratio, np.round(ratio), atol=1e-4)
