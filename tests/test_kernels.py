"""Pallas kernels vs pure-jnp oracle: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.core import quantizers as Q

SHAPES = [(7,), (128,), (1000,), (256, 128), (33, 77), (4, 128, 130), (32768,)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _rand(shape, dtype, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape, scale=scale)).astype(dtype)


class TestLogQuantizeKernel:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_matches_oracle(self, shape, dtype):
        x = _rand(shape, dtype, seed=hash(shape) % 1000)
        k_g = 6
        codes_p, scale_p = ops.quantize_log(x, k_g)
        codes_r, scale_r = ops.quantize_log(x, k_g, use_pallas=False)
        np.testing.assert_allclose(np.float32(scale_p), np.float32(scale_r),
                                   rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(codes_p), np.asarray(codes_r))

    @pytest.mark.parametrize("k_g", [1, 3, 6])
    def test_roundtrip_matches_core_quantizer(self, k_g):
        """kernel path == repro.core.quantizers.LogGradQuantizer semantics."""
        x = _rand((513,), jnp.float32, seed=k_g)
        codes, scale = ops.quantize_log(x, k_g)
        deq = ops.dequantize_log(codes, scale, k_g)
        expect = Q.LogGradQuantizer(k_g=k_g)(x)
        np.testing.assert_allclose(np.asarray(deq), np.asarray(expect),
                                   rtol=1e-5, atol=1e-8)

    def test_zero_tensor(self):
        x = jnp.zeros((300,), jnp.float32)
        codes, scale = ops.quantize_log(x, 4)
        assert np.all(np.asarray(codes) == 0)
        deq = ops.dequantize_log(codes, scale, 4)
        assert np.all(np.asarray(deq) == 0)


class TestUniformQuantizeKernel:
    @pytest.mark.parametrize("shape", SHAPES[:5])
    @pytest.mark.parametrize("absolute", [True, False])
    def test_matches_oracle(self, shape, absolute):
        x = _rand(shape, jnp.float32, seed=1, scale=0.2)
        codes_p, s_p = ops.quantize_uniform(x, 5, absolute=absolute)
        codes_r, s_r = ops.quantize_uniform(x, 5, absolute=absolute,
                                            use_pallas=False)
        np.testing.assert_allclose(np.float32(s_p), np.float32(s_r), rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(codes_p), np.asarray(codes_r))

    def test_roundtrip_matches_core(self):
        x = _rand((777,), jnp.float32, seed=2, scale=0.2)
        codes, scale = ops.quantize_uniform(x, 6, absolute=True)
        deq = ops.dequantize_uniform(codes, scale, 6)
        expect = Q.UniformWeightQuantizer(k_x=6)(x)
        np.testing.assert_allclose(np.asarray(deq), np.asarray(expect),
                                   atol=1e-7)


class TestAdamEFKernel:
    @pytest.mark.parametrize("shape", [(100,), (256, 128), (5, 333)])
    def test_matches_oracle(self, shape):
        seed = abs(hash(shape)) % 100
        g = _rand(shape, jnp.float32, seed=seed)
        m = _rand(shape, jnp.float32, seed=seed + 1, scale=0.1)
        v = jnp.abs(_rand(shape, jnp.float32, seed=seed + 2, scale=0.01))
        e = _rand(shape, jnp.float32, seed=seed + 3, scale=1e-3)
        hp = dict(alpha_t=1e-3, beta=0.99, theta_t=0.9, eps=1e-5)
        out_p = ops.adam_ef_step(g, m, v, e, **hp, k_g=6)
        out_r = ops.adam_ef_step(g, m, v, e, **hp, k_g=6, use_pallas=False)
        names = ["m", "v", "codes", "scale", "e"]
        for n, a, b in zip(names, out_p, out_r):
            if n == "codes":
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            else:
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=2e-5, atol=1e-7, err_msg=n)

    def test_fused_step_equals_algorithm1_lines(self):
        """Fused kernel == the unfused Algorithm 1 computations."""
        g = _rand((512,), jnp.float32, seed=9)
        m = jnp.zeros((512,))
        v = jnp.zeros((512,))
        e = jnp.zeros((512,))
        a, b, th, eps, kg = 0.01, 0.99, 0.5, 1e-5, 6
        m2, v2, codes, scale, e2 = ops.adam_ef_step(
            g, m, v, e, alpha_t=a, beta=b, theta_t=th, eps=eps, k_g=kg)
        v_ref = (1 - th) * g * g
        m_ref = (1 - b) * g
        de_ref = a * m_ref / jnp.sqrt(v_ref + eps)
        np.testing.assert_allclose(np.asarray(v2), np.asarray(v_ref), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(m2), np.asarray(m_ref), rtol=1e-5)
        deq = ops.dequantize_log(codes, scale, kg)
        np.testing.assert_allclose(np.asarray(deq + e2), np.asarray(de_ref),
                                   rtol=2e-5, atol=1e-7)

    def test_ef_residual_bound(self):
        """|e'| per element <= half the local grid step (log grid property)."""
        g = _rand((4096,), jnp.float32, seed=11)
        out = ops.adam_ef_step(g, jnp.zeros_like(g), jnp.zeros_like(g),
                               jnp.zeros_like(g), alpha_t=0.01, beta=0.9,
                               theta_t=0.5, eps=1e-8, k_g=6)
        _, _, codes, scale, e2 = out
        de = ops.dequantize_log(codes, scale, 6) + e2
        assert float(jnp.max(jnp.abs(e2))) <= float(jnp.max(jnp.abs(de)))


class TestPack4Kernel:
    @pytest.mark.parametrize("rows", [256, 1024])
    def test_roundtrip_and_matches_core_packing(self, rows):
        from repro.kernels.pack import pack4_pallas, unpack4_pallas
        from repro.core.packing import pack_codes
        rng = np.random.default_rng(rows)
        codes = jnp.asarray(rng.integers(-8, 8, size=(rows, 256))
                            .astype(np.int8))
        packed = pack4_pallas(codes, interpret=True)
        assert packed.shape == (rows, 128) and packed.dtype == jnp.uint8
        out = unpack4_pallas(packed, interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))
        # same wire bytes as the reference codec (layout differs: the
        # kernel packs lane pairs, the codec packs flat pairs)
        ref = pack_codes(codes, 4)
        assert ref.size == packed.size


class TestFlashAttention:
    @pytest.mark.parametrize("case", [
        dict(B=2, Sq=256, Skv=256, H=4, K=2, hd=64, causal=True, window=0,
             softcap=None),
        dict(B=1, Sq=128, Skv=384, H=8, K=2, hd=32, causal=True, window=0,
             softcap=None, q_offset=256),       # decode-style suffix queries
        dict(B=1, Sq=256, Skv=256, H=2, K=2, hd=64, causal=True, window=96,
             softcap=50.0),                     # gemma-style SWA + softcap
        dict(B=2, Sq=128, Skv=128, H=4, K=4, hd=128, causal=False, window=0,
             softcap=None),                     # bidirectional (whisper enc)
    ])
    def test_matches_reference_attention(self, case):
        from repro.kernels.flash_attention import flash_attention
        from repro.models import layers as L
        rng = np.random.default_rng(7)
        B, Sq, Skv, H, K, hd = (case["B"], case["Sq"], case["Skv"],
                                case["H"], case["K"], case["hd"])
        q = jnp.asarray(rng.normal(size=(B, Sq, H, hd)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(B, Skv, K, hd)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(B, Skv, K, hd)).astype(np.float32))
        q_off = case.get("q_offset", 0)
        out = flash_attention(q, k, v, causal=case["causal"],
                              window=case["window"], softcap=case["softcap"],
                              q_offset=q_off, interpret=True)
        expect = L.attention(q, k, v, q_pos=q_off + jnp.arange(Sq),
                             causal=case["causal"], window=case["window"],
                             softcap=case["softcap"])
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-4, atol=2e-5)
