"""Tests for the ``repro.perf`` subsystem: AOT step export/load,
persistent compile cache, the profiling trace harness, and the
benchmark compare gate.

The load-bearing contracts:

  1. an AOT-loaded executable produces BITWISE the state the freshly
     compiled one does (an artifact dir is a cache, never a fork);
  2. a second session against a warm AOT dir reports ZERO compilations
     (the cold-start elimination is real, not probabilistic);
  3. the AOT key is value-independent for python scalars (the train
     step's ring slot varies per dispatch and must not fork artifacts)
     but forks on config/shape changes;
  4. enabling the persistent cache mid-process takes effect (jax
     initializes its cache object once - see cache._reset_cache_state).
"""
import glob
import importlib
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import perf
from repro.perf import aot


def _leaves_bytes(tree):
    return [np.asarray(l).tobytes() for l in jax.tree_util.tree_leaves(tree)]


class TestAotKey:
    def test_python_scalars_are_value_independent(self):
        a = aot.step_key({"f": 1}, (jnp.ones(4), 3, 2.5, True))
        b = aot.step_key({"f": 1}, (jnp.ones(4), 9, 0.1, False))
        assert a == b

    def test_forks_on_facts_shapes_dtypes(self):
        base = aot.step_key({"f": 1}, (jnp.ones(4),))
        assert aot.step_key({"f": 2}, (jnp.ones(4),)) != base
        assert aot.step_key({"f": 1}, (jnp.ones(5),)) != base
        assert aot.step_key({"f": 1},
                            (jnp.ones(4, jnp.int32),)) != base

    def test_dataclass_facts_canonicalize(self):
        import dataclasses

        @dataclasses.dataclass(frozen=True)
        class Cfg:
            k: int = 6
        assert aot.digest(Cfg()) == aot.digest(Cfg())
        assert aot.digest(Cfg(k=7)) != aot.digest(Cfg())


class TestAotRoundtrip:
    def test_export_load_bit_identity(self, tmp_path):
        jitted = jax.jit(lambda s, x: (s * 1.5 + x, (s * x).sum()))
        args = (jnp.arange(8.0), jnp.full(8, 2.0))
        facts = {"prog": "t"}
        stats = {}
        cold = aot.load_or_compile(jitted, args, aot_dir=str(tmp_path),
                                   facts=facts, stats=stats)
        ref = jitted(*args)
        assert stats == {"compilations": 1, "aot_saves": 1}
        assert glob.glob(str(tmp_path / ("*" + aot.SUFFIX)))
        warm = aot.load_or_compile(jitted, args, aot_dir=str(tmp_path),
                                   facts=facts, stats=stats)
        assert stats["aot_loads"] == 1 and stats["compilations"] == 1
        for c, w, r in zip(_leaves_bytes(cold(*args)),
                           _leaves_bytes(warm(*args)), _leaves_bytes(ref)):
            assert c == w == r

    def test_corrupt_artifact_is_a_miss(self, tmp_path):
        jitted = jax.jit(lambda x: x + 1)
        args = (jnp.ones(4),)
        aot.load_or_compile(jitted, args, aot_dir=str(tmp_path),
                            facts="f", stats=None)
        [path] = glob.glob(str(tmp_path / ("*" + aot.SUFFIX)))
        with open(path, "wb") as f:
            f.write(b"torn")
        stats = {}
        fn = aot.load_or_compile(jitted, args, aot_dir=str(tmp_path),
                                 facts="f", stats=stats)
        assert stats == {"compilations": 1, "aot_saves": 1}
        np.testing.assert_array_equal(np.asarray(fn(*args)),
                                      np.asarray(jitted(*args)))

    def test_no_dir_passthrough(self):
        jitted = jax.jit(lambda x: x * 2)
        stats = {}
        fn = aot.load_or_compile(jitted, (jnp.ones(2),), aot_dir=None,
                                 facts="f", stats=stats)
        assert fn is jitted and stats == {"compilations": 1}


def _train_session(aot_dir, steps=2):
    from repro.configs import get_config
    from repro.models.model import Model
    from repro.launch.mesh import make_local_mesh
    from repro.dist.step import make_train_step, TrainConfig
    from repro.train.session import SessionConfig, TrainSession
    from repro.data.pipeline import batch_for_model

    cfg = get_config("yi-6b", smoke=True)
    model = Model(cfg)
    mesh = make_local_mesh(data=1, model=1)
    tc = TrainConfig(grad_k=6, weight_k=None, worker_axes=())
    art = make_train_step(model, mesh, tc)
    sess = TrainSession.from_artifacts(
        art, batch_for_model(cfg, 32, 2, seed=0),
        SessionConfig(log_every=0, prefetch=0, aot_dir=aot_dir),
        log=lambda *_: None)
    sess.run(steps)
    state = jax.device_get(sess._state)
    stats = dict(sess.stats)
    sess.close()
    return state, stats


@pytest.mark.slow
class TestSessionAot:
    def test_second_train_session_zero_compilations(self, tmp_path):
        d = str(tmp_path / "aot")
        cold_state, cold = _train_session(d)
        warm_state, warm = _train_session(d)
        assert cold["compilations"] == 1 and cold["aot_saves"] == 1
        assert warm["compilations"] == 0 and warm["aot_loads"] == 1
        for a, b in zip(_leaves_bytes(cold_state),
                        _leaves_bytes(warm_state)):
            assert a == b

    def test_second_serve_session_zero_compilations(self, tmp_path):
        from repro.configs import get_config
        from repro.models.model import Model
        from repro.serve import Request, ServeSession

        cfg = get_config("yi-6b", smoke=True)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        d = str(tmp_path / "aot")

        def run():
            s = ServeSession(model, params, slots=2, max_seq=64, seed=0,
                             aot_dir=d)
            s.submit(Request(prompt=[1, 2, 3], max_new_tokens=4))
            res = s.drain()
            return list(res.values())[0].tokens, dict(s.stats)

        toks_c, cold = run()
        toks_w, warm = run()
        assert cold["compilations"] >= 1
        assert warm["compilations"] == 0 and warm["aot_loads"] >= 1
        assert toks_c == toks_w


class TestPersistentCache:
    def test_enable_after_first_compile_takes_effect(self, tmp_path):
        prev = jax.config.jax_compilation_cache_dir
        d = str(tmp_path / "xla")
        try:
            # a compile BEFORE enabling initializes jax's cache state
            jax.jit(lambda x: x - 3)(jnp.ones(4)).block_until_ready()
            assert perf.enable_persistent_cache(d) == d
            jax.jit(lambda x: x * 3 + 7)(jnp.ones(16)).block_until_ready()
            assert perf.cache_entries(d) >= 1
        finally:
            if prev:
                perf.enable_persistent_cache(prev)
            else:
                perf.disable_persistent_cache()

    def test_env_off_disables(self, monkeypatch):
        monkeypatch.setenv(perf.cache.ENV_VAR, "off")
        assert perf.enable_persistent_cache() is None
        assert perf.ensure_persistent_cache() is None

    def test_ensure_requires_opt_in(self, monkeypatch):
        monkeypatch.delenv(perf.cache.ENV_VAR, raising=False)
        prev = jax.config.jax_compilation_cache_dir
        try:
            perf.disable_persistent_cache()
            assert perf.ensure_persistent_cache() is None
            assert jax.config.jax_compilation_cache_dir is None
        finally:
            if prev:
                perf.enable_persistent_cache(prev)

    def test_cache_entries_ignores_sidecars(self, tmp_path):
        (tmp_path / "entry").write_bytes(b"x")
        (tmp_path / "entry-atime").write_bytes(b"x")
        (tmp_path / ".hidden").write_bytes(b"x")
        assert perf.cache_entries(str(tmp_path)) == 1


class TestTraceHarness:
    def test_trace_writes_profile(self, tmp_path):
        d = str(tmp_path / "tr")
        with perf.trace(d) as out:
            assert out == d
            with perf.annotate("bench:test"):
                jax.jit(lambda x: x @ x)(jnp.ones((32, 32))
                                         ).block_until_ready()
        runs = perf.profiling.trace_runs(d)
        assert len(runs) == 1
        assert glob.glob(os.path.join(runs[0], "*.xplane.pb"))

    def test_trace_disabled_is_noop(self, tmp_path):
        d = str(tmp_path / "tr")
        with perf.trace(d, enabled=False) as out:
            assert out is None
        assert not os.path.exists(d)


class TestAutotune:
    def test_tune_restores_when_not_installed(self):
        from repro.comm import kernels as K
        res = perf.autotune.tune_enc_rows(candidates=(8, 16), iters=1,
                                          numel=1 << 12, install=False)
        assert res["best"] in (8, 16)
        assert set(res["timings_s"]) == {8, 16}
        assert K.enc_rows() == K.ENC_ROWS   # override not left behind

    def test_tune_mm_cols_restores_when_not_installed(self):
        from repro.comm import matmul as MM
        res = perf.autotune.tune_mm_cols(candidates=(128, 256), iters=1,
                                         m=4, k=256, n=256, install=False)
        # 256 % 128 == 0 and 256 % 256 == 0: both candidates measured
        assert res["best"] in (128, 256)
        assert set(res["timings_s"]) == {128, 256}
        assert MM.mm_cols() == MM.MM_COLS   # override not left behind

    def test_tune_mm_cols_skips_non_covering_tiles(self):
        res = perf.autotune.tune_mm_cols(candidates=(128, 512), iters=1,
                                         m=4, k=256, n=256, install=False)
        assert set(res["timings_s"]) == {128}  # 512 can't tile n=256


def _compare_mod():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if root not in sys.path:  # benchmarks/ is a namespace package
        sys.path.insert(0, root)
    return importlib.import_module("benchmarks.compare")


class TestCompareGate:
    def test_ratio_floor_catches_the_pr5_regression(self):
        compare = _compare_mod()
        base = [{"name": "comm_decode_speedup_log_6", "us_per_call": 0.0,
                 "derived": "1.03x"}]
        bad = [{"name": "comm_decode_speedup_log_6", "us_per_call": 0.0,
                "derived": "0.23x", "ratio": 0.23}]
        good = [{"name": "comm_decode_speedup_log_6", "us_per_call": 0.0,
                 "derived": "1.46x", "ratio": 1.46}]
        [fail] = compare.compare(base, bad)
        assert fail["status"] == "FAIL" and "floor" in fail["detail"]
        [ok] = compare.compare(base, good)
        assert ok["status"] == "ok"

    def test_legacy_baseline_derived_ratio_parses(self):
        compare = _compare_mod()
        assert compare.row_ratio({"derived": "0.23x"}) == 0.23
        assert compare.row_ratio({"derived": "4.43GB_s_4MB"}) is None

    def test_time_budget_gate(self):
        compare = _compare_mod()
        base = [{"name": "comm_encode_fused_log_6", "us_per_call": 100.0,
                 "derived": ""}]
        new = [{"name": "comm_encode_fused_log_6", "us_per_call": 300.0,
                "derived": ""}]
        [off] = compare.compare(base, new)
        assert off["status"] == "ok"          # machine-dependent: opt-in
        [on] = compare.compare(base, new, gate_times=True, time_budget=2.0)
        assert on["status"] == "FAIL"
