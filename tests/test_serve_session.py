"""ServeSession: greedy parity vs the batch-synchronous reference,
continuous-batching invariance, zero per-token host transfers, and truly
code-resident quantized weights."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import layers as L
from repro.models.model import Model
from repro.serve import (Engine, Request, ServeSession, is_quantized,
                         params_nbytes, quantize_params)
from repro.serve.quantized import QuantizedLeaf


@pytest.fixture(scope="module")
def yi():
    cfg = get_config("yi-6b", smoke=True)
    model = Model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _reference_greedy(model, params, prompts, max_new, max_seq=48):
    """The old Engine algorithm: one padded prefill + scalar-pos decode
    loop with host-side argmax (requires equal-length prompts for the
    padded cache positions to be valid)."""
    B = len(prompts)
    plens = [len(p) for p in prompts]
    pmax = max(plens)
    assert min(plens) == pmax, "reference is only correct for equal lengths"
    toks = np.asarray(prompts, np.int32)
    batch = {"tokens": jnp.asarray(toks), "targets": jnp.asarray(toks),
             "mask": jnp.ones((B, pmax), jnp.float32)}
    prefill = jax.jit(lambda p, b: model.prefill(p, b,
                                                 max_seq_local=max_seq))
    logits, cache = prefill(params, batch)
    cur = jnp.argmax(logits[:, pmax - 1], axis=-1).astype(jnp.int32)
    outs = [[int(cur[i])] for i in range(B)]
    dec = jax.jit(lambda p, i, c, pos: model.decode_step(p, i, c, pos))
    for t in range(max_new - 1):
        lg, cache = dec(params, {"token": cur[:, None]}, cache,
                        jnp.int32(pmax + t))
        cur = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        for i in range(B):
            outs[i].append(int(cur[i]))
    return outs


class TestGreedyParity:
    def test_session_matches_reference(self, yi):
        cfg, model, params = yi
        prompts = [[5, 6, 7, 8], [9, 10, 11, 12], [3, 14, 15, 16]]
        ref = _reference_greedy(model, params, prompts, max_new=6)
        sess = ServeSession(model, params, slots=3, max_seq=48)
        hs = [sess.submit(Request(prompt=p, max_new_tokens=6))
              for p in prompts]
        res = sess.drain()
        assert [res[h].tokens for h in hs] == ref

    def test_engine_shim_matches_reference(self, yi):
        cfg, model, params = yi
        prompts = [[5, 6, 7, 8], [9, 10, 11, 12]]
        ref = _reference_greedy(model, params, prompts, max_new=5)
        out = Engine(model, params, max_seq=48).generate(
            [Request(prompt=p, max_new_tokens=5) for p in prompts])
        assert [r.tokens for r in out] == ref

    def test_quantized_high_kx_matches_fp32(self, yi):
        """High-resolution Q_x (k_x=12, int16 codes) leaves greedy decoding
        unchanged; k_x=6 (the paper's ~4x) keeps >= first-token agreement."""
        cfg, model, params = yi
        req = Request(prompt=[3, 4, 5, 6], max_new_tokens=6)

        def run(p):
            s = ServeSession(model, p, slots=1, max_seq=32)
            h = s.submit(req)
            return s.drain()[h].tokens

        full = run(params)
        assert run(quantize_params(params, k_x=12, min_numel=256)) == full
        assert run(quantize_params(params, k_x=6, min_numel=256))[0] \
            == full[0]

    @pytest.mark.parametrize("k_x,pack", [(6, True), (2, True), (6, False)])
    def test_fused_matmul_tokens_identical_to_unfused(self, yi, k_x, pack):
        """The fused dequant-matmul path (codes contracted in the kernel,
        the default) must emit tokens IDENTICAL to the unfused session
        (dequantize-then-matmul) - the end-to-end form of the bitwise
        kernel contract, covering packed sub-8-bit lanes as served."""
        cfg, model, params = yi
        qparams = quantize_params(params, k_x=k_x, min_numel=256, pack=pack)
        prompts = [[5, 6, 7, 8], [9, 10, 11, 12], [3, 14, 15, 16]]

        def run(**kw):
            s = ServeSession(model, qparams, slots=3, max_seq=48, **kw)
            hs = [s.submit(Request(prompt=p, max_new_tokens=6))
                  for p in prompts]
            res = s.drain()
            return [res[h].tokens for h in hs], s

        fused_toks, fused_sess = run()
        plain_toks, plain_sess = run(fused_matmul=False)
        assert fused_sess.fused_matmul and not plain_sess.fused_matmul
        assert fused_toks == plain_toks


class TestContinuousBatching:
    @pytest.mark.parametrize("arch", ["yi-6b", "mamba2-2.7b", "gemma2-2b"])
    def test_tokens_independent_of_batch_mates(self, arch):
        """A request's greedy tokens do not depend on what else shares the
        batch - including a slot freed by EOS/max-new and re-claimed
        mid-flight by a queued request (the continuous-batching path)."""
        cfg = get_config(arch, smoke=True)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        alone = ServeSession(model, params, slots=1, max_seq=48)
        h = alone.submit(Request(prompt=[5, 6, 7], max_new_tokens=6))
        want = alone.drain()[h].tokens

        sess = ServeSession(model, params, slots=2, max_seq=48)
        h1 = sess.submit(Request(prompt=[5, 6, 7], max_new_tokens=6))
        h2 = sess.submit(Request(prompt=list(range(9, 21)),
                                 max_new_tokens=12))
        h3 = sess.submit(Request(prompt=[5, 6, 7], max_new_tokens=6))
        res = sess.drain()
        assert res[h1].tokens == want          # longer companion alongside
        assert res[h3].tokens == want          # admitted into a reused slot
        assert res[h2].prompt_len == 12

    def test_short_prompt_not_polluted_by_padding(self, yi):
        """The per-slot position fix: a short prompt decoding next to a
        long one must match the same prompt decoded alone (the old engine
        attended over stale padded cache slots between prompt end and
        generation start)."""
        cfg, model, params = yi
        alone = ServeSession(model, params, slots=1, max_seq=48)
        h = alone.submit(Request(prompt=[7, 8], max_new_tokens=5))
        want = alone.drain()[h].tokens
        sess = ServeSession(model, params, slots=2, max_seq=48)
        h1 = sess.submit(Request(prompt=[7, 8], max_new_tokens=5))
        sess.submit(Request(prompt=list(range(1, 17)), max_new_tokens=5))
        assert sess.drain()[h1].tokens == want

    def test_eos_frees_slot_early(self, yi):
        cfg, model, params = yi
        probe = ServeSession(model, params, slots=1, max_seq=48)
        h = probe.submit(Request(prompt=[5, 6, 7, 8], max_new_tokens=6))
        toks = probe.drain()[h].tokens
        sess = ServeSession(model, params, slots=1, max_seq=48,
                            eos_id=toks[2])
        h = sess.submit(Request(prompt=[5, 6, 7, 8], max_new_tokens=6))
        r = sess.drain()[h]
        assert r.tokens == toks[:3] and r.finish_reason == "eos"


class TestNoPerTokenHostTransfer:
    def test_steady_state_decode_never_syncs(self, yi, monkeypatch):
        """Sampling is jitted: with every slot occupied and nothing queued,
        N decode steps are N dispatches and ZERO device->host transfers."""
        cfg, model, params = yi
        sess = ServeSession(model, params, slots=2, max_seq=64)
        for p in ([5, 6, 7, 8], [9, 10, 11, 12]):
            sess.submit(Request(prompt=p, max_new_tokens=30))

        gets = {"n": 0}
        real_get = jax.device_get

        def counting_get(x):
            gets["n"] += 1
            return real_get(x)

        monkeypatch.setattr(jax, "device_get", counting_get)
        dispatches0 = sess.stats["dispatches"]
        for _ in range(20):
            sess.step()
        assert gets["n"] == 0
        assert sess.stats["dispatches"] - dispatches0 == 20
        assert sess.stats["syncs"] == 0
        monkeypatch.undo()
        res = sess.drain()
        assert all(len(r.tokens) == 30 for r in res.values())
        # host reads scale with requests (harvests), not tokens
        assert sess.stats["syncs"] <= 4


class TestQuantizedResidency:
    def test_device_bytes_quarter_of_fp32(self, yi):
        """int8 codes + per-layer scales actually hold ~nbytes/4 - measured
        from the resident arrays, not a printed theoretical '/4'."""
        cfg, model, params = yi
        qp = quantize_params(params, k_x=6, min_numel=256)
        assert is_quantized(qp)
        fp32 = params_nbytes(params)
        quant = params_nbytes(qp)
        assert quant <= 0.30 * fp32
        for leaf in jax.tree.leaves(
                qp, is_leaf=lambda l: isinstance(l, QuantizedLeaf)):
            if isinstance(leaf, QuantizedLeaf):
                assert leaf.codes.dtype == jnp.int8

    def test_stacked_leaves_get_per_layer_scales(self, yi):
        cfg, model, params = yi
        qp = quantize_params(params, k_x=6, min_numel=256)
        lq = qp["blocks"]["attn"]["q"]
        assert isinstance(lq, QuantizedLeaf)
        assert lq.scale.shape == (cfg.n_layers,)
        np.testing.assert_allclose(
            np.asarray(lq.dequantize()),
            np.asarray(params["blocks"]["attn"]["q"]), atol=0.02)

    def test_pack4_roundtrip(self):
        """k_x<=2 codes pack two-per-byte through repro.core.packing."""
        rng = np.random.default_rng(0)
        x = {"w": jnp.asarray(rng.normal(size=(64, 33)).astype(np.float32))}
        qp = quantize_params(x, k_x=2, min_numel=1, pack=True)
        qu = quantize_params(x, k_x=2, min_numel=1, pack=False)
        assert qp["w"].codes.dtype == jnp.uint8
        assert qp["w"].nbytes < qu["w"].nbytes
        np.testing.assert_array_equal(np.asarray(qp["w"].dequantize()),
                                      np.asarray(qu["w"].dequantize()))

    def test_decode_attention_masks_per_slot(self):
        """Unit check of the satellite fix: rows at different depths mask
        exactly their own prefix - garbage beyond a row's length is
        unreachable."""
        rng = np.random.default_rng(1)
        B, S, K, hd = 2, 8, 2, 4
        q = jnp.asarray(rng.normal(size=(B, 1, 2, hd)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(B, S, K, hd)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(B, S, K, hd)).astype(np.float32))
        pk, pv = k.at[0, 3:].set(1e4), v.at[0, 3:].set(1e4)
        pos = jnp.asarray([2, 6])
        out = L.decode_attention(q, pk, pv, total_len=pos + 1, q_pos=pos)
        clean = L.decode_attention(q, k, v, total_len=pos + 1, q_pos=pos)
        np.testing.assert_array_equal(np.asarray(out[0]),
                                      np.asarray(clean[0]))


class TestScheduler:
    def test_submit_validates_capacity(self, yi):
        cfg, model, params = yi
        sess = ServeSession(model, params, slots=1, max_seq=16)
        with pytest.raises(ValueError):
            sess.submit(Request(prompt=list(range(12)), max_new_tokens=8))
        with pytest.raises(ValueError):
            sess.submit(Request(prompt=[], max_new_tokens=4))

    def test_queue_overflow_is_served(self, yi):
        """More requests than slots: all finish, in bounded steps."""
        cfg, model, params = yi
        sess = ServeSession(model, params, slots=2, max_seq=32)
        hs = [sess.submit(Request(prompt=[i + 1, i + 2], max_new_tokens=4))
              for i in range(5)]
        res = sess.drain()
        assert sorted(res) == sorted(hs)
        assert all(len(res[h].tokens) == 4 for h in hs)
